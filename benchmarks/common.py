"""Shared benchmark plumbing: train-once-and-cache a tiny LM, PPL eval.

The paper's tables use pretrained Qwen/LLaMA checkpoints; offline we
substitute a small llama-family LM trained in-repo on the synthetic
bigram language (DESIGN.md §8).  The trained checkpoint is cached under
reports/bench_cache so repeated benchmark runs skip the ~2-minute
training.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core import run_calibration
from repro.data.synthetic import DataConfig, SyntheticLM, calibration_batches
from repro.dist import checkpoint as ckpt
from repro.models.registry import build_model
from repro.train.trainer import TrainConfig, make_train_step, cross_entropy

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                         "bench_cache")
TRAIN_STEPS = 400
SEQ = 64
BATCH = 16


def bench_model():
    cfg = ARCHS["llama3-8b"].tiny()
    return cfg, build_model(cfg)


def bench_data(cfg):
    return SyntheticLM(DataConfig(vocab_size=cfg.vocab_size))


def trained_params(verbose: bool = True, outliers: bool = True):
    """Train (or load cached) the benchmark LM.

    ``outliers=True`` applies the output-invariant outlier injection —
    the activation regime the paper's method targets (see
    :func:`inject_outliers`)."""
    cfg, model = bench_model()
    data = bench_data(cfg)
    params = model.init(jax.random.PRNGKey(0))
    step = ckpt.latest_step(CACHE_DIR)
    if step == TRAIN_STEPS:
        restored = ckpt.restore(CACHE_DIR, step, {"params": params})
        out = restored["params"]
        if outliers:
            out = inject_outliers(out)
        return cfg, model, out, data
    train_step, opt = make_train_step(
        model, TrainConfig(lr=3e-3, warmup=30, total_steps=TRAIN_STEPS))
    train_step = jax.jit(train_step)
    opt_state = opt.init(params)
    for s in range(TRAIN_STEPS):
        batch = {k: jnp.asarray(v)
                 for k, v in data.batch(s, BATCH, SEQ).items()}
        params, opt_state, metrics = train_step(params, opt_state, batch)
        if verbose and s % 100 == 0:
            print(f"  train step {s}: loss {float(metrics['loss']):.3f}",
                  flush=True)
    ckpt.save(CACHE_DIR, TRAIN_STEPS, {"params": params})
    if outliers:
        params = inject_outliers(params)
    return cfg, model, params, data


def inject_outliers(params, key=None, n_channels: int = 8,
                    magnitude: float = 12.0):
    """Create activation-outlier channels, *exactly* output-invariant.

    Real LLMs develop a few dominant residual-stream channels (the paper's
    Theorem-1 assumption (i); also the premise of AWQ/SmoothQuant).  A
    tiny freshly-trained LM has none, which mutes the difference between
    scale-search methods.  This transform scales ``n_channels`` entries of
    every block's norm weights by ``magnitude`` and divides the matching
    *rows* of the consuming projections (wq/wk/wv, w_gate/w_up) by the
    same factor: the float function is unchanged (the norm output feeds
    only those projections), but the activation statistics now have
    dominant channels — the regime the paper targets.  Channel indices are
    fixed across layers (persistent channels, as in real models).
    """
    idx = np.arange(n_channels) * 7 % params["blocks"]["attn_norm"].shape[-1]
    scale = jnp.ones(params["blocks"]["attn_norm"].shape[-1])
    scale = scale.at[idx].set(magnitude)
    p = jax.tree_util.tree_map(lambda x: x, params)  # shallow copy
    blocks = dict(p["blocks"])
    blocks["attn_norm"] = blocks["attn_norm"] * scale
    blocks["mlp_norm"] = blocks["mlp_norm"] * scale
    inv = (1.0 / scale)[:, None]
    for w in ("wq", "wk", "wv", "w_gate", "w_up"):
        blocks[w] = blocks[w] * inv[None]
    p["blocks"] = blocks
    return p


_EVAL_CACHE = {}


def eval_ppl(model, params, data, n_seqs: int = 24, seq: int = SEQ,
             offset: int = 20_000_000) -> float:
    """Perplexity on held-out synthetic sequences (disjoint index range)."""
    toks = np.stack([data.sequence(offset + i, seq) for i in range(n_seqs)])
    fwd = jax.jit(lambda p, t: model.forward(p, {"tokens": t})[0])
    total, count = 0.0, 0
    for i in range(0, n_seqs, 8):
        t = jnp.asarray(toks[i:i + 8])
        logits = fwd(params, t)
        ce = cross_entropy(logits[:, :-1], t[:, 1:])
        total += float(ce) * (t.shape[0] * (seq - 1))
        count += t.shape[0] * (seq - 1)
    return float(np.exp(total / count))


def calib_stats(model, params, data, n_samples: int = 16,
                biased: bool = False, seed_offset: int = 10_000_000):
    batches = calibration_batches(data, n_samples, SEQ, biased=biased,
                                  seed_offset=seed_offset)
    batches = [{k: jnp.asarray(v) for k, v in b.items()} for b in batches]
    return run_calibration(model.forward, params, batches)
