"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (Python
emulation — wall-time is meaningless for TPU), so the timed comparison
here is the *reference path* (what CPU serving would use) plus an
HBM-traffic model of the kernel's advantage on the TPU target:
the fused quant-error kernel reads W once per candidate instead of
materializing a fake-quantized copy (2x traffic + extra write), and the
W4A16 matmul streams 4-bit weights (4.4x fewer weight bytes than bf16).

``bench_decode`` additionally writes a machine-readable flash-decode
baseline to ``BENCH_decode.json`` at the repo root (dense vs int8-KV vs
paged, cache_len ≪ max_len): the jnp ref always pays for ``max_len``
positions, the split-KV kernel's per-split ``pl.when`` guard + clamped
index maps bound compute and cache fetches by ``ceil(cache_len / bs)``
live splits — ``work_fraction`` is that deterministic ratio.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.core import QuantSpec, quantize_groupwise
from repro.core.methods import DEFAULT_ALPHA_GRID, candidate_scale
from repro.kernels import ref
from repro.kernels.ops import quant_error_batch


def _time(fn, *args, iters=10):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6  # us


def run(emit):
    k, n, m = 2048, 2048, 256
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    w = jax.random.normal(ks[0], (k, n))
    x = jax.random.normal(ks[1], (m, k), jnp.bfloat16)
    spec = QuantSpec(bits=4, group_size=128)
    qt = quantize_groupwise(w, spec, pack=True)

    mm_ref = jax.jit(lambda xx: ref.quant_matmul_ref(xx, qt))
    us = _time(mm_ref, x)
    emit("kernel/quant_matmul_ref_cpu", us, f"{m}x{k}x{n}")
    # HBM traffic model on TPU target (per call, bytes)
    bf16_bytes = k * n * 2
    int4_bytes = k * n // 2 + qt.scale.size * 8
    emit("kernel/quant_matmul_weight_bytes_ratio", None,
         round(bf16_bytes / int4_bytes, 2))

    a_stat = jnp.abs(jax.random.normal(ks[2], (k,))) + 0.1
    scales = jnp.stack([candidate_scale(a_stat, a)
                        for a in DEFAULT_ALPHA_GRID])
    msq = a_stat ** 2
    qe = jax.jit(lambda: quant_error_batch(w, scales, msq, spec))
    us = _time(lambda: qe())
    emit("kernel/quant_error_batch_cpu", us, f"{len(DEFAULT_ALPHA_GRID)}cand")
    naive_traffic = len(DEFAULT_ALPHA_GRID) * (3 * k * n * 4)
    fused_traffic = len(DEFAULT_ALPHA_GRID) * (k * n * 4)
    emit("kernel/quant_error_traffic_ratio", None,
         round(naive_traffic / fused_traffic, 2))

    bench_decode(emit)


def bench_decode(emit, out_path=None):
    """Flash-decode vs jnp-ref baseline -> BENCH_decode.json.

    For each variant (dense fp, int8-KV, paged) at cache_len ≪ max_len:
      * ``ref_us`` — the jitted jnp oracle, which gathers/upcasts and
        scores all ``max_len`` positions no matter how short the slot is
        (its time is ~flat across cache_len),
      * ``kernel_interpret_us`` — the split-KV kernel under the Pallas
        interpreter (CPU emulation: *not* TPU wall-time, recorded for
        trend only),
      * ``live_splits / total_splits`` and ``work_fraction`` — the
        deterministic work bound: every split past ``cache_len`` skips
        its MXU work under ``pl.when`` and its index_map clamps to the
        last live block (no re-fetch), so kernel compute and cache
        traffic scale with ``cache_len`` while the ref's scale with
        ``max_len``.
    """
    from repro.kernels.flash_decode import (flash_decode_paged_pallas,
                                            flash_decode_pallas,
                                            flash_decode_q8_pallas)
    from repro.models.common import quantize_kv

    b, h, kh, hd = 4, 8, 2, 64
    max_len, bs, ps = 1024, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k = jax.random.normal(ks[1], (b, kh, max_len, hd))
    v = jax.random.normal(ks[2], (b, kh, max_len, hd))
    kq, kqs = quantize_kv(k.transpose(0, 2, 1, 3))
    vq, vqs = quantize_kv(v.transpose(0, 2, 1, 3))
    kq, kqs = kq.transpose(0, 2, 1, 3), kqs.transpose(0, 2, 1, 3)
    vq, vqs = vq.transpose(0, 2, 1, 3), vqs.transpose(0, 2, 1, 3)
    # paged store: identity-ish table (page j of slot b -> 1 + b*NP + j),
    # page 0 is the pinned trash page
    n_per = max_len // ps
    store_k = k.reshape(b, kh, n_per, ps, hd).transpose(0, 2, 1, 3, 4) \
               .reshape(b * n_per, kh, ps, hd)
    store_v = v.reshape(b, kh, n_per, ps, hd).transpose(0, 2, 1, 3, 4) \
               .reshape(b * n_per, kh, ps, hd)
    trash = jnp.zeros((1, kh, ps, hd), store_k.dtype)
    store_k = jnp.concatenate([trash, store_k])
    store_v = jnp.concatenate([trash, store_v])
    table = 1 + jnp.arange(b * n_per, dtype=jnp.int32).reshape(b, n_per)

    kv_bytes = {
        "dense": 2 * b * kh * max_len * hd * 4,
        "q8": 2 * b * kh * max_len * (hd + 4),
        "paged": 2 * b * kh * max_len * hd * 4,
    }
    cases = {
        "dense": (
            jax.jit(lambda L: ref.decode_attention_ref(
                q, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), L)),
            lambda L: flash_decode_pallas(q, k, v, L, bs=bs,
                                          interpret=True),
            bs),
        "q8": (
            jax.jit(lambda L: ref.decode_attention_q8_ref(
                q, kq.transpose(0, 2, 1, 3), kqs.transpose(0, 2, 1, 3),
                vq.transpose(0, 2, 1, 3), vqs.transpose(0, 2, 1, 3), L)),
            lambda L: flash_decode_q8_pallas(q, kq, kqs, vq, vqs, L,
                                             bs=bs, interpret=True),
            bs),
        "paged": (
            jax.jit(lambda L: ref.paged_decode_attention_ref(
                q, store_k, store_v, table, L)),
            lambda L: flash_decode_paged_pallas(q, store_k, store_v,
                                                table, L, interpret=True),
            ps),
    }
    rows = []
    for cache_len in (64, 256, 1024):
        lens = jnp.full((b,), cache_len, jnp.int32)
        for variant, (ref_fn, kern_fn, block) in cases.items():
            ref_us = _time(ref_fn, lens, iters=5)
            kern_us = _time(kern_fn, lens, iters=2)
            live = -(-cache_len // block)
            total = -(-max_len // block)
            frac = live / total
            rows.append({
                "variant": variant, "cache_len": cache_len,
                "max_len": max_len, "batch": b, "kv_heads": kh,
                "q_heads": h, "head_dim": hd, "block": block,
                "ref_us": round(ref_us, 1),
                "kernel_interpret_us": round(kern_us, 1),
                "live_splits": live, "total_splits": total,
                "work_fraction": round(frac, 4),
                "kv_bytes_ref": kv_bytes[variant],
                "kv_bytes_kernel": int(kv_bytes[variant] * frac),
            })
            emit(f"kernel/flash_decode_{variant}_ref_us_len{cache_len}",
                 ref_us, f"S={max_len}")
            emit(f"kernel/flash_decode_{variant}_work_fraction_"
                 f"len{cache_len}", None, round(frac, 4))

    path = pathlib.Path(out_path) if out_path else \
        pathlib.Path(__file__).resolve().parent.parent / "BENCH_decode.json"
    path.write_text(json.dumps({
        "bench": "flash_decode_vs_jnp_ref",
        "note": ("kernel_interpret_us is the Pallas CPU interpreter, not "
                 "TPU wall-time; work_fraction = live_splits/total_splits "
                 "is the deterministic compute+fetch bound of the "
                 "length-aware kernel (ref always pays max_len)"),
        "rows": rows}, indent=1) + "\n")
    return rows


if __name__ == "__main__":
    from .run import emit
    run(emit)
