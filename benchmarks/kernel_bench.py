"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (Python
emulation — wall-time is meaningless for TPU), so the timed comparison
here is the *reference path* (what CPU serving would use) plus an
HBM-traffic model of the kernel's advantage on the TPU target:
the fused quant-error kernel reads W once per candidate instead of
materializing a fake-quantized copy (2x traffic + extra write), and the
W4A16 matmul streams 4-bit weights (4.4x fewer weight bytes than bf16).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import QuantSpec, quantize_groupwise
from repro.core.methods import DEFAULT_ALPHA_GRID, candidate_scale
from repro.kernels import ref
from repro.kernels.ops import quant_error_batch


def _time(fn, *args, iters=10):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6  # us


def run(emit):
    k, n, m = 2048, 2048, 256
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    w = jax.random.normal(ks[0], (k, n))
    x = jax.random.normal(ks[1], (m, k), jnp.bfloat16)
    spec = QuantSpec(bits=4, group_size=128)
    qt = quantize_groupwise(w, spec, pack=True)

    mm_ref = jax.jit(lambda xx: ref.quant_matmul_ref(xx, qt))
    us = _time(mm_ref, x)
    emit("kernel/quant_matmul_ref_cpu", us, f"{m}x{k}x{n}")
    # HBM traffic model on TPU target (per call, bytes)
    bf16_bytes = k * n * 2
    int4_bytes = k * n // 2 + qt.scale.size * 8
    emit("kernel/quant_matmul_weight_bytes_ratio", None,
         round(bf16_bytes / int4_bytes, 2))

    a_stat = jnp.abs(jax.random.normal(ks[2], (k,))) + 0.1
    scales = jnp.stack([candidate_scale(a_stat, a)
                        for a in DEFAULT_ALPHA_GRID])
    msq = a_stat ** 2
    qe = jax.jit(lambda: quant_error_batch(w, scales, msq, spec))
    us = _time(lambda: qe())
    emit("kernel/quant_error_batch_cpu", us, f"{len(DEFAULT_ALPHA_GRID)}cand")
    naive_traffic = len(DEFAULT_ALPHA_GRID) * (3 * k * n * 4)
    fused_traffic = len(DEFAULT_ALPHA_GRID) * (k * n * 4)
    emit("kernel/quant_error_traffic_ratio", None,
         round(naive_traffic / fused_traffic, 2))
