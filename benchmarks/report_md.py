"""Emit the EXPERIMENTS.md dry-run + roofline tables from the records.

    PYTHONPATH=src python -m benchmarks.report_md [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCHS, SHAPE_CELLS

from .roofline import (REPORT_DIR, full_table, load_records, model_flops,
                       param_count)


def dryrun_table(mesh: str) -> str:
    rows = ["| arch | cell | status | compile_s | args GiB/dev | temp GiB/dev "
            "| HLO GFLOP/dev | coll MB/dev |",
            "|---|---|---|---|---|---|---|---|"]
    recs = load_records(mesh)
    skipped = []
    for (arch, cell), r in sorted(recs.items()):
        if r["status"] == "skipped":
            skipped.append(f"{arch} × {cell}")
            continue
        mem = r.get("memory", {})
        rows.append(
            f"| {arch} | {cell} | {r['status']} | {r.get('compile_s','-')} | "
            f"{(mem.get('argument_bytes') or 0)/2**30:.2f} | "
            f"{(mem.get('temp_bytes') or 0)/2**30:.2f} | "
            f"{r.get('cost',{}).get('flops',0)/1e9:.0f} | "
            f"{r.get('collectives',{}).get('total',0)/2**20:.0f} |")
    out = "\n".join(rows)
    if skipped:
        out += ("\n\nSkipped-by-design (long_500k on full-attention archs, "
                "DESIGN.md §6): " + ", ".join(skipped))
    return out


def roofline_table(mesh: str = "16x16") -> str:
    rows = ["| arch | cell | compute_ms | memory_ms | collective_ms | "
            "dominant | MODEL/HLO | roofline frac |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(full_table(mesh), key=lambda r: (r["arch"], r["cell"])):
        rows.append(
            f"| {r['arch']} | {r['cell']} | {r['compute_s']*1e3:.1f} | "
            f"{r['memory_s']*1e3:.1f} | {r['collective_s']*1e3:.1f} | "
            f"{r['dominant']} | {r['useful_ratio']:.3f} | "
            f"{r['roofline_frac']:.4f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    print("## Dry-run —", args.mesh)
    print(dryrun_table(args.mesh))
    print()
    if args.mesh == "16x16":
        print("## Roofline —", args.mesh)
        print(roofline_table(args.mesh))


if __name__ == "__main__":
    main()
