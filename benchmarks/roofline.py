"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch × shape) on the single-pod 16x16 mesh:
  compute term    = HLO_FLOPs / (chips × 197e12)
  memory term     = HLO_bytes / (chips × 819e9)
  collective term = collective_bytes / (chips × 50e9)

HLO numbers come from the dry-run's while-loop-corrected cost extraction
(cost-mode unrolled L1/L2 extrapolation — see launch/dryrun.py).  The
numbers are *per device* (the compiled module is the per-device SPMD
program), so terms are per-chip seconds directly.

Analytic add-on (documented): sequential time-scan bodies (hymba's mamba
scan, xlstm's sLSTM layers) are counted once by XLA regardless of T; we
add their analytic FLOPs (elementwise-dominated, small next to matmuls).

MODEL_FLOPS: 6·N·D (train, dense), 6·N_active·D (train, MoE),
2·N(_active)·tokens for serving steps; the ratio MODEL/HLO flags
remat/dispatch/dequant overheads.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCHS, SHAPE_CELLS

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                          "dryrun")
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = {"16x16": 256, "2x16x16": 512}


def param_count(cfg, active_only: bool = False) -> float:
    """Analytic parameter count (embeddings included once)."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    hd = cfg.head_dim_
    attn = d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
    if cfg.family == "ssm":
        d_in = cfg.ssm_expand * d
        per_layer = d * 2 * d_in + 3 * d_in * d_in + d_in * d  # mLSTM
    else:
        per_layer = attn
        if cfg.d_ff:
            per_layer += 3 * d * cfg.d_ff if cfg.family != "audio" \
                else 2 * d * cfg.d_ff
    if cfg.family == "moe":
        routed = 3 * d * cfg.d_ff
        n_act = cfg.experts_per_token
        experts_total = cfg.n_experts * routed
        experts_active = n_act * routed
        shared = 3 * d * cfg.shared_expert_ff if cfg.n_shared_experts else 0
        per_layer_total = attn + experts_total + shared
        per_layer_active = attn + experts_active + shared
        per_layer = per_layer_active if active_only else per_layer_total
    if cfg.family == "hybrid":
        d_in = cfg.ssm_expand * d
        per_layer += 2 * d * d_in + d_in * d  # mamba in/out proj
    total = L * per_layer + 2 * V * d
    if cfg.family == "audio":
        total += cfg.n_encoder_layers * (attn + 2 * d * cfg.d_ff)
    return float(total)


def model_flops(cfg, cell) -> float:
    n = param_count(cfg, active_only=(cfg.family == "moe"))
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    tokens = cell.global_batch  # one token per sequence
    return 2.0 * n * tokens


def ssm_scan_addon_flops(cfg, cell, chips: int) -> float:
    """Analytic per-device FLOPs for sequential time-scans XLA counts once."""
    if cell.kind == "decode":
        return 0.0
    tokens = cell.global_batch * cell.seq_len
    add = 0.0
    if cfg.family == "hybrid":  # mamba: ~6 flops per (t, d_inner, state)
        add += 6.0 * tokens * cfg.ssm_expand * cfg.d_model * cfg.ssm_state \
            * cfg.n_layers
    if cfg.family == "ssm" and cfg.slstm_every:
        n_s = cfg.n_layers // cfg.slstm_every
        hd = cfg.d_model // cfg.n_heads
        add += 2.0 * tokens * 4 * cfg.n_heads * hd * hd * n_s
    return add / chips


def load_records(mesh: str = "16x16"):
    out = {}
    for f in glob.glob(os.path.join(REPORT_DIR, f"*__{mesh}.json")):
        r = json.load(open(f))
        out[(r["arch"], r["cell"])] = r
    return out


def kernel_adjustments(cfg, cell, chips: int) -> dict:
    """Analytic per-device HBM-byte savings when the Pallas kernels replace
    the pure-jnp paths (the CPU dry-run lowers the jnp reference paths; on
    the TPU target the kernels are used instead):

    * flash attention (kernels/flash_attention.py): the jnp chunked path
      round-trips the (B_loc, H_loc, T, T) f32 score tensor through HBM
      (one write + one read per pass); the kernel keeps it in VMEM.
      Train counts 3 passes (fwd + remat-recompute + bwd), prefill 1.
    * W4A16 dequant matmul (kernels/quant_matmul.py): the jnp path writes
      + reads a bf16 dequantized copy of every weight per step; the kernel
      dequantizes in VMEM (serve cells only).
    """
    save = {"attn_score_bytes": 0.0, "dequant_bytes": 0.0}
    dp = 16  # data shards on the single-pod mesh
    tp = 16
    if cell.kind in ("train", "prefill") and cfg.family != "ssm":
        b_loc = max(1, cell.global_batch // dp)
        h_loc = cfg.n_heads // tp if cfg.n_heads % tp == 0 else cfg.n_heads
        t_eff = min(cell.seq_len, 2 * cfg.sliding_window)             if cfg.sliding_window else cell.seq_len
        passes = 3 if cell.kind == "train" else 1
        layers = cfg.n_layers + cfg.n_encoder_layers
        save["attn_score_bytes"] = (2 * 4.0 * b_loc * h_loc * cell.seq_len
                                    * t_eff * layers * passes)
    if cell.kind in ("prefill", "decode"):
        n = param_count(cfg, active_only=False)
        save["dequant_bytes"] = 2 * 2.0 * n / tp
    return save


def roofline_row(r, cfg, cell) -> dict:
    chips = CHIPS[r["mesh"]]
    flops = r["cost"]["flops"] + ssm_scan_addon_flops(cfg, cell, chips)
    byts = r["cost"]["bytes_accessed"]
    coll = r["collectives"].get("total", 0)
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    mf = model_flops(cfg, cell) / chips
    bound = max(t_c, t_m, t_x)
    adj = kernel_adjustments(cfg, cell, chips)
    kbytes = max(byts - adj["attn_score_bytes"] - adj["dequant_bytes"],
                 byts * 0.02)
    t_mk = kbytes / HBM_BW
    kbound = max(t_c, t_mk, t_x)
    return {
        "arch": r["arch"], "cell": r["cell"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom[0],
        "model_flops_per_chip": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        # fraction of roofline: useful work at peak vs the bound term
        "roofline_frac": (mf / PEAK_FLOPS) / bound if bound else 0.0,
        # with the Pallas kernels substituted for the jnp reference paths
        "kernel_memory_s": t_mk,
        "kernel_frac": (mf / PEAK_FLOPS) / kbound if kbound else 0.0,
        # decode is weight-bandwidth-bound by nature: fraction of the
        # *serving bandwidth roofline* (ideal = stream the int4 weights
        # from HBM once per step) is the meaningful score there
        "bw_frac": ((param_count(cfg) * 0.5 / 16 / HBM_BW) / kbound
                    if cell.kind == "decode" and kbound else None),
        "hbm_gb_per_device": (r["memory"]["argument_bytes"] or 0) / 2 ** 30,
    }


def full_table(mesh: str = "16x16"):
    rows = []
    recs = load_records(mesh)
    for (arch, cell_name), r in sorted(recs.items()):
        if r["status"] != "ok":
            continue
        rows.append(roofline_row(r, ARCHS[arch], SHAPE_CELLS[cell_name]))
    return rows


def run(emit):
    for row in full_table():
        tag = f"roofline/{row['arch']}/{row['cell']}"
        emit(tag + "/compute_ms", None, row["compute_s"] * 1e3)
        emit(tag + "/memory_ms", None, row["memory_s"] * 1e3)
        emit(tag + "/collective_ms", None, row["collective_s"] * 1e3)
        emit(tag + "/dominant", None, row["dominant"])
        emit(tag + "/roofline_frac", None, round(row["roofline_frac"], 4))
