"""Benchmark harness — one section per paper table + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call empty where the
measurement is a quality metric rather than a timing).
"""
from __future__ import annotations

import sys


def emit(name, us_per_call, derived):
    us = "" if us_per_call is None else f"{us_per_call:.1f}"
    print(f"{name},{us},{derived}", flush=True)


def main() -> None:
    from . import (kernel_bench, roofline, serve_bench, table4_hparams,
                   tables, traffic_bench)

    print("name,us_per_call,derived")
    tables.table1(emit)
    tables.table2(emit)
    tables.table3(emit)
    table4_hparams.run(emit)
    kernel_bench.run(emit)
    roofline.run(emit)
    serve_bench.run(emit)
    traffic_bench.run(emit)


if __name__ == "__main__":
    main()
