"""Serving-throughput benchmark: bucketed batched engine vs the
pre-refactor per-request-retrace baseline.

The baseline reproduces the old engine's hot-path behavior exactly:
per-request exact-length prefill (one XLA compile per distinct prompt
length), host-side tree_map cache splice on admission, and a full
vocab-row device->host round-trip with NumPy sampling per decoded token.
The rebuilt engine pads admission batches to a fixed bucket grid
(compile count bounded by the bucket count), merges prefilled rows into
the live cache with one jitted op, and samples on-device.

Each run appends a row to the BENCH trajectory at
``reports/serve_bench.csv`` so tok/s progress is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.serve_bench --tiny --requests 16
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports")


# ---------------------------------------------------------------------------
# Pre-refactor reference engine (kept verbatim-in-spirit for the baseline)
# ---------------------------------------------------------------------------

class LegacyEngine:
    """The old serve loop: per-length prefill retrace, host splice,
    host sampling of full logits rows."""

    def __init__(self, model, params, *, n_slots=4, max_len=128):
        self.model, self.params = model, params
        self.n_slots, self.max_len = n_slots, max_len
        self.cfg = model.cfg
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def serve(self, requests):
        queue = list(requests)
        results = {}
        cache = self.model.init_cache(self.n_slots, self.max_len)
        slot_req = [None] * self.n_slots
        slot_last = np.zeros((self.n_slots, 1), np.int32)
        slot_left = np.zeros(self.n_slots, np.int32)

        def splice(batched, single, slot):
            def leaf(b, s):
                for ax in range(b.ndim):
                    if ax < s.ndim and b.shape[ax] != s.shape[ax]:
                        idx = [slice(None)] * b.ndim
                        idx[ax] = slice(slot, slot + 1)
                        return b.at[tuple(idx)].set(s.astype(b.dtype))
                return s
            new = jax.tree_util.tree_map(leaf, batched, single)
            for k in batched:
                batched[k] = new[k]

        def fill_slots():
            for s in range(self.n_slots):
                if slot_req[s] is None and queue:
                    req = queue.pop(0)
                    req.out_tokens = []
                    c1 = self.model.init_cache(1, self.max_len)
                    tok = jnp.asarray(np.asarray(req.prompt, np.int32))[None]
                    logits, c1 = self._prefill(self.params, tok, c1)
                    splice(cache, c1, s)
                    nxt = int(np.argmax(
                        np.asarray(logits[0, 0, :self.cfg.vocab_size])))
                    req.out_tokens.append(nxt)
                    slot_req[s] = req
                    slot_last[s, 0] = nxt
                    slot_left[s] = req.max_new_tokens - 1

        fill_slots()
        while any(r is not None for r in slot_req):
            logits, new_cache = self._decode(self.params, cache,
                                             jnp.asarray(slot_last))
            for k in cache:
                cache[k] = new_cache[k]
            logits_np = np.asarray(logits[:, 0, :self.cfg.vocab_size])
            for s in range(self.n_slots):
                req = slot_req[s]
                if req is None:
                    continue
                nxt = int(np.argmax(logits_np[s]))
                req.out_tokens.append(nxt)
                slot_last[s, 0] = nxt
                slot_left[s] -= 1
                if slot_left[s] <= 0:
                    results[req.rid] = np.asarray(req.out_tokens, np.int32)
                    slot_req[s] = None
            fill_slots()
        return results


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------

def _requests(cfg, n, new_tokens, seed=0):
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(4, 48))),
                    max_new_tokens=new_tokens)
            for i in range(n)]


def _shared_prefix_requests(cfg, n, new_tokens, prefix_len=32, seed=0):
    """Mixed-length requests sharing one system-prompt prefix — the
    paged bench workload (prefix covers whole pages, tails vary)."""
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, cfg.vocab_size, size=prefix_len)
    return [Request(rid=i,
                    prompt=np.concatenate(
                        [sys_prompt,
                         rng.integers(0, cfg.vocab_size,
                                      size=int(rng.integers(4, 40)))]),
                    max_new_tokens=new_tokens)
            for i in range(n)]


def _fresh_request(r):
    """Fresh Request copy (engines mutate out_tokens in place)."""
    from repro.serve import Request
    return Request(rid=r.rid, prompt=r.prompt,
                   max_new_tokens=r.max_new_tokens)


def _quantized_setup(full=False):
    """Target setup: FAQ int4-packed weights.  ``full=True`` also
    returns the fp params and the calibration stats (the self-int8
    draft reuses the stats when re-quantizing the serving weights,
    DESIGN.md §12)."""
    from repro.configs import ARCHS
    from repro.core import QuantSpec, quantize_model, run_calibration
    from repro.models.registry import build_model

    cfg = ARCHS["llama3-8b"].tiny()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(i), (2, 32),
                                           0, cfg.vocab_size)}
             for i in range(2)]
    stats = run_calibration(model.forward, params, calib)
    qp, _ = quantize_model(params, model.quant_site_map(), stats,
                           method="faq", spec=QuantSpec(bits=4, group_size=64),
                           mode="packed")
    if full:
        return cfg, model, qp, params, stats
    return cfg, model, qp


CSV_HEADER = ("timestamp,requests,new_tokens,n_slots,max_len,"
              "legacy_tok_s,bucketed_tok_s,speedup,prefill_traces,"
              "paged_tok_s,dense_cache_bytes,paged_peak_bytes,"
              "spec_tok_s,spec_speedup,accept_rate,tokens_per_step,"
              "mesh,sharded_tok_s,per_device_cache_bytes,"
              "traffic_process,traffic_rate,ttft_p50_ms,ttft_p95_ms,"
              "ttft_p99_ms,queue_delay_p95_ms,per_token_p50_ms")

# Steady-state measurement policy shared by every row (recorded in
# BENCH_serve.json so rows stay comparable across PRs): each engine
# first serves one same-distribution warmup workload (seed 1), putting
# XLA compiles and allocator warmup outside the timed region.  The
# legacy engine still retraces novel prompt lengths *inside* the timed
# run — per-length retrace is its steady-state behavior, not a
# cold-start artifact — while the bucketed grid is fully compiled.
WARMUP_POLICY = {
    "policy": "warmed-steady-state",
    "detail": "each engine serves one same-distribution workload "
              "(seed=1) before timing; compiles excluded from timed "
              "rows, counters reported as timed-run deltas",
    "warm_seed": 1,
    "timed_seed": 0,
}


def _append_row(values: dict):
    """Append one row of the BENCH trajectory; columns absent from
    ``values`` stay empty.  A file written before the paged columns
    existed is migrated in place (old rows padded with empty fields)."""
    os.makedirs(REPORT_DIR, exist_ok=True)
    path = os.path.join(REPORT_DIR, "serve_bench.csv")
    cols = CSV_HEADER.split(",")
    if os.path.exists(path):
        with open(path) as f:
            lines = f.read().splitlines()
        if lines and lines[0] != CSV_HEADER:
            old_n = len(lines[0].split(","))
            pad = "," * (len(cols) - old_n)
            lines = [CSV_HEADER] + [ln + pad for ln in lines[1:] if ln]
            with open(path, "w") as f:
                f.write("\n".join(lines) + "\n")
    else:
        with open(path, "w") as f:
            f.write(CSV_HEADER + "\n")
    with open(path, "a") as f:
        f.write(",".join(str(values.get(c, "")) for c in cols) + "\n")


def bench(emit=print, *, requests=16, new_tokens=16, n_slots=4, max_len=128,
          record=True):
    """Returns (legacy tok/s, bucketed tok/s, speedup).

    Both rows are measured warmed (``WARMUP_POLICY``): the legacy
    engine's timed run still pays per-novel-length retraces, because
    that IS its steady state; the bucketed grid is fully compiled."""
    from repro.serve import ServeEngine

    cfg, model, qp = _quantized_setup()
    warm = _requests(cfg, 2 * n_slots, new_tokens, seed=1)

    legacy = LegacyEngine(model, qp, n_slots=n_slots, max_len=max_len)
    legacy.serve([_fresh_request(r) for r in warm])
    t0 = time.time()
    res_l = legacy.serve(_requests(cfg, requests, new_tokens))
    dt_l = time.time() - t0
    tok_l = sum(len(v) for v in res_l.values())

    eng = ServeEngine(model, qp, n_slots=n_slots, max_len=max_len)
    eng.serve([_fresh_request(r) for r in warm])
    m0 = eng.metrics()
    t0 = time.time()
    res_b = eng.serve(_requests(cfg, requests, new_tokens))
    dt_b = time.time() - t0
    tok_b = sum(len(v) for v in res_b.values())

    for rid in res_l:  # both engines are greedy: outputs must agree
        assert np.array_equal(res_l[rid], res_b[rid]), f"rid {rid} diverged"

    tps_l, tps_b = tok_l / dt_l, tok_b / dt_b
    speedup = tps_b / tps_l
    m = eng.metrics()
    emit(f"serve/legacy_tok_s,,{tps_l:.2f}")
    emit(f"serve/bucketed_tok_s,,{tps_b:.2f}")
    emit(f"serve/speedup,,{speedup:.2f}")
    emit(f"serve/prefill_traces,,{m['prefill_traces']}")
    emit(f"serve/decode_steps,,{m['decode_steps'] - m0['decode_steps']}")

    if record:
        _append_row(dict(timestamp=int(time.time()), requests=requests,
                         new_tokens=new_tokens, n_slots=n_slots,
                         max_len=max_len, legacy_tok_s=f"{tps_l:.2f}",
                         bucketed_tok_s=f"{tps_b:.2f}",
                         speedup=f"{speedup:.2f}",
                         prefill_traces=m["prefill_traces"]))
    return tps_l, tps_b, speedup


def bench_paged(emit=print, *, requests=16, new_tokens=16, n_slots=4,
                max_len=128, page_size=16, record=True):
    """Paged vs dense cache at mixed-length requests sharing a system
    prompt: tok/s plus peak cache bytes.  The dense engine pins
    ``n_slots * max_len`` positions for the whole run; the paged engine
    pins only the pages in use, and requests after the first map their
    prompt-prefix pages to the blocks the first request published.

    ``paged_peak_bytes`` is *pinned*-page accounting — the provisioning
    signal (``n_pages`` sized to peak + slack).  The run itself uses the
    deadlock-free default pool, whose device allocation
    (``alloc_cache_bytes``, also emitted) slightly exceeds the dense
    cache; the memory win is realized by provisioning, not by default.

    Returns (dense tok/s, paged tok/s, dense bytes, paged peak bytes).
    """
    from repro.serve import ServeEngine

    cfg, model, qp = _quantized_setup()
    warm = _shared_prefix_requests(cfg, 2 * n_slots, new_tokens, seed=1)

    dense = ServeEngine(model, qp, n_slots=n_slots, max_len=max_len)
    dense.serve([_fresh_request(r) for r in warm])
    t0 = time.time()
    res_d = dense.serve(_shared_prefix_requests(cfg, requests, new_tokens))
    dt_d = time.time() - t0
    tok_d = sum(len(v) for v in res_d.values())
    dense_bytes = sum(
        leaf.size * leaf.dtype.itemsize
        for leaf in jax.tree_util.tree_leaves(
            jax.eval_shape(lambda: model.init_cache(n_slots, max_len))))

    paged = ServeEngine(model, qp, n_slots=n_slots, max_len=max_len,
                        paged=True, page_size=page_size)
    # the warm workload uses a *different* system prompt (seed 1), so
    # the timed run's prefix hits are all earned inside the timed run;
    # the warm prefix stays in the index — exactly what a long-lived
    # server's pinned-page peak looks like
    paged.serve([_fresh_request(r) for r in warm])
    m0 = paged.metrics()
    t0 = time.time()
    res_p = paged.serve(_shared_prefix_requests(cfg, requests, new_tokens))
    dt_p = time.time() - t0
    tok_p = sum(len(v) for v in res_p.values())

    for rid in res_d:  # both engines are greedy: outputs must agree
        assert np.array_equal(res_d[rid], res_p[rid]), f"rid {rid} diverged"

    m = paged.metrics()
    paged_bytes = m["peak_cache_bytes"]
    tps_d, tps_p = tok_d / dt_d, tok_p / dt_p
    emit(f"serve/dense_tok_s,,{tps_d:.2f}")
    emit(f"serve/paged_tok_s,,{tps_p:.2f}")
    emit(f"serve/dense_cache_bytes,,{dense_bytes}")
    emit(f"serve/paged_peak_bytes,,{paged_bytes}")
    emit(f"serve/paged_alloc_bytes,,{m['alloc_cache_bytes']}")
    emit(f"serve/prefix_hits,,{m['prefix_hits'] - m0['prefix_hits']}")
    emit(f"serve/prefix_hit_tokens,,"
         f"{m['prefix_hit_tokens'] - m0['prefix_hit_tokens']}")

    if record:
        _append_row(dict(timestamp=int(time.time()), requests=requests,
                         new_tokens=new_tokens, n_slots=n_slots,
                         max_len=max_len, bucketed_tok_s=f"{tps_d:.2f}",
                         paged_tok_s=f"{tps_p:.2f}",
                         dense_cache_bytes=dense_bytes,
                         paged_peak_bytes=paged_bytes))
    return tps_d, tps_p, dense_bytes, paged_bytes


def bench_spec(emit=print, *, requests=16, new_tokens=32, n_slots=4,
               max_len=128, k=7, record=True):
    """Speculative vs plain decode on the int4-packed target with the
    FAQ int8 self-draft (DESIGN.md §12).

    Greedy outputs are asserted token-for-token identical — the speedup
    is pure latency: the self-draft's dense int8 reconstruction decodes
    cheaply while the target's packed-int4 verify scores K+1 positions
    for roughly the cost of one (the dequant dominates and is
    length-independent), so accepted bursts amortize the expensive
    target step.

    Returns (plain tok/s, spec tok/s, accept_rate, tokens_per_step).
    """
    from repro.serve import ServeEngine, SpecConfig, self_int8_draft

    cfg, model, qp, fp_params, stats = _quantized_setup(full=True)

    # the draft re-quantizes the *serving* weights at int8 — it tracks
    # the int4 target (not the fp model it came from), which is what the
    # acceptance rate pays for
    draft = self_int8_draft(model, qp, stats)
    plain = ServeEngine(model, qp, n_slots=n_slots, max_len=max_len)
    eng = ServeEngine(model, qp, n_slots=n_slots, max_len=max_len,
                      spec=SpecConfig(k=k, draft=draft))
    # steady-state comparison: warm both engines over the same bucket /
    # cycle shapes first, so the measurement is decode throughput rather
    # than XLA compile amortization (the legacy-vs-bucketed bench above
    # owns the compile-count story)
    warm = _requests(cfg, 2 * n_slots, new_tokens, seed=1)
    plain.serve([_fresh_request(r) for r in warm])
    eng.serve([_fresh_request(r) for r in warm])
    m0 = eng.metrics()

    t0 = time.time()
    res_n = plain.serve(_requests(cfg, requests, new_tokens))
    dt_n = time.time() - t0
    tok_n = sum(len(v) for v in res_n.values())

    t0 = time.time()
    res_s = eng.serve(_requests(cfg, requests, new_tokens))
    dt_s = time.time() - t0
    tok_s = sum(len(v) for v in res_s.values())

    for rid in res_n:  # greedy: speculative output must be identical
        assert np.array_equal(res_n[rid], res_s[rid]), f"rid {rid} diverged"

    tps_n, tps_s = tok_n / dt_n, tok_s / dt_s
    m = eng.metrics()
    # timed-run deltas: engine counters are lifetime-cumulative and the
    # warm workload must not dilute the measured acceptance
    d = lambda key: m[key] - m0[key]
    accept = d("accepted_tokens") / max(d("proposed_tokens"), 1)
    tpstep = d("tokens_generated") / max(d("decode_steps"), 1)
    emit(f"serve/nonspec_tok_s,,{tps_n:.2f}")
    emit(f"serve/spec_tok_s,,{tps_s:.2f}")
    emit(f"serve/spec_speedup,,{tps_s / tps_n:.2f}")
    emit(f"serve/accept_rate,,{accept:.3f}")
    emit(f"serve/tokens_per_step,,{tpstep:.2f}")
    emit(f"serve/draft_share,,{m['draft_share']:.3f}")

    if record:
        _append_row(dict(timestamp=int(time.time()), requests=requests,
                         new_tokens=new_tokens, n_slots=n_slots,
                         max_len=max_len, bucketed_tok_s=f"{tps_n:.2f}",
                         spec_tok_s=f"{tps_s:.2f}",
                         spec_speedup=f"{tps_s / tps_n:.2f}",
                         accept_rate=f"{accept:.3f}",
                         tokens_per_step=f"{tpstep:.2f}"))
    return tps_n, tps_s, accept, tpstep


def bench_obs_overhead(emit=print, *, requests=16, new_tokens=16,
                       n_slots=4, max_len=128):
    """Tracing-overhead guard (DESIGN.md §17): identical warmed
    workloads on a plain engine and on one with a live span tracer +
    registry histograms.  The observability layer is host-side
    bookkeeping only — no extra device transfers — so the contract is
    <= 5% tok/s cost; CI asserts it via the recorded ``overhead_frac``.

    Returns (plain tok/s, traced tok/s, overhead fraction)."""
    from repro.obs import Tracer
    from repro.serve import ServeEngine

    cfg, model, qp = _quantized_setup()
    warm = _requests(cfg, 2 * n_slots, new_tokens, seed=1)

    def timed(tracer):
        eng = ServeEngine(model, qp, n_slots=n_slots, max_len=max_len,
                          tracer=tracer)
        eng.serve([_fresh_request(r) for r in warm])
        # Best-of-3: the workload is short enough that a single pass is
        # dominated by scheduler-noise jitter, which would make the <=5%
        # contract flaky; the minimum time is the honest cost estimate.
        best, res = 0.0, None
        for _ in range(3):
            t0 = time.time()
            res = eng.serve(_requests(cfg, requests, new_tokens))
            dt = time.time() - t0
            best = max(best, sum(len(v) for v in res.values()) / dt)
        return best, res

    tps_plain, res_plain = timed(None)
    tps_traced, res_traced = timed(Tracer(capacity=65536))
    for rid in res_plain:  # tracing must not perturb outputs
        assert np.array_equal(res_plain[rid], res_traced[rid]), \
            f"rid {rid} diverged under tracing"
    overhead = max(0.0, 1.0 - tps_traced / tps_plain)
    emit(f"serve/obs_plain_tok_s,,{tps_plain:.2f}")
    emit(f"serve/obs_traced_tok_s,,{tps_traced:.2f}")
    emit(f"serve/obs_overhead_frac,,{overhead:.4f}")
    return tps_plain, tps_traced, overhead


# Runs in a subprocess because the virtual device count must be set
# before jax initializes; workload knobs arrive via BENCH_* env vars.
_SHARDED_CODE = """
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import ARCHS
from repro.core import QuantSpec, quantize_model, run_calibration
from repro.launch.mesh import make_local_mesh
from repro.models.registry import build_model
from repro.serve import Request, ServeEngine

data_ax, model_ax = (int(x) for x in os.environ["BENCH_MESH"].split(","))
n_req = int(os.environ["BENCH_REQUESTS"])
new_tokens = int(os.environ["BENCH_NEW_TOKENS"])
n_slots = int(os.environ["BENCH_N_SLOTS"])
max_len = int(os.environ["BENCH_MAX_LEN"])

cfg = ARCHS["llama3-8b"].tiny()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(i), (2, 32), 0,
                                       cfg.vocab_size)} for i in range(2)]
stats = run_calibration(model.forward, params, calib)
qp, _ = quantize_model(params, model.quant_site_map(), stats, method="faq",
                       spec=QuantSpec(bits=4, group_size=64), mode="packed")

mesh = None if data_ax * model_ax == 1 else make_local_mesh(data_ax, model_ax)
eng = ServeEngine(model, qp, n_slots=n_slots, max_len=max_len, mesh=mesh)

def reqs(seed):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               size=int(rng.integers(4, 32))),
                    max_new_tokens=new_tokens) for i in range(n_req)]

eng.serve(reqs(1))                    # warm: compiles out of the timing
t0 = time.time()
res = eng.serve(reqs(0))
dt = time.time() - t0
tok = sum(len(v) for v in res.values())

# per-device footprint of the placed dense cache: the largest shard any
# one device holds, summed over leaves (head-sharding should divide the
# KV leaves by the model-axis size)
cache = eng._place(model.init_cache(n_slots, max_len), eng._cache_axes)
per_dev = sum(max(s.data.nbytes for s in leaf.addressable_shards)
              for leaf in jax.tree_util.tree_leaves(cache))
total = sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(cache))
print(json.dumps({"mesh": [data_ax, model_ax], "tok_s": tok / dt,
                  "per_device_cache_bytes": int(per_dev),
                  "total_cache_bytes": int(total),
                  "outputs": {int(k): v.tolist() for k, v in res.items()}}))
"""


def bench_sharded(emit=print, *, requests=8, new_tokens=8, n_slots=4,
                  max_len=64, shapes=((1, 1), (1, 2), (1, 4)), record=True):
    """Tensor-parallel serving at several mesh shapes on 8 virtual CPU
    devices (DESIGN.md §13): tok/s and the per-device peak dense-cache
    bytes (head-sharded KV leaves shrink with the model-axis size).
    Each shape runs in its own subprocess — the device count must be
    fixed before jax initializes — and greedy outputs are asserted
    identical across shapes.  Virtual CPU tok/s measures dispatch
    overhead, not accelerator scaling; the per-device bytes column is
    the provisioning signal.

    Returns {"DxM": {"tok_s": ..., "per_device_cache_bytes": ...}}.
    """
    import json
    import subprocess
    import sys

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    results = {}
    for data_ax, model_ax in shapes:
        env = dict(os.environ, JAX_PLATFORMS="cpu",
                   XLA_FLAGS="--xla_force_host_platform_device_count=8",
                   PYTHONPATH=src + os.pathsep + os.environ.get("PYTHONPATH",
                                                                ""),
                   BENCH_MESH=f"{data_ax},{model_ax}",
                   BENCH_REQUESTS=str(requests),
                   BENCH_NEW_TOKENS=str(new_tokens),
                   BENCH_N_SLOTS=str(n_slots),
                   BENCH_MAX_LEN=str(max_len))
        out = subprocess.run([sys.executable, "-c", _SHARDED_CODE], env=env,
                             capture_output=True, text=True, timeout=900)
        if out.returncode != 0:
            raise RuntimeError(f"sharded bench {data_ax}x{model_ax} failed:"
                               f"\n{out.stderr[-2000:]}")
        r = json.loads(out.stdout.splitlines()[-1])
        key = f"{data_ax}x{model_ax}"
        emit(f"serve/sharded_{key}_tok_s,,{r['tok_s']:.2f}")
        emit(f"serve/sharded_{key}_device_cache_bytes,,"
             f"{r['per_device_cache_bytes']}")
        first = next(iter(results.values()), None)
        if first is not None:   # greedy identity across mesh shapes
            assert r["outputs"] == first["outputs"], f"{key} diverged"
        results[key] = r
        if record:
            _append_row(dict(timestamp=int(time.time()), requests=requests,
                             new_tokens=new_tokens, n_slots=n_slots,
                             max_len=max_len, mesh=key,
                             sharded_tok_s=f"{r['tok_s']:.2f}",
                             per_device_cache_bytes=r[
                                 "per_device_cache_bytes"]))
    return {k: {"tok_s": round(v["tok_s"], 2),
                "per_device_cache_bytes": v["per_device_cache_bytes"],
                "total_cache_bytes": v["total_cache_bytes"]}
            for k, v in results.items()}


def _write_json(summary: dict):
    """BENCH trajectory snapshot at the repo root (like
    BENCH_decode.json): tok/s and peak cache bytes per serving mode.
    Merge-updates top-level sections so the closed-loop benches and
    ``benchmarks.traffic_bench`` (the ``traffic`` section) can refresh
    the file independently without clobbering each other."""
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
    import json
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {}
    data.update(summary)
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")


def _bench_all(emit, *, requests=16, new_tokens=16, n_slots=4, max_len=128,
               spec_k=7, spec_new_tokens=32, record=True, write_json=True):
    """Run all three serving benches and assemble the JSON summary."""
    tps_l, tps_b, speedup = bench(emit, requests=requests,
                                  new_tokens=new_tokens, n_slots=n_slots,
                                  max_len=max_len, record=record)
    tps_d, tps_p, db, pb = bench_paged(emit, requests=requests,
                                       new_tokens=new_tokens,
                                       n_slots=n_slots, max_len=max_len,
                                       record=record)
    # the spec cell decodes longer sequences: speculative cycles
    # amortize per-step cost, so the decode-bound regime is the one a
    # deployment would run it in (prefill dilution hides the signal at
    # very short budgets)
    tps_n, tps_s, acc, tpstep = bench_spec(emit, requests=requests,
                                           new_tokens=spec_new_tokens,
                                           n_slots=n_slots, max_len=max_len,
                                           k=spec_k, record=record)
    sharded = bench_sharded(emit, record=record)
    tps_o_plain, tps_o_traced, overhead = bench_obs_overhead(
        emit, requests=requests, new_tokens=new_tokens, n_slots=n_slots,
        max_len=max_len)
    base = {"requests": requests, "new_tokens": new_tokens,
            "n_slots": n_slots, "max_len": max_len}
    summary = {
        "timestamp": int(time.time()),
        "workload": dict(base),
        "warmup": dict(WARMUP_POLICY),
        "legacy": {"tok_s": round(tps_l, 2),
                   "workload": dict(base, prompt_lens="uniform[4,48)")},
        "dense": {"tok_s": round(tps_b, 2), "peak_cache_bytes": int(db),
                  "speedup_vs_legacy": round(speedup, 2),
                  "workload": dict(base, prompt_lens="uniform[4,48)")},
        "paged": {"tok_s": round(tps_p, 2), "peak_cache_bytes": int(pb),
                  "workload": dict(base, prompt_lens="32+uniform[4,40)",
                                   shared_prefix_len=32)},
        "spec": {"tok_s": round(tps_s, 2), "peak_cache_bytes": int(db),
                 "speedup_vs_nonspec": round(tps_s / tps_n, 2),
                 "nonspec_tok_s": round(tps_n, 2), "k": spec_k,
                 "new_tokens": spec_new_tokens,
                 "draft": "self-int8", "accept_rate": round(acc, 3),
                 "tokens_per_step": round(tpstep, 2),
                 "workload": dict(base, new_tokens=spec_new_tokens,
                                  prompt_lens="uniform[4,48)")},
        "sharded": dict(sharded,
                        workload={"requests": 8, "new_tokens": 8,
                                  "n_slots": 4, "max_len": 64,
                                  "prompt_lens": "uniform[4,32)"}),
        "obs": {"tok_s_plain": round(tps_o_plain, 2),
                "tok_s_traced": round(tps_o_traced, 2),
                "overhead_frac": round(overhead, 4),
                "budget_frac": 0.05,
                "workload": dict(base, prompt_lens="uniform[4,48)")},
    }
    if write_json:
        _write_json(summary)
    return summary


def run(emit):
    """Entry point for benchmarks.run."""
    _bench_all(emit)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action=argparse.BooleanOptionalAction,
                    default=True, help="tiny config (the only offline mode)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--spec-k", type=int, default=7)
    ap.add_argument("--no-record", action="store_true",
                    help="skip the CSV trajectory and BENCH_serve.json")
    args = ap.parse_args()
    if not args.tiny:
        raise SystemExit("full-size serving bench needs accelerators; "
                         "run with --tiny")
    s = _bench_all(print, requests=args.requests,
                   new_tokens=args.new_tokens, n_slots=args.n_slots,
                   max_len=args.max_len, spec_k=args.spec_k,
                   record=not args.no_record,
                   write_json=not args.no_record)
    print(f"legacy: {s['legacy']['tok_s']:.1f} tok/s | "
          f"bucketed: {s['dense']['tok_s']:.1f} tok/s | "
          f"speedup: {s['dense']['speedup_vs_legacy']:.2f}x")
    print(f"dense: {s['dense']['tok_s']:.1f} tok/s / "
          f"{s['dense']['peak_cache_bytes']/1e6:.2f} MB cache | "
          f"paged: {s['paged']['tok_s']:.1f} tok/s / "
          f"{s['paged']['peak_cache_bytes']/1e6:.2f} MB peak pinned")
    sp = s["spec"]
    print(f"spec(k={sp['k']}, {sp['draft']}): {sp['tok_s']:.1f} tok/s vs "
          f"{sp['nonspec_tok_s']:.1f} non-spec "
          f"({sp['speedup_vs_nonspec']:.2f}x, accept {sp['accept_rate']:.2f},"
          f" {sp['tokens_per_step']:.2f} tok/step)")
    for mesh, r in s["sharded"].items():
        if mesh == "workload":
            continue
        print(f"sharded {mesh}: {r['tok_s']:.1f} tok/s, "
              f"{r['per_device_cache_bytes']/1e6:.2f} MB cache/device")
    ob = s["obs"]
    print(f"obs: {ob['tok_s_plain']:.1f} tok/s plain vs "
          f"{ob['tok_s_traced']:.1f} traced "
          f"({100 * ob['overhead_frac']:.1f}% overhead, "
          f"budget {100 * ob['budget_frac']:.0f}%)")


if __name__ == "__main__":
    main()
