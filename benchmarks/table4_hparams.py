"""Hyperparameter analysis (paper §3.1: "we performed a preliminary search
to fix the fusion factor γ=0.85 and window size=3").

Sweeps (γ, j) on the outlier-injected testbed and reports 3-bit PPL, plus
the full per-layer Eq.-8 joint search as the upper bound.  Validates that
the paper's pre-searched configuration sits on the plateau.
"""
from __future__ import annotations

from repro.core import QuantSpec, quantize_model
from repro.core.methods import full_search_faq

from .common import calib_stats, eval_ppl, trained_params


def run(emit, gammas=(0.6, 0.85, 1.0), windows=(1, 3, 6)):
    cfg, model, params, data = trained_params()
    stats = calib_stats(model, params, data, n_samples=16)
    spec = QuantSpec(bits=3, group_size=64)
    for gamma in gammas:
        for window in windows:
            qp, _ = quantize_model(params, model.quant_site_map(), stats,
                                   method="faq", spec=spec, mode="fake",
                                   gamma=gamma, window=window)
            ppl = eval_ppl(model, qp, data)
            emit(f"table4/faq_g{gamma}_w{window}_ppl", None, round(ppl, 4))
