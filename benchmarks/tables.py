"""Paper-table analog benchmarks (Tables 1-3) on the in-repo trained LM.

Table 1 — PPL at 3-bit: FP / RTN / AWQ / FAQ.
Table 2 — 3-bit vs 4-bit: the FAQ advantage should shrink at 4 bits.
Table 3 — calibration-set size/bias robustness: mean/std of PPL over
          independent biased calibration draws (AWQ vs FAQ).  This is the
          paper's variance-reduction claim — its strongest effect.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import QuantSpec, quantize_model

from .common import calib_stats, eval_ppl, trained_params


def _quantize_eval(model, params, data, stats, method, bits, group=64):
    t0 = time.time()
    qp, _ = quantize_model(params, model.quant_site_map(), stats,
                           method=method,
                           spec=QuantSpec(bits=bits, group_size=group),
                           mode="fake")
    q_s = time.time() - t0
    return eval_ppl(model, qp, data), q_s


def table1(emit):
    cfg, model, params, data = trained_params()
    stats = calib_stats(model, params, data, n_samples=16)
    fp = eval_ppl(model, params, data)
    emit("table1/fp16_ppl", None, fp)
    for method in ("rtn", "awq", "faq"):
        ppl, q_s = _quantize_eval(model, params, data, stats, method, bits=3)
        emit(f"table1/{method}_3bit_ppl", q_s * 1e6, ppl)
    return fp


def table2(emit):
    cfg, model, params, data = trained_params()
    stats = calib_stats(model, params, data, n_samples=16)
    for bits in (3, 4):
        for method in ("rtn", "awq", "faq"):
            ppl, q_s = _quantize_eval(model, params, data, stats, method, bits)
            emit(f"table2/{method}_{bits}bit_ppl", q_s * 1e6, ppl)


def table3(emit, n_draws: int = 6, sizes=(4, 16)):
    """Biased small calibration sets: FAQ should show lower PPL variance
    across draws than AWQ (paper Table 3)."""
    cfg, model, params, data = trained_params()
    for n in sizes:
        for method in ("awq", "faq"):
            ppls = []
            for draw in range(n_draws):
                stats = calib_stats(model, params, data, n_samples=n,
                                    biased=True,
                                    seed_offset=10_000_000 + draw * 1000)
                ppl, _ = _quantize_eval(model, params, data, stats, method,
                                        bits=3)
                ppls.append(ppl)
            emit(f"table3/{method}_N{n}_mean_ppl", None, float(np.mean(ppls)))
            emit(f"table3/{method}_N{n}_std_ppl", None, float(np.std(ppls)))
