"""Open-loop traffic benchmark: latency percentiles under Poisson and
bursty arrivals, plus the chunked-prefill head-of-line scenario.

The closed-loop benches (``benchmarks.serve_bench``) measure
throughput; this one measures what a client feels.  A seeded
:class:`repro.serve.TrafficConfig` trace drives the engine open-loop
through :meth:`repro.serve.Scheduler.run_traffic` — arrivals follow the
trace clock and do not wait for the engine — and per-request timestamp
records are digested into p50/p95/p99 TTFT, queue delay, and per-token
decode latency.  Results merge into the ``traffic`` section of
``BENCH_serve.json`` (the closed-loop sections stay untouched) and
append rows to ``reports/serve_bench.csv``.

The head-of-line scenario measures what chunked prefill buys: waves of
one near-max-length prompt trailed by short prompts.  Monolithic
prefill makes each wave's shorts wait out the full long prefill before
they can be admitted; chunked admission (``prefill_chunk="auto"``)
bounds any single prefill call by the chunk bucket, so the shorts'
p95 TTFT drops.  Both numbers are recorded.

    PYTHONPATH=src python -m benchmarks.traffic_bench --requests 100
    PYTHONPATH=src python -m benchmarks.traffic_bench --smoke
"""
from __future__ import annotations

import argparse
import math
import time

import numpy as np

from benchmarks.serve_bench import (WARMUP_POLICY, _append_row,
                                    _quantized_setup, _write_json)


def _warm(eng, cfg, new_tokens):
    """Compile every prefill bucket and the decode/fill path before any
    timed traffic (same warmed-steady-state policy as serve_bench)."""
    from repro.serve import Request
    rng = np.random.default_rng(1)
    reqs = []
    for i, b in enumerate(eng.buckets):
        n = min(b, eng.max_len - new_tokens - 1)
        reqs.append(Request(rid=-(i + 1),
                            prompt=rng.integers(1, cfg.vocab_size, n)
                            .astype(np.int32),
                            max_new_tokens=new_tokens))
    eng.serve(reqs)


def bench_traffic(emit=print, *, requests=100, rate=16.0, n_slots=4,
                  max_len=128, new_tokens=8, seed=0, record=True):
    """Percentile report under Poisson and bursty arrivals on a fresh
    warmed engine per process.  Returns ``{process: report}`` where each
    report carries its generating workload next to the percentiles."""
    from repro.serve import Scheduler, ServeEngine, TrafficConfig, make_trace

    cfg, model, qp = _quantized_setup()
    out = {}
    for process in ("poisson", "bursty"):
        eng = ServeEngine(model, qp, n_slots=n_slots, max_len=max_len)
        _warm(eng, cfg, new_tokens)
        tcfg = TrafficConfig(n_requests=requests, process=process,
                             rate=rate, max_new_tokens=new_tokens,
                             prompt_len_max=min(48, max_len - new_tokens - 1),
                             vocab_size=cfg.vocab_size, seed=seed)
        res = Scheduler(eng).run_traffic(make_trace(tcfg))
        rep = res.traffic
        out[process] = dict(rep, workload=tcfg.workload(),
                            prefill_chunk=eng.prefill_chunk or 0)
        emit(f"serve/traffic_{process}_ttft_p50_ms,,"
             f"{rep['ttft_ms']['p50']:.2f}")
        emit(f"serve/traffic_{process}_ttft_p95_ms,,"
             f"{rep['ttft_ms']['p95']:.2f}")
        emit(f"serve/traffic_{process}_ttft_p99_ms,,"
             f"{rep['ttft_ms']['p99']:.2f}")
        emit(f"serve/traffic_{process}_queue_p95_ms,,"
             f"{rep['queue_delay_ms']['p95']:.2f}")
        emit(f"serve/traffic_{process}_tok_s,,{rep['tokens_per_s']:.2f}")
        if record:
            _append_row(dict(
                timestamp=int(time.time()), requests=requests,
                new_tokens=new_tokens, n_slots=n_slots, max_len=max_len,
                traffic_process=process, traffic_rate=rate,
                ttft_p50_ms=f"{rep['ttft_ms']['p50']:.2f}",
                ttft_p95_ms=f"{rep['ttft_ms']['p95']:.2f}",
                ttft_p99_ms=f"{rep['ttft_ms']['p99']:.2f}",
                queue_delay_p95_ms=f"{rep['queue_delay_ms']['p95']:.2f}",
                per_token_p50_ms=f"{rep['per_token_ms']['p50']:.2f}"))
    return out


def _wave_trace(cfg, *, waves, long_len, short_len, shorts_per_wave,
                wave_gap, new_tokens, seed=0):
    """Head-of-line workload: each wave is one long prompt followed
    1 ms later by ``shorts_per_wave`` short prompts.  Returns the trace
    plus the rids of the short requests (the TTFT population)."""
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    trace, shorts, rid = [], [], 0
    for w in range(waves):
        t = w * wave_gap
        trace.append((t, Request(
            rid=rid, prompt=rng.integers(1, cfg.vocab_size, long_len)
            .astype(np.int32), max_new_tokens=new_tokens)))
        rid += 1
        for _ in range(shorts_per_wave):
            trace.append((t + 1e-3, Request(
                rid=rid, prompt=rng.integers(1, cfg.vocab_size, short_len)
                .astype(np.int32), max_new_tokens=new_tokens)))
            shorts.append(rid)
            rid += 1
    return trace, shorts


def bench_chunked_ttft(emit=print, *, waves=10, shorts_per_wave=2,
                       n_slots=4, max_len=128, new_tokens=8,
                       wave_gap=0.6, record=True):
    """p95 TTFT of short requests stuck behind a near-max-length prompt,
    monolithic prefill vs chunked (``prefill_chunk="auto"``).  Same
    trace, same seed, same warmed engine config — the only variable is
    the chunk.  Returns both reports plus the p95 improvement."""
    from repro.serve import Scheduler, ServeEngine, summarize

    cfg, model, qp = _quantized_setup()
    long_len = max_len - new_tokens - 1
    out = {}
    for label, chunk in (("monolithic", 0), ("chunked", "auto")):
        eng = ServeEngine(model, qp, n_slots=n_slots, max_len=max_len,
                          prefill_chunk=chunk)
        _warm(eng, cfg, new_tokens)
        trace, shorts = _wave_trace(
            cfg, waves=waves, long_len=long_len, short_len=8,
            shorts_per_wave=shorts_per_wave, wave_gap=wave_gap,
            new_tokens=new_tokens)
        res = Scheduler(eng).run_traffic(trace)
        assert res.traffic["completed"] == res.traffic["submitted"]
        rep = summarize({rid: res.records[rid] for rid in shorts})
        out[label] = {
            "short_ttft_ms": rep["ttft_ms"],
            "prefill_chunk": eng.prefill_chunk or 0,
            "workload": {"waves": waves, "long_len": long_len,
                         "short_len": 8,
                         "shorts_per_wave": shorts_per_wave,
                         "wave_gap_s": wave_gap, "n_slots": n_slots,
                         "max_len": max_len, "new_tokens": new_tokens},
        }
        emit(f"serve/traffic_{label}_short_ttft_p95_ms,,"
             f"{rep['ttft_ms']['p95']:.2f}")
    gain = (out["monolithic"]["short_ttft_ms"]["p95"]
            - out["chunked"]["short_ttft_ms"]["p95"])
    out["p95_improvement_ms"] = round(gain, 3)
    emit(f"serve/traffic_chunked_ttft_p95_gain_ms,,{gain:.2f}")
    return out


def _sanity(report: dict):
    """The smoke contract: percentiles ordered and finite, every
    submitted request completed."""
    assert report["completed"] == report["submitted"], report
    for key in ("ttft_ms", "queue_delay_ms", "per_token_ms"):
        dist = report[key]
        vals = [dist["p50"], dist["p95"], dist["p99"], dist["mean"]]
        assert all(math.isfinite(v) for v in vals), (key, dist)
        assert dist["p50"] <= dist["p95"] <= dist["p99"], (key, dist)


def _bench_all(emit, *, requests=100, rate=16.0, n_slots=4, max_len=128,
               new_tokens=8, waves=10, record=True, write_json=True):
    traffic = bench_traffic(emit, requests=requests, rate=rate,
                            n_slots=n_slots, max_len=max_len,
                            new_tokens=new_tokens, record=record)
    for rep in traffic.values():
        _sanity(rep)
    hol = bench_chunked_ttft(emit, waves=waves, n_slots=n_slots,
                             max_len=max_len, new_tokens=new_tokens,
                             record=record)
    summary = {"traffic": {
        "timestamp": int(time.time()),
        "warmup": dict(WARMUP_POLICY),
        "poisson": traffic["poisson"],
        "bursty": traffic["bursty"],
        "chunked_prefill_hol": hol,
    }}
    if write_json:
        _write_json(summary)
    return summary


def run(emit):
    """Entry point for benchmarks.run."""
    _bench_all(emit)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--rate", type=float, default=16.0)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--waves", type=int, default=10,
                    help="head-of-line scenario wave count")
    ap.add_argument("--no-record", action="store_true",
                    help="skip the CSV trajectory and BENCH_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: seeded traffic, sanity-assert the "
                         "percentile report, write nothing")
    args = ap.parse_args()
    if args.smoke:
        traffic = bench_traffic(print, requests=args.requests,
                                rate=args.rate, n_slots=args.n_slots,
                                max_len=args.max_len,
                                new_tokens=args.new_tokens, record=False)
        for process, rep in traffic.items():
            _sanity(rep)
            print(f"{process}: {rep['submitted']} submitted, "
                  f"{rep['completed']} completed, ttft p50/p95/p99 = "
                  f"{rep['ttft_ms']['p50']:.1f}/{rep['ttft_ms']['p95']:.1f}/"
                  f"{rep['ttft_ms']['p99']:.1f} ms")
        print("traffic smoke OK")
        return
    s = _bench_all(print, requests=args.requests, rate=args.rate,
                   n_slots=args.n_slots, max_len=args.max_len,
                   new_tokens=args.new_tokens, waves=args.waves,
                   record=not args.no_record,
                   write_json=not args.no_record)["traffic"]
    for process in ("poisson", "bursty"):
        rep = s[process]
        print(f"{process}@{rep['workload']['rate']}/s: "
              f"ttft p50 {rep['ttft_ms']['p50']:.1f} ms / "
              f"p95 {rep['ttft_ms']['p95']:.1f} ms / "
              f"p99 {rep['ttft_ms']['p99']:.1f} ms | "
              f"queue p95 {rep['queue_delay_ms']['p95']:.1f} ms | "
              f"{rep['tokens_per_s']:.1f} tok/s")
    hol = s["chunked_prefill_hol"]
    print(f"head-of-line short p95 TTFT: monolithic "
          f"{hol['monolithic']['short_ttft_ms']['p95']:.1f} ms -> chunked "
          f"{hol['chunked']['short_ttft_ms']['p95']:.1f} ms "
          f"({hol['p95_improvement_ms']:+.1f} ms)")


if __name__ == "__main__":
    main()
