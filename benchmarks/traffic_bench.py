"""Open-loop traffic benchmark: latency percentiles under Poisson and
bursty arrivals, plus the chunked-prefill head-of-line scenario.

The closed-loop benches (``benchmarks.serve_bench``) measure
throughput; this one measures what a client feels.  A seeded
:class:`repro.serve.TrafficConfig` trace drives the engine open-loop
through :meth:`repro.serve.Scheduler.run_traffic` — arrivals follow the
trace clock and do not wait for the engine — and per-request timestamp
records are digested into p50/p95/p99 TTFT, queue delay, and per-token
decode latency.  Results merge into the ``traffic`` section of
``BENCH_serve.json`` (the closed-loop sections stay untouched) and
append rows to ``reports/serve_bench.csv``.

The head-of-line scenario measures what chunked prefill buys: waves of
one near-max-length prompt trailed by short prompts.  Monolithic
prefill makes each wave's shorts wait out the full long prefill before
they can be admitted; chunked admission (``prefill_chunk="auto"``)
bounds any single prefill call by the chunk bucket, so the shorts'
p95 TTFT drops.  Both numbers are recorded.

    PYTHONPATH=src python -m benchmarks.traffic_bench --requests 100
    PYTHONPATH=src python -m benchmarks.traffic_bench --smoke
"""
from __future__ import annotations

import argparse
import math
import time

import numpy as np

from benchmarks.serve_bench import (WARMUP_POLICY, _append_row,
                                    _quantized_setup, _write_json)


def _warm(eng, cfg, new_tokens):
    """Compile every prefill bucket and the decode/fill path before any
    timed traffic (same warmed-steady-state policy as serve_bench)."""
    from repro.serve import Request
    rng = np.random.default_rng(1)
    reqs = []
    for i, b in enumerate(eng.buckets):
        n = min(b, eng.max_len - new_tokens - 1)
        reqs.append(Request(rid=-(i + 1),
                            prompt=rng.integers(1, cfg.vocab_size, n)
                            .astype(np.int32),
                            max_new_tokens=new_tokens))
    eng.serve(reqs)


def bench_traffic(emit=print, *, requests=100, rate=16.0, n_slots=4,
                  max_len=128, new_tokens=8, seed=0, record=True,
                  tracer=None):
    """Percentile report under Poisson and bursty arrivals on a fresh
    warmed engine per process.  Returns ``{process: report}`` where each
    report carries its generating workload next to the percentiles."""
    from repro.serve import Scheduler, ServeEngine, TrafficConfig, make_trace

    cfg, model, qp = _quantized_setup()
    out = {}
    for process in ("poisson", "bursty"):
        eng = ServeEngine(model, qp, n_slots=n_slots, max_len=max_len,
                          tracer=tracer)
        _warm(eng, cfg, new_tokens)
        tcfg = TrafficConfig(n_requests=requests, process=process,
                             rate=rate, max_new_tokens=new_tokens,
                             prompt_len_max=min(48, max_len - new_tokens - 1),
                             vocab_size=cfg.vocab_size, seed=seed)
        res = Scheduler(eng).run_traffic(make_trace(tcfg))
        rep = res.traffic
        out[process] = dict(rep, workload=tcfg.workload(),
                            prefill_chunk=eng.prefill_chunk or 0)
        emit(f"serve/traffic_{process}_ttft_p50_ms,,"
             f"{rep['ttft_ms']['p50']:.2f}")
        emit(f"serve/traffic_{process}_ttft_p95_ms,,"
             f"{rep['ttft_ms']['p95']:.2f}")
        emit(f"serve/traffic_{process}_ttft_p99_ms,,"
             f"{rep['ttft_ms']['p99']:.2f}")
        emit(f"serve/traffic_{process}_queue_p95_ms,,"
             f"{rep['queue_delay_ms']['p95']:.2f}")
        emit(f"serve/traffic_{process}_tok_s,,{rep['tokens_per_s']:.2f}")
        if record:
            _append_row(dict(
                timestamp=int(time.time()), requests=requests,
                new_tokens=new_tokens, n_slots=n_slots, max_len=max_len,
                traffic_process=process, traffic_rate=rate,
                ttft_p50_ms=f"{rep['ttft_ms']['p50']:.2f}",
                ttft_p95_ms=f"{rep['ttft_ms']['p95']:.2f}",
                ttft_p99_ms=f"{rep['ttft_ms']['p99']:.2f}",
                queue_delay_p95_ms=f"{rep['queue_delay_ms']['p95']:.2f}",
                per_token_p50_ms=f"{rep['per_token_ms']['p50']:.2f}"))
    return out


def _wave_trace(cfg, *, waves, long_len, short_len, shorts_per_wave,
                wave_gap, new_tokens, seed=0):
    """Head-of-line workload: each wave is one long prompt followed
    1 ms later by ``shorts_per_wave`` short prompts.  Returns the trace
    plus the rids of the short requests (the TTFT population)."""
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    trace, shorts, rid = [], [], 0
    for w in range(waves):
        t = w * wave_gap
        trace.append((t, Request(
            rid=rid, prompt=rng.integers(1, cfg.vocab_size, long_len)
            .astype(np.int32), max_new_tokens=new_tokens)))
        rid += 1
        for _ in range(shorts_per_wave):
            trace.append((t + 1e-3, Request(
                rid=rid, prompt=rng.integers(1, cfg.vocab_size, short_len)
                .astype(np.int32), max_new_tokens=new_tokens)))
            shorts.append(rid)
            rid += 1
    return trace, shorts


def bench_chunked_ttft(emit=print, *, waves=10, shorts_per_wave=2,
                       n_slots=4, max_len=128, new_tokens=8,
                       wave_gap=0.6, record=True):
    """p95 TTFT of short requests stuck behind a near-max-length prompt,
    monolithic prefill vs chunked (``prefill_chunk="auto"``).  Same
    trace, same seed, same warmed engine config — the only variable is
    the chunk.  Returns both reports plus the p95 improvement."""
    from repro.serve import Scheduler, ServeEngine, summarize

    cfg, model, qp = _quantized_setup()
    long_len = max_len - new_tokens - 1
    out = {}
    for label, chunk in (("monolithic", 0), ("chunked", "auto")):
        eng = ServeEngine(model, qp, n_slots=n_slots, max_len=max_len,
                          prefill_chunk=chunk)
        _warm(eng, cfg, new_tokens)
        trace, shorts = _wave_trace(
            cfg, waves=waves, long_len=long_len, short_len=8,
            shorts_per_wave=shorts_per_wave, wave_gap=wave_gap,
            new_tokens=new_tokens)
        res = Scheduler(eng).run_traffic(trace)
        assert res.traffic["completed"] == res.traffic["submitted"]
        rep = summarize({rid: res.records[rid] for rid in shorts})
        out[label] = {
            "short_ttft_ms": rep["ttft_ms"],
            "prefill_chunk": eng.prefill_chunk or 0,
            "workload": {"waves": waves, "long_len": long_len,
                         "short_len": 8,
                         "shorts_per_wave": shorts_per_wave,
                         "wave_gap_s": wave_gap, "n_slots": n_slots,
                         "max_len": max_len, "new_tokens": new_tokens},
        }
        emit(f"serve/traffic_{label}_short_ttft_p95_ms,,"
             f"{rep['ttft_ms']['p95']:.2f}")
    gain = (out["monolithic"]["short_ttft_ms"]["p95"]
            - out["chunked"]["short_ttft_ms"]["p95"])
    out["p95_improvement_ms"] = round(gain, 3)
    emit(f"serve/traffic_chunked_ttft_p95_gain_ms,,{gain:.2f}")
    return out


def bench_overload(emit=print, *, requests=60, rate=None, n_slots=4,
                   max_len=128, new_tokens=8, deadline_s=None,
                   n_pages=None, seed=0, record=True, tracer=None):
    """Seeded overload run: arrivals well above the measured service
    rate into a page pool sized below peak demand, with SLO-aware
    admission shedding doomed requests.  The contract (asserted here
    and in CI): the loop never crashes, every request reaches exactly
    one terminal outcome (completed + shed + expired + truncated ==
    submitted), and survivors' tail TTFT stays reported.  Returns the
    report with ``shed_rate`` and survivor percentiles."""
    from repro.serve import (Request, Scheduler, ServeEngine, SLOConfig,
                             TrafficConfig, make_trace)

    cfg, model, qp = _quantized_setup()
    page_size = 16
    if n_pages is None:
        # below peak demand: the pool holds less than what all slots
        # decoding *typical* (median-length) sequences need at once, so
        # sustained concurrency must preempt; the longest single request
        # (prompt cap + generation) still fits on its own
        med_pages = -(-(12 + new_tokens) // page_size)   # lognormal median
        cap_pages = -(-(min(48, max_len - new_tokens - 1) + new_tokens + 1)
                      // page_size)
        n_pages = 1 + max(cap_pages, n_slots * med_pages - 2)
    if rate is None or deadline_s is None:
        # calibrate against *this machine's* compiled service rate: a
        # closed-loop probe of typical-length requests on an identical
        # warmed engine (warmup-time estimates are dominated by compile)
        eng0 = ServeEngine(model, qp, n_slots=n_slots, max_len=max_len,
                           paged=True, page_size=page_size)
        _warm(eng0, cfg, new_tokens)
        rng = np.random.default_rng(7)
        mk = lambda base: [Request(rid=-(base + i),
                                   prompt=rng.integers(1, cfg.vocab_size,
                                                       12 + i % 8)
                                   .astype(np.int32),
                                   max_new_tokens=new_tokens)
                           for i in range(3 * n_slots)]
        eng0.serve(mk(100))          # first pass compiles partial-batch
        m0 = eng0.metrics()["serve_time_s"]     # shapes; time the second
        probe = mk(200)
        eng0.serve(probe)
        dt = eng0.metrics()["serve_time_s"] - m0
        service_rate = len(probe) / max(dt, 1e-6)
        if rate is None:
            rate = 3.0 * service_rate
        if deadline_s is None:
            # a multiple of the naive drain time (requests/service):
            # preemption churn on the undersized pool stretches the real
            # drain well past it, so the backlog's tail is doomed while
            # the front can still make it — shed and survival
            # populations both stay non-degenerate
            deadline_s = max(0.1, 6.0 * requests / service_rate)
    eng = ServeEngine(model, qp, n_slots=n_slots, max_len=max_len,
                      paged=True, page_size=page_size, n_pages=n_pages,
                      slo=SLOConfig(seed=seed), tracer=tracer)
    _warm(eng, cfg, new_tokens)
    tcfg = TrafficConfig(n_requests=requests, process="poisson", rate=rate,
                         max_new_tokens=new_tokens,
                         prompt_len_max=min(48, max_len - new_tokens - 1),
                         vocab_size=cfg.vocab_size, deadline_s=deadline_s,
                         seed=seed)
    res = Scheduler(eng).run_traffic(make_trace(tcfg))
    s, rep = res.summary, res.traffic
    terminal = (s["completed"] + s["shed"] + s["expired"] + s["truncated"])
    assert terminal == rep["submitted"], (
        f"request accounting leak: {terminal} terminal outcomes for "
        f"{rep['submitted']} submitted ({s})")
    pool = eng._stepper.pool
    assert int(pool.ref[1:].sum()) == sum(
        1 for p in pool.index.values()), \
        "page refs leaked after overload run"
    shed_rate = s["shed"] / max(rep["submitted"], 1)
    out = dict(rep, workload=tcfg.workload(), n_pages=n_pages,
               shed_rate=round(shed_rate, 4),
               shed=s["shed"], shed_retried=s["shed_retried"],
               expired=s["expired"], truncated=s["truncated"],
               preempted=s["preempted"], resumed=s["resumed"],
               pressure_events=s["pressure_events"])
    emit(f"serve/overload_shed_rate,,{shed_rate:.3f}")
    emit(f"serve/overload_survivor_ttft_p99_ms,,"
         f"{rep['survivor_ttft_ms']['p99']:.2f}")
    emit(f"serve/overload_preempted,,{s['preempted']}")
    if record:
        _append_row(dict(
            timestamp=int(time.time()), requests=requests,
            new_tokens=new_tokens, n_slots=n_slots, max_len=max_len,
            traffic_process="overload", traffic_rate=f"{rate:.1f}",
            ttft_p50_ms=f"{rep['survivor_ttft_ms']['p50']:.2f}",
            ttft_p95_ms=f"{rep['survivor_ttft_ms']['p95']:.2f}",
            ttft_p99_ms=f"{rep['survivor_ttft_ms']['p99']:.2f}",
            queue_delay_p95_ms=f"{rep['queue_delay_ms']['p95']:.2f}"))
    return out


def _sanity(report: dict):
    """The smoke contract: percentiles ordered and finite, every
    submitted request completed."""
    assert report["completed"] == report["submitted"], report
    for key in ("ttft_ms", "queue_delay_ms", "per_token_ms"):
        dist = report[key]
        vals = [dist["p50"], dist["p95"], dist["p99"], dist["mean"]]
        assert all(math.isfinite(v) for v in vals), (key, dist)
        assert dist["p50"] <= dist["p95"] <= dist["p99"], (key, dist)


def _bench_all(emit, *, requests=100, rate=16.0, n_slots=4, max_len=128,
               new_tokens=8, waves=10, record=True, write_json=True,
               tracer=None):
    traffic = bench_traffic(emit, requests=requests, rate=rate,
                            n_slots=n_slots, max_len=max_len,
                            new_tokens=new_tokens, record=record,
                            tracer=tracer)
    for rep in traffic.values():
        _sanity(rep)
    hol = bench_chunked_ttft(emit, waves=waves, n_slots=n_slots,
                             max_len=max_len, new_tokens=new_tokens,
                             record=record)
    summary = {"traffic": {
        "timestamp": int(time.time()),
        "warmup": dict(WARMUP_POLICY),
        "poisson": traffic["poisson"],
        "bursty": traffic["bursty"],
        "chunked_prefill_hol": hol,
    }}
    if write_json:
        _write_json(summary)
    return summary


def run(emit):
    """Entry point for benchmarks.run."""
    _bench_all(emit)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--rate", type=float, default=16.0)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--waves", type=int, default=10,
                    help="head-of-line scenario wave count")
    ap.add_argument("--no-record", action="store_true",
                    help="skip the CSV trajectory and BENCH_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: seeded traffic, sanity-assert the "
                         "percentile report, write nothing")
    ap.add_argument("--overload", action="store_true",
                    help="overload scenario: arrivals at ~2x the measured "
                         "service rate, page pool below peak demand, "
                         "SLO-aware shedding; asserts the terminal-outcome "
                         "accounting and records shed rate + survivor p99 "
                         "TTFT")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="overload scenario per-request SLO (default: "
                         "scaled to the measured service rate)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export the run's request/step trace as Chrome/"
                         "Perfetto trace_event JSON (DESIGN.md §17)")
    ap.add_argument("--trace-capacity", type=int, default=16384,
                    help="trace ring-buffer size")
    args = ap.parse_args()
    tracer = None
    if args.trace_out:
        from repro.obs import Tracer
        tracer = Tracer(capacity=args.trace_capacity)

    def export_trace():
        if tracer is None:
            return
        tracer.export(args.trace_out)
        print(f"trace: {len(tracer.events())} events "
              f"({tracer.dropped} dropped) -> {args.trace_out} "
              f"(open in ui.perfetto.dev)")

    if args.overload:
        requests = 24 if args.smoke else args.requests
        rep = bench_overload(print, requests=requests,
                             n_slots=args.n_slots, max_len=args.max_len,
                             new_tokens=args.new_tokens,
                             deadline_s=args.deadline_s,
                             record=not (args.smoke or args.no_record),
                             tracer=tracer)
        if not (args.smoke or args.no_record):
            _write_json({"overload": dict(rep,
                                          timestamp=int(time.time()))})
        oc = rep["outcomes"]
        print(f"overload@{rep['workload']['rate']:.1f}/s over "
              f"{rep['n_pages']} pages: {rep['submitted']} submitted -> "
              f"{oc.get('completed', 0)} completed, {rep['shed']} shed "
              f"({rep['shed_retried']} retried), {rep['expired']} expired, "
              f"{rep['truncated']} truncated | {rep['preempted']} "
              f"preempted / {rep['resumed']} resumed | survivor ttft p99 "
              f"{rep['survivor_ttft_ms']['p99']:.1f} ms")
        print("overload accounting OK"
              + (" (smoke)" if args.smoke else ""))
        export_trace()
        return
    if args.smoke:
        traffic = bench_traffic(print, requests=args.requests,
                                rate=args.rate, n_slots=args.n_slots,
                                max_len=args.max_len,
                                new_tokens=args.new_tokens, record=False,
                                tracer=tracer)
        for process, rep in traffic.items():
            _sanity(rep)
            print(f"{process}: {rep['submitted']} submitted, "
                  f"{rep['completed']} completed, ttft p50/p95/p99 = "
                  f"{rep['ttft_ms']['p50']:.1f}/{rep['ttft_ms']['p95']:.1f}/"
                  f"{rep['ttft_ms']['p99']:.1f} ms")
        print("traffic smoke OK")
        export_trace()
        return
    s = _bench_all(print, requests=args.requests, rate=args.rate,
                   n_slots=args.n_slots, max_len=args.max_len,
                   new_tokens=args.new_tokens, waves=args.waves,
                   record=not args.no_record,
                   write_json=not args.no_record,
                   tracer=tracer)["traffic"]
    for process in ("poisson", "bursty"):
        rep = s[process]
        print(f"{process}@{rep['workload']['rate']}/s: "
              f"ttft p50 {rep['ttft_ms']['p50']:.1f} ms / "
              f"p95 {rep['ttft_ms']['p95']:.1f} ms / "
              f"p99 {rep['ttft_ms']['p99']:.1f} ms | "
              f"queue p95 {rep['queue_delay_ms']['p95']:.1f} ms | "
              f"{rep['tokens_per_s']:.1f} tok/s")
    hol = s["chunked_prefill_hol"]
    print(f"head-of-line short p95 TTFT: monolithic "
          f"{hol['monolithic']['short_ttft_ms']['p95']:.1f} ms -> chunked "
          f"{hol['chunked']['short_ttft_ms']['p95']:.1f} ms "
          f"({hol['p95_improvement_ms']:+.1f} ms)")
    export_trace()


if __name__ == "__main__":
    main()
