"""The paper's core comparison, reproduced end to end:

1. Theorem-1 scenario: fused future-aware scales beat current-layer-only
   scales under noisy calibration (win rate across seeds).
2. Trained-LM PPL at 3-bit: RTN vs AWQ vs FAQ (Table-1 analog).
3. Calibration-bias robustness: PPL spread across biased calibration
   draws (Table-3 analog) — FAQ's variance should be smaller.

    PYTHONPATH=src python examples/faq_vs_awq.py
"""
import numpy as np

from repro.core import QuantSpec, quantize_model
from repro.core.theory import theorem1_win_rate

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import calib_stats, eval_ppl, trained_params  # noqa: E402


def main():
    print("== 1. Theorem-1 scenario ==")
    rate = theorem1_win_rate(n_seeds=16)
    print(f"   delta_FAQ < delta_AWQ in {rate*100:.0f}% of seeds")

    print("== 2. 3-bit PPL (paper Table-1 analog) ==")
    cfg, model, params, data = trained_params()
    stats = calib_stats(model, params, data, n_samples=16)
    print(f"   fp32 ppl: {eval_ppl(model, params, data):.3f}")
    for method in ("rtn", "awq", "faq"):
        qp, _ = quantize_model(params, model.quant_site_map(), stats,
                               method=method,
                               spec=QuantSpec(bits=3, group_size=64),
                               mode="fake")
        print(f"   {method:4s} ppl: {eval_ppl(model, qp, data):.3f}")

    print("== 3. biased-calibration robustness (paper Table-3 analog) ==")
    for method in ("awq", "faq"):
        ppls = []
        for draw in range(4):
            st = calib_stats(model, params, data, n_samples=8, biased=True,
                             seed_offset=10_000_000 + draw * 1000)
            qp, _ = quantize_model(params, model.quant_site_map(), st,
                                   method=method,
                                   spec=QuantSpec(bits=3, group_size=64),
                                   mode="fake")
            ppls.append(eval_ppl(model, qp, data))
        print(f"   {method:4s} mean {np.mean(ppls):.3f}  std {np.std(ppls):.4f}")


if __name__ == "__main__":
    main()
