"""Quickstart: FAQ-quantize a model in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.core import QuantSpec, quantize_model, report_summary, run_calibration
from repro.models.registry import build_model

# 1. any registered architecture; .tiny() shrinks it for CPU
cfg = ARCHS["llama3-8b"].tiny()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

# 2. one calibration pass collects every layer's activation statistics —
#    including the future layers FAQ previews (no re-forwarding needed)
calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(i), (4, 64),
                                       0, cfg.vocab_size)} for i in range(4)]
stats = run_calibration(model.forward, params, calib)

# 3. quantize: paper-presearched FAQ (gamma=0.85, window=3), 3-bit asym
qparams, report = quantize_model(
    params, model.quant_site_map(), stats,
    method="faq", spec=QuantSpec(bits=3, group_size=64), mode="fake")

# 4. the quantized tree is a drop-in replacement
logits_fp, _ = model.forward(params, calib[0])
logits_q, _ = model.forward(qparams, calib[0])
print("logit rmse:", float(jnp.sqrt(jnp.mean((logits_q - logits_fp) ** 2))))
for site, s in report_summary(report).items():
    print(f"  {site:22s} alpha={s['mean_alpha']:.2f} "
          f"loss={s['mean_loss']:.5f} (+{100*s['improvement_vs_rtn']:.1f}% vs RTN)")
