"""End-to-end serving driver: train (or load) a small LM, FAQ-quantize to
the packed int4 format, and serve a batch of requests through the
continuous-batching engine — the full edge-deployment story of the paper.

    PYTHONPATH=src python examples/serve_quantized.py --requests 6
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QuantSpec, quantize_model, run_calibration
from repro.data.synthetic import calibration_batches
from repro.serve import Request, Scheduler, ServeEngine

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import trained_params  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--slots", type=int, default=3)
    args = ap.parse_args()

    print("== loading/training the base model ==")
    cfg, model, params, data = trained_params()

    print("== calibrating + FAQ-quantizing to packed int4 ==")
    calib = calibration_batches(data, 16, 64)
    stats = run_calibration(model.forward, params,
                            [{k: jnp.asarray(v) for k, v in b.items()}
                             for b in calib])
    t0 = time.time()
    qparams, _ = quantize_model(params, model.quant_site_map(), stats,
                                method="faq",
                                spec=QuantSpec(bits=args.bits, group_size=64),
                                mode="packed")
    print(f"   quantized in {time.time()-t0:.1f}s")
    n_bytes_fp = sum(p.size * p.dtype.itemsize
                     for p in jax.tree_util.tree_leaves(params))
    n_bytes_q = sum(p.size * p.dtype.itemsize
                    for p in jax.tree_util.tree_leaves(qparams))
    print(f"   weights: {n_bytes_fp/2**20:.1f} MiB -> {n_bytes_q/2**20:.1f} MiB")

    print("== serving (bucketed batched prefill + streaming) ==")
    eng = ServeEngine(model, qparams, n_slots=args.slots, max_len=128)
    sched = Scheduler(eng)
    rng = np.random.default_rng(0)
    streamed = {}
    for i in range(args.requests):
        req = Request(rid=i,
                      prompt=data.sequence(30_000_000 + i,
                                           int(rng.integers(8, 24))),
                      max_new_tokens=args.new_tokens)
        streamed[i] = []
        sched.submit(req, deadline=time.time() + 120.0,
                     on_token=lambda rid, tok: streamed[rid].append(tok))
    t0 = time.time()
    results = sched.run()
    dt = time.time() - t0
    total = sum(len(v) for v in results.values())
    for rid in sorted(results):
        assert results[rid].tolist() == streamed[rid]  # stream == result
        print(f"   req {rid}: {results[rid][:8]}...")
    m = sched.metrics()
    print(f"   {total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s CPU ref-path)")
    print(f"   prefill {m['prefill_batches']} batches / "
          f"{m['prefill_traces']} traces on buckets {m['buckets']}; "
          f"{m['decode_steps']} decode steps")


if __name__ == "__main__":
    main()
