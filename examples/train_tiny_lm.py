"""End-to-end training driver: train a small LM on the synthetic pipeline
with checkpointing/restart (the fault-tolerance path used at scale).

    PYTHONPATH=src python examples/train_tiny_lm.py --steps 300
    # kill it anytime; rerun resumes from the last checkpoint

Scale knobs: --arch picks any registered architecture (reduced with
--tiny/full), --grad-compress enables int8 EF gradient compression.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.dist import checkpoint as ckpt
from repro.models.registry import build_model
from repro.train.grad_compress import ef_init
from repro.train.trainer import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="reports/train_tiny")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="use the full (paper-size) config — needs a pod")
    args = ap.parse_args()

    cfg = ARCHS[args.arch] if args.full else ARCHS[args.arch].tiny()
    model = build_model(cfg)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size))
    tcfg = TrainConfig(lr=args.lr, warmup=30, total_steps=args.steps,
                       grad_compress=args.grad_compress)
    train_step, opt = make_train_step(model, tcfg)
    train_step = jax.jit(train_step)

    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    ef = ef_init(params) if args.grad_compress else None
    start = 0
    last = ckpt.latest_step(args.ckpt_dir)
    if last is not None:
        restored = ckpt.restore(args.ckpt_dir, last,
                                {"params": params, "opt": opt_state})
        params, opt_state, start = restored["params"], restored["opt"], last
        print(f"resumed from step {last}")

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in data.batch(step, args.batch, args.seq).items()}
        if args.grad_compress:
            params, opt_state, ef, metrics = train_step(params, opt_state,
                                                        batch, ef)
        else:
            params, opt_state, metrics = train_step(params, opt_state, batch)
        if step % 20 == 0:
            tok_s = args.batch * args.seq * (step - start + 1) / (time.time() - t0)
            print(f"step {step:5d} loss {float(metrics['loss']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {tok_s:,.0f} tok/s",
                  flush=True)
        if step and step % args.ckpt_every == 0:
            ckpt.save_async(args.ckpt_dir, step,
                            {"params": params, "opt": opt_state})
    ckpt.wait_pending()
    ckpt.save(args.ckpt_dir, args.steps, {"params": params, "opt": opt_state})
    print(f"done: final loss {float(metrics['loss']):.3f} "
          f"(true-process floor ~{jnp.log(data.perplexity_upper_bound()):.2f})")


if __name__ == "__main__":
    main()
