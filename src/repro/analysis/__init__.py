"""Static analysis for the repro codebase (DESIGN.md §15).

Two passes turn the repo's hand-enforced invariants into machine checks:

* :mod:`.lint` — an AST lint framework with per-rule codes (RPR001..),
  ``# repro: noqa[RPRxxx] reason`` suppressions, and a committed
  baseline file.  The rules encode real past bug classes: raw
  ``jax.jit`` bypassing the serve rule-table seam, host syncs inside
  jitted bodies, recompile hazards, low-precision accumulation in
  Pallas kernels, serve-loop regrowth, clock-seam bypasses, and bare
  tile-divisibility asserts.
* :mod:`.hlo_audit` — compiles the serving entry points for a
  dense/paged × spec × mesh matrix and checks the lowered HLO against
  a declarative contract table (collective counts, all-reduce operand
  ceilings, no host transfers).

CLI: ``python -m repro.analysis [paths] [--hlo]`` — see ``--help``.
The lint pass is stdlib-only (no jax import) so it stays fast enough
for a pre-commit hook; the HLO audit imports jax lazily.
"""
from .lint import Finding, code_line_count, load_baseline, run_lint

__all__ = ["Finding", "code_line_count", "load_baseline", "run_lint"]
