"""CLI: ``python -m repro.analysis [paths] [options]``.

Default run lints ``src/`` against the committed baseline
(``analysis_baseline.json`` at the repo root) and exits non-zero on
any non-baselined finding.  ``--hlo`` additionally compiles the
serving entry points and checks the lowered HLO against the contract
table (imports jax; needs enough devices for the mesh — the CLI sets
``XLA_FLAGS`` for 8 virtual CPU devices if unset).
"""
from __future__ import annotations

import argparse
import os
import sys
from pathlib import Path

from .lint import apply_baseline, collect_files, load_baseline, run_lint, \
    write_baseline
from .rules import all_rules


def _default_baseline(paths) -> Path:
    """analysis_baseline.json next to the scanned tree's repo root
    (the directory holding ``src``), falling back to cwd."""
    for p in paths:
        p = Path(p).resolve()
        for anchor in (p, *p.parents):
            if (anchor / "analysis_baseline.json").exists() \
                    or (anchor / "src").is_dir():
                return anchor / "analysis_baseline.json"
    return Path("analysis_baseline.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST lint + compiled-HLO contract audit "
                    "(DESIGN.md §15)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to lint (default: src)")
    ap.add_argument("--baseline", default=None,
                    help="baseline file (default: analysis_baseline.json "
                         "at the repo root)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept current findings into the baseline")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline (report everything)")
    ap.add_argument("--hlo", action="store_true",
                    help="also run the compiled-HLO contract audit")
    ap.add_argument("--hlo-mesh", default="1,2", metavar="DATA,MODEL",
                    help="mesh shape for the HLO audit (default 1,2)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    rules = all_rules()
    if args.list_rules:
        for r in rules:
            scope = ",".join(r.scope) if r.scope else "project-wide"
            print(f"{r.code}  [{scope}]  {r.title}")
        return 0

    paths = args.paths or ["src"]
    missing = [p for p in paths if not Path(p).exists()]
    if missing:
        print(f"error: no such path(s): {missing}", file=sys.stderr)
        return 2
    files = collect_files(paths)
    findings = run_lint(paths, rules, files=files)

    baseline_path = (Path(args.baseline) if args.baseline
                     else _default_baseline(paths))
    if args.write_baseline:
        write_baseline(baseline_path, findings, files)
        print(f"wrote {len(findings)} finding(s) to {baseline_path}")
        return 0

    baseline = set() if args.no_baseline else load_baseline(baseline_path)
    new, old, stale = apply_baseline(findings, files, baseline)
    for f in new:
        print(f.render())
    if old:
        print(f"({len(old)} baselined finding(s) suppressed)")
    for key in stale:
        print(f"stale baseline entry (fixed? shrink the baseline): {key}")

    rc = 0
    if new:
        print(f"\n{len(new)} new finding(s) — fix, noqa with a reason, "
              f"or (last resort) --write-baseline")
        rc = 1
    else:
        print(f"lint clean: {len(files)} files, "
              f"{len(rules)} rules, {len(old)} baselined")

    if args.hlo:
        # 8 virtual CPU devices unless the caller already configured XLA
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
        from . import hlo_audit
        mesh_shape = tuple(int(x) for x in args.hlo_mesh.split(","))
        violations = hlo_audit.audit(mesh_shape=mesh_shape)
        for v in violations:
            print(v.render())
        if violations:
            print(f"\nHLO audit: {len(violations)} contract violation(s)")
            rc = 1
        else:
            print(f"HLO audit clean at mesh {mesh_shape}: "
                  f"{len(hlo_audit.CONTRACTS)} contracts")
    return rc


if __name__ == "__main__":
    sys.exit(main())
