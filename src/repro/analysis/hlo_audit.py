"""Compiled-artifact auditor: check lowered HLO against collective contracts.

The serving design makes hard promises about what each jitted entry
point is allowed to do on the wire (DESIGN.md §9, §15.3): decode pays
exactly **one** logits all-gather per step, nothing ever lowers to an
all-to-all or collective-permute, per-token all-reduces stay at
activation size (2×d_model elements per operand), and no jitted hot
path touches the host (``is_host_transfer=true``).  Those promises used
to live as one-off regexes in ``tests/test_serve_sharded.py``; this
module turns them into a declarative :data:`CONTRACTS` table checked
uniformly across the full (cache kind × op × spec) matrix.

Each :class:`Contract` names an engine entry point and bounds, per
collective kind, how many ops the compiled module may contain and how
large their operands may be.  :func:`audit` builds one spec-enabled
engine per cache kind on a virtual mesh, lowers every contract's entry
point under the rule table the serve loop would use, and returns a
:class:`Violation` per broken bound.  Run it from the CLI::

    python -m repro.analysis --hlo            # mesh (1, 2), 8 CPU devices

Counts are exact for the audited tiny config and pinned toolchain; when
a legitimate change shifts a count, edit the table entry in the same PR
— the table is the reviewable artifact, exactly like the lint baseline.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

#: Operand-size ceilings are expressed as multiples of d_model so the
#: table survives config-size changes; ``VOCAB`` marks "the padded vocab
#: dimension must appear in the operand type" (the logits gather).
VOCAB = "vocab"


@dataclasses.dataclass(frozen=True)
class Bound:
    """Per-collective-kind budget inside one compiled module."""

    max_count: int                       # how many such ops may appear
    max_elem_factor: Optional[float] = None   # operand elems <= f * d_model
    require_contains: Optional[str] = None    # VOCAB: padded vocab must
    #                                           appear as an operand dim


@dataclasses.dataclass(frozen=True)
class Contract:
    name: str                            # stable id, e.g. "decode/dense"
    op: str                              # decode | prefill | spec_cycle
    paged: bool
    bounds: Dict[str, Bound] = dataclasses.field(default_factory=dict)
    forbid_host_transfer: bool = True

    def bound(self, kind: str) -> Bound:
        # Unlisted collective kinds are forbidden outright.
        return self.bounds.get(kind, Bound(max_count=0))


@dataclasses.dataclass(frozen=True)
class Violation:
    contract: str
    kind: str                            # collective kind or "host-transfer"
    message: str

    def render(self) -> str:
        return f"{self.contract}: [{self.kind}] {self.message}"


_COLLECTIVES = ("all-gather", "all-to-all", "collective-permute",
                "all-reduce")

# The one-all-gather-per-decode-step invariant and its friends, probed
# on the tiny llama3-8b at mesh (1, 2).  Decode: the single all-gather
# is the logits gather (operand carries the padded vocab dim) and the
# three all-reduces are activation-sized.  Prefill additionally gathers
# sequence-sharded activations and row-parallel weights (bounded by
# count only).  The spec cycle never moves vocab-sized data: its
# all-gathers are (B, k+1)-shaped token/prob exchanges, bounded tightly
# at 16×d_model elements.
_DECODE_BOUNDS = {
    "all-gather": Bound(max_count=1, require_contains=VOCAB),
    "all-reduce": Bound(max_count=3, max_elem_factor=2.0),
}
_PREFILL_BOUNDS = {
    "all-gather": Bound(max_count=15),
    "all-reduce": Bound(max_count=2, max_elem_factor=32.0),
}
_SPEC_BOUNDS = {
    "all-gather": Bound(max_count=14, max_elem_factor=16.0),
    "all-reduce": Bound(max_count=18, max_elem_factor=8.0),
}

CONTRACTS: Tuple[Contract, ...] = (
    Contract("decode/dense", "decode", paged=False, bounds=_DECODE_BOUNDS),
    Contract("decode/paged", "decode", paged=True, bounds=_DECODE_BOUNDS),
    Contract("prefill/dense", "prefill", paged=False, bounds=_PREFILL_BOUNDS),
    Contract("prefill/paged", "prefill", paged=True, bounds=_PREFILL_BOUNDS),
    Contract("spec_cycle/dense", "spec_cycle", paged=False,
             bounds=_SPEC_BOUNDS),
    Contract("spec_cycle/paged", "spec_cycle", paged=True,
             bounds=_SPEC_BOUNDS),
)

#: Draft depth the spec-cycle contracts are probed at.
SPEC_K = 2


# ---------------------------------------------------------------------------
# HLO text inspection
# ---------------------------------------------------------------------------

def collective_operands(txt: str, kind: str) -> List[str]:
    """Result types of every ``kind`` op in an HLO module dump."""
    return re.findall(r"= (\S+) %s\(" % kind, txt)


def type_elems(ty: str) -> int:
    """Element count of an HLO type string.

    ``f32[2,1,512]{2,1,0}`` -> 1024.  The layout suffix in braces must
    be ignored (its digits are dimension *indices*, and the trailing 0
    would zero the product — the bug that made the old inline check in
    test_serve_sharded vacuous).  Scalars (``f32[]``) count as 1; tuple
    types sum their leaves.
    """
    total = 0
    for shape in re.findall(r"\[([\d,]*)\]", ty):
        n = 1
        for d in shape.split(","):
            if d.strip().isdigit():
                n *= int(d)
        total += n
    return total if total else 1


def type_dims(ty: str) -> List[int]:
    dims: List[int] = []
    for shape in re.findall(r"\[([\d,]*)\]", ty):
        dims.extend(int(d) for d in shape.split(",") if d.strip().isdigit())
    return dims


def check_module(txt: str, contract: Contract, *, d_model: int,
                 vocab_pad: int) -> List[Violation]:
    """Check one compiled module's text against one contract."""
    out: List[Violation] = []
    for kind in _COLLECTIVES:
        ops = collective_operands(txt, kind)
        b = contract.bound(kind)
        if len(ops) > b.max_count:
            out.append(Violation(
                contract.name, kind,
                f"{len(ops)} ops, contract allows {b.max_count}"))
        if b.max_elem_factor is not None:
            ceil = int(b.max_elem_factor * d_model)
            for ty in ops:
                n = type_elems(ty)
                if n > ceil:
                    out.append(Violation(
                        contract.name, kind,
                        f"operand {ty} has {n} elems, contract ceiling "
                        f"{ceil} ({b.max_elem_factor} x d_model)"))
        if b.require_contains == VOCAB:
            for ty in ops:
                if vocab_pad not in type_dims(ty):
                    out.append(Violation(
                        contract.name, kind,
                        f"operand {ty} does not carry the padded vocab "
                        f"dim {vocab_pad} — expected the logits gather"))
    if contract.forbid_host_transfer and "is_host_transfer=true" in txt:
        out.append(Violation(
            contract.name, "host-transfer",
            "compiled module contains is_host_transfer=true"))
    return out


# ---------------------------------------------------------------------------
# Engine building + lowering (imports deferred: jax init is expensive and
# the lint half of the package must stay importable without devices)
# ---------------------------------------------------------------------------

def _build_engine(paged: bool, mesh):
    import jax
    import jax.numpy as jnp
    from repro.configs import ARCHS
    from repro.core import QuantSpec, quantize_model, run_calibration
    from repro.data.synthetic import DataConfig, SyntheticLM, \
        calibration_batches
    from repro.models.registry import build_model
    from repro.serve.draft import self_int8_draft
    from repro.serve.engine import ServeEngine
    from repro.serve.spec import SpecConfig

    cfg = ARCHS["llama3-8b"].tiny()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size))
    calib = [{k: jnp.asarray(v) for k, v in b.items()}
             for b in calibration_batches(data, 4, 32)]
    stats = run_calibration(model.forward, params, calib)
    qp, _ = quantize_model(params, model.quant_site_map(), stats,
                           method="faq", spec=QuantSpec(bits=4,
                                                        group_size=64),
                           mode="packed")
    spec = SpecConfig(k=SPEC_K, draft=self_int8_draft(model, qp, stats))
    eng = ServeEngine(model, qp, n_slots=2, max_len=64, paged=paged,
                      spec=spec, mesh=mesh)
    return cfg, model, eng


def _lower_contract(contract: Contract, cfg, model, eng, mesh) -> str:
    import jax
    import jax.numpy as jnp
    from repro.dist.sharding import SERVE_DECODE_RULES, \
        SERVE_PREFILL_RULES, axis_rules

    B = eng.n_slots
    zi = jnp.zeros((B,), jnp.int32)
    zb = jnp.ones((B,), bool)
    zf = jnp.zeros((B,), jnp.float32)
    key = jax.random.PRNGKey(0)

    if not contract.paged:
        cache = eng._place(model.init_cache(B, eng.max_len),
                           eng._cache_axes)
        if contract.op == "decode":
            with axis_rules(mesh, SERVE_DECODE_RULES):
                low = eng._decode.fn.jitted.lower(
                    eng.params, cache, zi, zb, zf, None, None, key)
        elif contract.op == "prefill":
            b = eng.buckets[0]
            toks = jnp.zeros((B, b), jnp.int32)
            plen = jnp.full((B,), b, jnp.int32)
            with axis_rules(mesh, SERVE_PREFILL_RULES):
                low = eng._prefill_admit.fn.jitted.lower(
                    eng.params, toks, plen, cache, zb, zf, None, None,
                    key, zi)
        else:
            fn = eng._spec._get_cycle("dense", SPEC_K, False, False)
            with axis_rules(mesh, SERVE_DECODE_RULES):
                low = fn.fn.jitted.lower(
                    eng.params, eng._spec.draft.params, cache, zi, zi,
                    zb, zf, zi, zf, key)
    else:
        store = eng._store
        table = jnp.zeros((B, eng.pages_per_slot), jnp.int32)
        if contract.op == "decode":
            with axis_rules(mesh, SERVE_DECODE_RULES):
                low = eng._decode_paged.fn.jitted.lower(
                    eng.params, store, table, zi, zi, zb, zf, None,
                    None, key)
        elif contract.op == "prefill":
            b = eng.buckets[0]
            toks = jnp.zeros((B, b), jnp.int32)
            plen = jnp.full((B,), b, jnp.int32)
            with axis_rules(mesh, SERVE_PREFILL_RULES):
                low = eng._prefill_paged.fn.jitted.lower(
                    eng.params, toks, plen, zb, zf, None, None, key, zi)
        else:
            fn = eng._spec._get_cycle("paged", SPEC_K, False, False)
            with axis_rules(mesh, SERVE_DECODE_RULES):
                low = fn.fn.jitted.lower(
                    eng.params, eng._spec.draft.params, store, table,
                    zi, zi, zb, zf, zi, zf, key)
    return low.compile().as_text()


def audit(mesh_shape: Tuple[int, int] = (1, 2),
          contracts: Tuple[Contract, ...] = CONTRACTS) -> List[Violation]:
    """Compile every contract's entry point and check its HLO.

    Needs enough devices for ``mesh_shape`` (CI uses
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).  Returns
    the flat list of violations; empty means every contract holds.
    """
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh(*mesh_shape)
    violations: List[Violation] = []
    for paged in (False, True):
        todo = [c for c in contracts if c.paged is paged]
        if not todo:
            continue
        cfg, model, eng = _build_engine(paged, mesh)
        from repro.models.common import padded_vocab
        vocab_pad = padded_vocab(cfg.vocab_size)
        for c in todo:
            txt = _lower_contract(c, cfg, model, eng, mesh)
            violations.extend(check_module(
                txt, c, d_model=cfg.d_model, vocab_pad=vocab_pad))
    return violations
