"""AST lint framework: findings, noqa suppressions, baseline, runner.

A rule is a class with a ``code`` ("RPR001"), a ``scope`` (path
substrings it applies to; empty = everywhere), and either a per-file
``check(sf)`` or a whole-project ``project(files)`` hook (for rules
that need cross-file state, e.g. which functions end up jitted).

Suppression is per physical line: ``# repro: noqa[RPR002] <reason>``.
The reason string is part of the convention (every suppression should
say *why* the invariant doesn't apply), but the parser accepts a bare
``noqa[...]`` so fixtures stay terse.

The baseline file keys findings on ``(rule, path, stripped line
text)`` rather than line numbers, so unrelated edits above a
baselined finding don't churn the file.  Entries that no longer match
anything are reported as stale — the baseline can only shrink.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import re
import tokenize
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set

NOQA_RE = re.compile(
    r"#\s*repro:\s*noqa\[(?P<codes>[A-Z0-9,\s]+)\]\s*(?P<reason>.*)")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    path: str          # posix path as given to the runner
    line: int          # 1-based
    rule: str          # "RPR001"
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


class SourceFile:
    """One parsed file: text, lines, AST, and per-line noqa codes."""

    def __init__(self, path: Path, rel: str):
        self.path = path
        self.rel = rel
        self.text = path.read_text()
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=str(path))
        self.noqa: Dict[int, Set[str]] = {}
        for i, line in enumerate(self.lines, start=1):
            m = NOQA_RE.search(line)
            if m:
                self.noqa[i] = {c.strip() for c in
                                m.group("codes").split(",") if c.strip()}

    def suppressed(self, line: int, code: str) -> bool:
        return code in self.noqa.get(line, ())

    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""


class Rule:
    """Base rule.  Subclasses set ``code``/``title``/``scope`` and
    implement ``check`` (per file) or ``project`` (whole run)."""

    code = "RPR000"
    title = ""
    scope: Sequence[str] = ()      # path substrings; empty = all files

    def applies(self, rel: str) -> bool:
        rel = rel.replace("\\", "/")
        return not self.scope or any(s in rel for s in self.scope)

    def finding(self, sf: SourceFile, node, message: str) -> Finding:
        return Finding(sf.rel, getattr(node, "lineno", 0), self.code,
                       message)

    def check(self, sf: SourceFile) -> List[Finding]:
        return []


def collect_files(paths: Sequence, *, base: Optional[Path] = None
                  ) -> List[SourceFile]:
    """Parse every ``.py`` under ``paths`` (files or directories).
    ``rel`` paths are relative to ``base`` (default cwd) when possible,
    so baselines are machine-independent."""
    base = Path.cwd() if base is None else Path(base)
    out, seen = [], set()
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            f = f.resolve()
            if f in seen:
                continue
            seen.add(f)
            try:
                rel = f.relative_to(base.resolve()).as_posix()
            except ValueError:
                rel = f.as_posix()
            out.append(SourceFile(f, rel))
    return out


def run_lint(paths: Sequence, rules: Sequence[Rule], *,
             base: Optional[Path] = None,
             files: Optional[List[SourceFile]] = None) -> List[Finding]:
    """Run ``rules`` over ``paths``; returns noqa-filtered findings
    sorted by (path, line, rule)."""
    if files is None:
        files = collect_files(paths, base=base)
    by_rel = {sf.rel: sf for sf in files}
    findings: List[Finding] = []
    for rule in rules:
        in_scope = [sf for sf in files if rule.applies(sf.rel)]
        if hasattr(rule, "project"):
            got = rule.project(in_scope, all_files=files)
        else:
            got = [f for sf in in_scope for f in rule.check(sf)]
        for f in got:
            sf = by_rel.get(f.path)
            if sf is not None and sf.suppressed(f.line, f.rule):
                continue
            findings.append(f)
    return sorted(set(findings))


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------

def _baseline_key(f: Finding, by_rel: Dict[str, SourceFile]):
    sf = by_rel.get(f.path)
    text = sf.line_text(f.line) if sf is not None else ""
    return (f.rule, f.path, text)


def load_baseline(path) -> Set[tuple]:
    path = Path(path)
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return {(e["rule"], e["path"], e["line_text"])
            for e in data.get("findings", [])}


def write_baseline(path, findings: Sequence[Finding],
                   files: Sequence[SourceFile]) -> None:
    by_rel = {sf.rel: sf for sf in files}
    entries = sorted({_baseline_key(f, by_rel) for f in findings})
    Path(path).write_text(json.dumps(
        {"comment": "Accepted findings; regenerate with "
                    "`python -m repro.analysis --write-baseline`. "
                    "This file can only shrink — fix or noqa new "
                    "findings instead of re-baselining them.",
         "findings": [{"rule": r, "path": p, "line_text": t}
                      for r, p, t in entries]}, indent=2) + "\n")


def apply_baseline(findings: Sequence[Finding],
                   files: Sequence[SourceFile], baseline: Set[tuple]):
    """Split findings into (new, baselined) and report stale baseline
    entries (accepted findings that no longer occur)."""
    by_rel = {sf.rel: sf for sf in files}
    new, old, seen = [], [], set()
    for f in findings:
        key = _baseline_key(f, by_rel)
        if key in baseline:
            old.append(f)
            seen.add(key)
        else:
            new.append(f)
    stale = sorted(baseline - seen)
    return new, old, stale


# ---------------------------------------------------------------------------
# Comment/format-insensitive line counting (serve module budget)
# ---------------------------------------------------------------------------

def code_line_count(text: str) -> int:
    """Number of lines carrying actual code: comments, blank lines, and
    docstrings don't count — a module can't dodge (or trip) the serve
    line budget by reformatting."""
    tree = ast.parse(text)
    doc_lines: Set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.Module, ast.ClassDef, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            body = getattr(node, "body", [])
            if (body and isinstance(body[0], ast.Expr)
                    and isinstance(body[0].value, ast.Constant)
                    and isinstance(body[0].value.value, str)):
                doc_lines.update(range(body[0].lineno,
                                       body[0].end_lineno + 1))
    skip = {tokenize.COMMENT, tokenize.NL, tokenize.NEWLINE,
            tokenize.INDENT, tokenize.DEDENT, tokenize.ENCODING,
            tokenize.ENDMARKER}
    code_lines: Set[int] = set()
    for tok in tokenize.generate_tokens(io.StringIO(text).readline):
        if tok.type in skip:
            continue
        code_lines.update(range(tok.start[0], tok.end[0] + 1))
    return len(code_lines - doc_lines)


# ---------------------------------------------------------------------------
# Shared AST helpers for rules
# ---------------------------------------------------------------------------

def dotted(node) -> Optional[str]:
    """'jax.jit' for Attribute/Name chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def last_seg(node) -> Optional[str]:
    d = dotted(node)
    return d.rsplit(".", 1)[-1] if d else None


def call_kwargs(call: ast.Call) -> Set[str]:
    return {kw.arg for kw in call.keywords if kw.arg}
