"""Rule catalog (DESIGN.md §15).  Each module defines one rule class;
``all_rules()`` instantiates the full set in code order."""
from .rpr001_raw_jit import RawJitInServe
from .rpr002_host_sync import HostSyncInJitted
from .rpr003_static_args import ScalarArgsWithoutStatic
from .rpr004_accum_dtype import KernelAccumDtype
from .rpr005_serve_loop import SingleServeLoop
from .rpr006_clock_seam import ClockSeamBypass
from .rpr007_tile_assert import BareTileAssert
from .rpr008_pool_raise import PoolRaiseInServe
from .rpr009_obs_bypass import ObsBypassInServe

RULE_CLASSES = [RawJitInServe, HostSyncInJitted, ScalarArgsWithoutStatic,
                KernelAccumDtype, SingleServeLoop, ClockSeamBypass,
                BareTileAssert, PoolRaiseInServe, ObsBypassInServe]


def all_rules():
    return [cls() for cls in RULE_CLASSES]


def rules_by_code(*codes):
    by_code = {cls.code: cls for cls in RULE_CLASSES}
    return [by_code[c]() for c in codes]
