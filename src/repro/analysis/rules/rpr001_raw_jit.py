"""RPR001: raw ``jax.jit`` in ``serve/`` bypassing the rule-table seam.

Every jitted serving entry point must go through
``ServeEngine._jit(fn, rules)`` so it traces (and re-traces) under the
right ``axis_rules`` table (DESIGN.md §13).  A raw ``jax.jit`` in
``serve/`` compiles without the regime's sharding rules: on a mesh the
lowered program silently loses the decode-layout constraints (the PR 6
bug class this rule encodes).  The seam itself carries the one
documented suppression.
"""
from __future__ import annotations

import ast
from typing import List

from ..lint import Finding, Rule, SourceFile, dotted

_JIT_NAMES = {"jax.jit", "jit"}
_PARTIAL = {"functools.partial", "partial"}


def _is_raw_jit(node) -> bool:
    """``jax.jit``/``jit`` as a name, or ``partial(jax.jit, ...)``."""
    d = dotted(node)
    if d in _JIT_NAMES:
        return True
    if isinstance(node, ast.Call) and dotted(node.func) in _PARTIAL:
        return bool(node.args) and dotted(node.args[0]) in _JIT_NAMES
    return False


class RawJitInServe(Rule):
    code = "RPR001"
    title = "raw jax.jit in serve/ bypasses the ServeEngine._jit seam"
    scope = ("repro/serve/",)

    def check(self, sf: SourceFile) -> List[Finding]:
        out = []
        msg = ("raw jax.jit bypasses the rule-table seam — route through "
               "ServeEngine._jit(fn, rules) so the trace runs under the "
               "regime's axis_rules table")
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call) and _is_raw_jit(node.func):
                out.append(self.finding(sf, node, msg))
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if _is_raw_jit(dec):
                        out.append(Finding(sf.rel, dec.lineno, self.code,
                                           msg))
        return out
