"""RPR002: host-sync calls inside jitted/shard_map'd bodies, and
device→host transfers on the per-step serve hot path.

Two detection modes share the code:

1. **Jitted bodies** (project-wide): collect every function that ends
   up jitted — ``@jax.jit`` / ``@partial(jax.jit, ...)`` decorators,
   first arguments to ``jax.jit`` / ``ServeEngine._jit`` / ``shard_map``
   calls (by name or attribute, through ``functools.partial``), plus
   the transitive closure over plain-name calls from those bodies —
   then flag ``.item()``, ``np.asarray``/``np.array``,
   ``jax.device_get``, and ``float()``/``int()`` on non-constants
   inside them.  Inside a trace these either fail at trace time or,
   worse, silently constant-fold a traced value.

2. **Serve hot path**: the per-step methods of the engine/stepper/spec
   loop (``_plain_step``, ``plain_step``, ``spec_cycle``,
   ``input_tokens``, ...) run once per decode step — a device→host
   transfer there serializes the step pipeline.  Each transfer must be
   either removed or noqa-documented with the reason it is part of the
   designed per-step budget (one int32 per slot per step).

Known static limits: jit targets built by factories
(``build(k, ...)`` call results) and lambdas passed inline are only
scanned when the lambda itself is the argument; attribute calls are
not followed in the closure (bounding false positives).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from ..lint import Finding, Rule, SourceFile, call_kwargs, dotted, last_seg

_WRAPPERS = {"jit", "_jit", "shard_map"}
_TRANSFER_FUNCS = {"np.asarray", "np.array", "numpy.asarray",
                   "numpy.array", "jax.device_get", "device_get"}
_HOT_METHODS = {"_plain_step", "_spec_step", "plain_step", "spec_cycle",
                "input_tokens", "run_cycle_dense", "run_cycle_paged",
                "track_step"}


def _is_jit_decorator(dec) -> bool:
    if last_seg(dec) == "jit":
        return True
    if isinstance(dec, ast.Call):
        if last_seg(dec.func) in _WRAPPERS:
            return True
        if last_seg(dec.func) == "partial" and dec.args:
            return last_seg(dec.args[0]) in _WRAPPERS
    return False


def _wrapped_names(call: ast.Call) -> Set[str]:
    """Names a ``jit(fn)`` / ``shard_map(fn, ...)`` call roots: the bare
    or attribute name of the first argument (through ``partial``)."""
    if not call.args:
        return set()
    arg = call.args[0]
    if isinstance(arg, ast.Call) and last_seg(arg.func) == "partial" \
            and arg.args:
        arg = arg.args[0]
    if isinstance(arg, ast.Name):
        return {arg.id}
    if isinstance(arg, ast.Attribute):
        return {arg.attr}
    return set()


def _host_sync_calls(body_node, *, include_casts: bool):
    """Yield (node, description) for host-sync calls under ``body_node``
    (not descending into nested function definitions' decorators —
    nested defs are part of the traced body, so they are scanned)."""
    for node in ast.walk(body_node):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func)
        if d in _TRANSFER_FUNCS:
            yield node, f"{d}() forces a device sync"
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr == "item" and not node.args:
            yield node, ".item() forces a device sync"
        elif include_casts and isinstance(node.func, ast.Name) \
                and node.func.id in ("float", "int") and node.args \
                and not isinstance(node.args[0], ast.Constant):
            yield node, (f"{node.func.id}() on a traced value forces a "
                         "device sync (or fails at trace time)")


class HostSyncInJitted(Rule):
    code = "RPR002"
    title = "host sync inside a jitted/shard_map'd body or serve hot path"
    scope = ()          # project-wide (closure crosses modules)

    def project(self, in_scope: List[SourceFile],
                all_files: List[SourceFile]) -> List[Finding]:
        files = all_files
        # -- pass 1: every function definition, and every jit/shard_map
        #    root name, across the project
        defs: Dict[str, List[tuple]] = {}     # name -> [(sf, node)]
        roots: Set[str] = set()
        direct: List[tuple] = []              # (sf, lambda/def node)
        for sf in files:
            for node in ast.walk(sf.tree):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    defs.setdefault(node.name, []).append((sf, node))
                    if any(_is_jit_decorator(d)
                           for d in node.decorator_list):
                        direct.append((sf, node))
                elif isinstance(node, ast.Call) \
                        and last_seg(node.func) in _WRAPPERS:
                    roots |= _wrapped_names(node)
                    if node.args and isinstance(node.args[0], ast.Lambda):
                        direct.append((sf, node.args[0]))
        # -- pass 2: transitive closure over plain-name calls
        jitted: Set[str] = set()
        frontier = set(roots)
        while frontier:
            name = frontier.pop()
            if name in jitted or name not in defs:
                continue
            jitted.add(name)
            for _, fn in defs[name]:
                for node in ast.walk(fn):
                    if isinstance(node, ast.Call) \
                            and isinstance(node.func, ast.Name) \
                            and node.func.id in defs:
                        frontier.add(node.func.id)
        # -- pass 3: flag host syncs inside jitted bodies
        out: List[Finding] = []
        bodies = list(direct) + [(sf, fn) for name in jitted
                                 for sf, fn in defs[name]]
        seen: Set[int] = set()
        for sf, fn in bodies:
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            label = getattr(fn, "name", "<lambda>")
            for node, why in _host_sync_calls(fn, include_casts=True):
                out.append(Finding(
                    sf.rel, node.lineno, self.code,
                    f"{why} inside jitted body {label!r}"))
        # -- hot-path mode: per-step serve methods (host code, so casts
        #    like int(tok) are fine — only transfer initiators count)
        for sf in files:
            if "repro/serve/" not in sf.rel.replace("\\", "/"):
                continue
            for node in ast.walk(sf.tree):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)) \
                        and node.name in _HOT_METHODS \
                        and node.name not in jitted:
                    for call, why in _host_sync_calls(
                            node, include_casts=False):
                        out.append(Finding(
                            sf.rel, call.lineno, self.code,
                            f"{why} on the per-step serve hot path "
                            f"({node.name!r} runs every decode step)"))
        return out
