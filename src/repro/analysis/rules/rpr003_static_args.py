"""RPR003: jit over a function taking Python scalars without
``static_argnames`` — the recompile hazard.

A jitted function whose signature takes Python ints/floats/bools/strs
(by annotation or default) retraces on every distinct value unless the
argument is declared static.  Resolvable sites only: ``jax.jit(f)`` /
``@jax.jit`` / ``partial(jax.jit, ...)`` where ``f`` is a function
defined in the same module; lambdas and call-result targets are
skipped.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from ..lint import Finding, Rule, SourceFile, call_kwargs, dotted

_JIT = {"jax.jit", "jit"}
_PARTIAL = {"functools.partial", "partial"}
_SCALARS = {"int", "float", "bool", "str"}


def _scalar_params(fn) -> List[str]:
    """Parameter names whose annotation or default is a Python scalar."""
    a = fn.args
    params = a.posonlyargs + a.args + a.kwonlyargs
    defaults = dict(zip([p.arg for p in a.args[::-1]],
                        a.defaults[::-1]))
    defaults.update({p.arg: d for p, d in zip(a.kwonlyargs, a.kw_defaults)
                     if d is not None})
    out = []
    for p in params:
        ann = dotted(p.annotation) if p.annotation is not None else None
        d = defaults.get(p.arg)
        scalar_default = (isinstance(d, ast.Constant)
                          and isinstance(d.value, (int, float, bool, str))
                          and d.value is not None)
        if ann in _SCALARS or scalar_default:
            out.append(p.arg)
    return out


def _jit_call_without_static(node: ast.Call) -> Optional[ast.AST]:
    """The wrapped-function node of a jit site lacking static args."""
    d = dotted(node.func)
    if d in _JIT:
        if {"static_argnames", "static_argnums"} & call_kwargs(node):
            return None
        return node.args[0] if node.args else None
    if d in _PARTIAL and node.args and dotted(node.args[0]) in _JIT:
        if {"static_argnames", "static_argnums"} & call_kwargs(node):
            return None
        return "decorated"        # partial(jax.jit, ...) as decorator
    return None


class ScalarArgsWithoutStatic(Rule):
    code = "RPR003"
    title = "jit signature takes Python scalars without static_argnames"

    def check(self, sf: SourceFile) -> List[Finding]:
        defs = {n.name: n for n in ast.walk(sf.tree)
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        out = []

        def flag(site, fn):
            scalars = _scalar_params(fn)
            if scalars:
                out.append(Finding(
                    sf.rel, site.lineno, self.code,
                    f"jit over {fn.name!r} takes Python scalar(s) "
                    f"{scalars} without static_argnames — every distinct "
                    "value retraces; declare them static or pass arrays"))

        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                target = _jit_call_without_static(node)
                if isinstance(target, ast.Name) and target.id in defs:
                    flag(node, defs[target.id])
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if dotted(dec) in _JIT:
                        flag(dec, node)
                    elif isinstance(dec, ast.Call) \
                            and _jit_call_without_static(dec) is not None:
                        flag(dec, node)
        return out
