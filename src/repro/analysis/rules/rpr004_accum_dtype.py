"""RPR004: kernel ``dot``/``cumsum`` without an explicit f32
accumulator — the PR 1 ``window_preview`` cancellation bug class.

In ``kernels/``, every MXU-feeding contraction must pin
``preferred_element_type=jnp.float32`` (low-precision inputs otherwise
accumulate in the input dtype) and every ``cumsum`` must pin ``dtype``
(long prefix sums cancel catastrophically below f32).
"""
from __future__ import annotations

import ast
from typing import List

from ..lint import Finding, Rule, SourceFile, call_kwargs, last_seg

_DOT_FNS = {"dot", "dot_general", "matmul"}


class KernelAccumDtype(Rule):
    code = "RPR004"
    title = "kernel dot/cumsum without an explicit float32 accumulator"
    scope = ("repro/kernels/",)

    def check(self, sf: SourceFile) -> List[Finding]:
        out = []
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            seg = last_seg(node.func)
            kwargs = call_kwargs(node)
            if seg in _DOT_FNS and "preferred_element_type" not in kwargs:
                out.append(self.finding(
                    sf, node,
                    f"{seg}() without preferred_element_type=jnp.float32 "
                    "accumulates in the input dtype — pin the f32 "
                    "accumulator (window_preview cancellation bug class)"))
            elif seg == "cumsum" and "dtype" not in kwargs:
                out.append(self.finding(
                    sf, node,
                    "cumsum() without dtype=jnp.float32 — long prefix "
                    "sums cancel below f32; pin the accumulator dtype"))
        return out
