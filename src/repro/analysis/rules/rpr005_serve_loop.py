"""RPR005: serve-loop regrowth — cache-kind branching or a second
serve loop in the engine.

PR 7 collapsed dense and paged serving into ONE ``ServeEngine.serve``
loop driving a pluggable stepper.  This rule keeps it that way without
the old substring heuristics: no ``_serve_*`` sibling loops anywhere in
``serve/``, and inside ``ServeEngine.serve`` no ``self.paged``
branching and no stepper access beyond the ``begin()`` lifecycle hook
(everything else must flow through the per-step engine helpers, which
delegate through the stepper interface).
"""
from __future__ import annotations

import ast
from typing import List

from ..lint import Finding, Rule, SourceFile

_ALLOWED_STEPPER_ATTRS = {"begin"}


class SingleServeLoop(Rule):
    code = "RPR005"
    title = "cache-kind branching or a second serve loop in the engine"
    scope = ("repro/serve/",)

    def check(self, sf: SourceFile) -> List[Finding]:
        out = []
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name.startswith("_serve_"):
                out.append(self.finding(
                    sf, node,
                    f"{node.name!r} looks like a second serve loop — "
                    "dense and paged must share ServeEngine.serve with a "
                    "stepper plugged in (DESIGN.md §14)"))
            if isinstance(node, ast.ClassDef) and node.name == "ServeEngine":
                out.extend(self._check_serve(sf, node))
        return out

    def _check_serve(self, sf: SourceFile, cls: ast.ClassDef):
        out = []
        serve = next((n for n in cls.body
                      if isinstance(n, ast.FunctionDef)
                      and n.name == "serve"), None)
        if serve is None:
            return out
        for node in ast.walk(serve):
            if not isinstance(node, ast.Attribute):
                continue
            base = node.value
            if isinstance(base, ast.Name) and base.id == "self" \
                    and node.attr == "paged":
                out.append(self.finding(
                    sf, node,
                    "cache-kind branching (self.paged) inside the serve "
                    "loop — delegate through the stepper hooks"))
            elif isinstance(base, ast.Attribute) \
                    and isinstance(base.value, ast.Name) \
                    and base.value.id == "self" \
                    and base.attr == "_stepper" \
                    and node.attr not in _ALLOWED_STEPPER_ATTRS:
                out.append(self.finding(
                    sf, node,
                    f"serve loop reaches into the stepper "
                    f"(self._stepper.{node.attr}) — only the begin() "
                    "lifecycle hook may be called from the loop body"))
        return out
