"""RPR006: wall-clock reads in ``serve/`` outside the clock seam.

PR 7 threaded one injectable ``clock=`` through the engine, scheduler,
and load generator so deadline/TTFT behavior is testable with fake
clocks.  Any direct ``time.time()`` / ``time.monotonic()`` /
``time.perf_counter()`` *reference* (not just call — ``clock or
time.time`` defaults count) in ``serve/`` reintroduces untestable wall
time.  The seam's own default carries the documented suppression.
(``time.sleep`` is not a clock read and stays allowed.)
"""
from __future__ import annotations

import ast
from typing import List

from ..lint import Finding, Rule, SourceFile, dotted

_CLOCK_READS = {"time.time", "time.monotonic", "time.perf_counter",
                "time.process_time"}


class ClockSeamBypass(Rule):
    code = "RPR006"
    title = "wall-clock read in serve/ outside the injectable clock seam"
    scope = ("repro/serve/",)

    def check(self, sf: SourceFile) -> List[Finding]:
        out = []
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Attribute) \
                    and dotted(node) in _CLOCK_READS:
                out.append(self.finding(
                    sf, node,
                    f"{dotted(node)} bypasses the injectable clock seam "
                    "— read self.clock() (engine) or the injected "
                    "clock= callable instead"))
        return out
