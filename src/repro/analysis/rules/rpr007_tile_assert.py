"""RPR007: bare tile-divisibility ``assert`` in ``kernels/`` without a
pad fallback — the PR 3 ``quant_matmul`` crash class.

A kernel that asserts ``dim % tile == 0`` crashes on any model whose
shapes don't land on the tile grid (hymba's d_model=1600 was the
original trigger).  The fix pattern is pad-and-slice (see
``quant_matmul_pallas``); asserts that document a *constructed*
invariant (the code above already forced divisibility) carry a noqa
with the reason.
"""
from __future__ import annotations

import ast
from typing import List

from ..lint import Finding, Rule, SourceFile


def _has_mod(node) -> bool:
    return any(isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mod)
               for n in ast.walk(node))


class BareTileAssert(Rule):
    code = "RPR007"
    title = "bare tile-divisibility assert in kernels/ without pad fallback"
    scope = ("repro/kernels/",)

    def check(self, sf: SourceFile) -> List[Finding]:
        out = []
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Assert) and _has_mod(node.test):
                out.append(self.finding(
                    sf, node,
                    "divisibility assert without a pad fallback crashes "
                    "on non-tile-divisible shapes — pad up to the tile "
                    "and slice the result (quant_matmul pattern), or "
                    "noqa with the invariant that guarantees it"))
        return out
