"""RPR008: terminal pool/capacity errors raised on the serve path.

PR 9's backpressure protocol (DESIGN.md §16) removed the crash mode
where a full :class:`~repro.serve.pages.PagePool` killed the serve loop
mid-decode: serve-path allocators call ``try_alloc()`` and convert a
``None`` into :class:`~repro.serve.pages.PagePressure`, which the
engine resolves by preempting a slot.  A bare ``raise PoolExhausted``
(or a pool/capacity ``RuntimeError``) anywhere in ``serve/``
reintroduces the crash — one overloaded request would take down every
in-flight neighbor.

The one legitimate raise is the protocol's own terminal path
(:meth:`PagePool.alloc`, for direct offline callers), which carries the
documented suppression.
"""
from __future__ import annotations

import ast
import re
from typing import List

from ..lint import Finding, Rule, SourceFile, last_seg

_TERMINAL = {"PoolExhausted"}
_GENERIC = {"RuntimeError", "MemoryError"}
_CAPACITY_MSG = re.compile(r"pool|page|capacit|exhaust|out of memory",
                           re.IGNORECASE)


def _raised_name(node: ast.Raise):
    exc = node.exc
    if isinstance(exc, ast.Call):
        exc = exc.func
    return last_seg(exc) if exc is not None else None


def _msg_text(node: ast.Raise) -> str:
    """Every string constant under the raised expression (f-string parts
    included) — enough to tell a capacity error from an unrelated one."""
    if node.exc is None:
        return ""
    parts = [n.value for n in ast.walk(node.exc)
             if isinstance(n, ast.Constant) and isinstance(n.value, str)]
    return " ".join(parts)


class PoolRaiseInServe(Rule):
    code = "RPR008"
    title = "terminal pool/capacity raise on the serve path"
    scope = ("repro/serve/",)

    def check(self, sf: SourceFile) -> List[Finding]:
        out = []
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Raise):
                continue
            name = _raised_name(node)
            if name in _TERMINAL:
                out.append(self.finding(
                    sf, node,
                    f"raise {name} crashes the serve loop — allocate via "
                    "try_alloc() and raise PagePressure so the engine can "
                    "preempt instead"))
            elif name in _GENERIC and _CAPACITY_MSG.search(_msg_text(node)):
                out.append(self.finding(
                    sf, node,
                    f"capacity {name} on the serve path bypasses the "
                    "backpressure protocol — raise PagePressure (or shed) "
                    "so overload degrades instead of crashing"))
        return out
