"""RPR009: ad-hoc output or timestamping in ``serve/`` bypassing the
observability layer.

The serving stack has one sanctioned way to observe itself
(DESIGN.md §17): counters and histograms go through the engine's
:class:`repro.obs.MetricsRegistry`, events through the span tracer via
:mod:`repro.serve.instrument`, and every timestamp through the
injectable ``clock=`` seam.  A stray ``print()``, a ``logging`` call,
or a ``datetime.now()`` in ``serve/`` is telemetry the registry cannot
snapshot, the trace cannot order, and the fake-clock tests cannot see —
so it rots into an unmaintained side channel.  Launch scripts,
benchmarks, and tests are out of scope (printing is their job); a
deliberate exception inside ``serve/`` carries a reasoned
``# repro: noqa[RPR009]``.
"""
from __future__ import annotations

import ast
from typing import List

from ..lint import Finding, Rule, SourceFile, dotted

_TS_READS = {"datetime.now", "datetime.utcnow", "datetime.today",
             "datetime.datetime.now", "datetime.datetime.utcnow",
             "datetime.date.today"}


class ObsBypassInServe(Rule):
    code = "RPR009"
    title = "print/logging/raw timestamp in serve/ bypassing repro.obs"
    scope = ("repro/serve/",)

    def check(self, sf: SourceFile) -> List[Finding]:
        out = []
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id == "print":
                out.append(self.finding(
                    sf, node,
                    "print() in serve/ is telemetry the registry cannot "
                    "snapshot — use the engine's MetricsRegistry or a "
                    "serve.instrument tracer hook"))
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                mod = getattr(node, "module", None) or ""
                names = [a.name for a in node.names]
                if mod == "logging" or "logging" in names:
                    out.append(self.finding(
                        sf, node,
                        "logging in serve/ bypasses the observability "
                        "layer — emit a registry counter or a tracer "
                        "instant via serve.instrument instead"))
            elif isinstance(node, ast.Attribute) \
                    and dotted(node) in _TS_READS:
                out.append(self.finding(
                    sf, node,
                    f"{dotted(node)} is a raw timestamp outside the "
                    "clock seam — read the injected clock= callable so "
                    "fake-clock runs stay deterministic"))
        return out
