"""Assigned architecture configs (one module per arch) + registry."""
from .base import (LONG_500K, PREFILL_32K, SHAPE_CELLS, TRAIN_4K,
                   DECODE_32K, ModelConfig, ShapeCell, cell_applicable)

from .stablelm_12b import CONFIG as STABLELM_12B
from .llama3_405b import CONFIG as LLAMA3_405B
from .llama3_8b import CONFIG as LLAMA3_8B
from .deepseek_coder_33b import CONFIG as DEEPSEEK_CODER_33B
from .hymba_1_5b import CONFIG as HYMBA_1_5B
from .whisper_small import CONFIG as WHISPER_SMALL
from .xlstm_350m import CONFIG as XLSTM_350M
from .llama4_maverick_400b_a17b import CONFIG as LLAMA4_MAVERICK
from .qwen2_moe_a2_7b import CONFIG as QWEN2_MOE
from .qwen2_vl_2b import CONFIG as QWEN2_VL_2B

ARCHS = {
    c.name: c for c in (
        STABLELM_12B, LLAMA3_405B, LLAMA3_8B, DEEPSEEK_CODER_33B,
        HYMBA_1_5B, WHISPER_SMALL, XLSTM_350M, LLAMA4_MAVERICK,
        QWEN2_MOE, QWEN2_VL_2B,
    )
}

__all__ = ["ARCHS", "ModelConfig", "ShapeCell", "SHAPE_CELLS",
           "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
           "cell_applicable"]
