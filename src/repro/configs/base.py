"""Model / shape configuration dataclasses and the shape-cell definitions."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    rope_theta: float = 5e5
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = False             # checkpoint each block in the layer scan
    kv_cache_bits: int = 16         # 8 -> int8 KV cache (+per-entry scales)
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    shared_expert_ff: int = 0
    moe_capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int = 0            # 0 -> ceil(d_model / 16)
    slstm_every: int = 0            # xLSTM: a sLSTM block every k layers
    sliding_window: int = 0         # hymba attention branch window (0 = full)
    # --- enc-dec (whisper) ---
    n_encoder_layers: int = 0
    encoder_len: int = 1536         # stub frame count (1500 padded for sharding)
    # --- vlm ---
    mrope_sections: Tuple[int, ...] = ()
    patch_len: int = 256            # stub image patch count

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)

    def tiny(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=256 if self.d_ff else 0,
            head_dim=32,
            vocab_size=512,
            dtype="float32",
            n_experts=min(self.n_experts, 8),
            experts_per_token=min(self.experts_per_token, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            shared_expert_ff=256 if self.shared_expert_ff else 0,
            ssm_state=min(self.ssm_state, 8),
            ssm_dt_rank=8 if self.ssm_state else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            encoder_len=32,
            sliding_window=16 if self.sliding_window else 0,
            slstm_every=self.slstm_every,
            patch_len=8 if self.patch_len and self.family == "vlm" else self.patch_len,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeCell("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeCell("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeCell("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeCell("long_500k", 524288, 1, "decode")

SHAPE_CELLS = {c.name: c for c in
               (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

# Families with sub-quadratic sequence mixing — the only ones that run
# long_500k (DESIGN.md §6).
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> bool:
    if cell.name == "long_500k":
        return cfg.family in SUBQUADRATIC_FAMILIES
    return True
