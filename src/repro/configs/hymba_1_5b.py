"""hymba-1.5b [hybrid] — parallel attn+mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Attention branch uses sliding-window (1024) per Hymba's design, making the
arch sub-quadratic (long_500k applicable).  Vocab padded 32001 -> 32256
internally.  d_model=1600 -> quant group size falls back to 100.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64, rope_theta=1e4,
    ssm_state=16, ssm_expand=2, ssm_conv=4, sliding_window=1024,
)
