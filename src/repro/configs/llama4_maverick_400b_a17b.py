"""llama4-maverick-400b-a17b [moe] — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts
top-1 + 1 shared expert per layer.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128, rope_theta=5e5,
    n_experts=128, experts_per_token=1,
    n_shared_experts=1, shared_expert_ff=8192,
)
