"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L d_model=2048 16H (kv=16, MHA) per-expert d_ff=1408 vocab=151936,
60 routed experts top-4 (padded to 64 for the 16-way expert-parallel
axis; pad experts are masked in the router) + shared expert block of
intermediate 4*1408=5632.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=151936, head_dim=128, rope_theta=1e6,
    n_experts=60, experts_per_token=4,
    n_shared_experts=1, shared_expert_ff=5632,
)
