"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.  Backbone only
per the assignment: the vision tower is a stub; input_specs() provides
precomputed patch embeddings that are prepended to the token stream.
M-RoPE sections (t,h,w) = (16, 24, 24) over head_dim/2 = 64.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab_size=151936, head_dim=128, rope_theta=1e6,
    mrope_sections=(16, 24, 24), patch_len=256,
)
