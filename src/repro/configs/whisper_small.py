"""whisper-small [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356].

12L (decoder) d_model=768 12H (kv=12, MHA) d_ff=3072 vocab=51865.
12 encoder layers over stub frame embeddings (1500 padded to 1536 frames
for even sequence sharding).  input_specs() provides precomputed frame
embeddings per the assignment.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64, rope_theta=1e4,
    n_encoder_layers=12, encoder_len=1536,
)
