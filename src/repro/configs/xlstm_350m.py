"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24L d_model=1024 4H (kv=4) d_ff=0 (projections live inside the xLSTM
blocks, proj_factor=2) vocab=50304.  A sLSTM block every 4th layer
(positions 3, 7, ...), the rest mLSTM (DESIGN.md notes the placement
approximation).  Recurrent -> sub-quadratic -> long_500k applicable.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=256, rope_theta=1e4,
    ssm_state=0, slstm_every=4,
)
