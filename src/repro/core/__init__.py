"""Core library: the paper's contribution (FAQ) plus RTN/AWQ baselines."""
from .quantizer import (QuantSpec, QuantizedTensor, dequantize_groupwise,
                        effective_group_size, pack_codes, quant_dequant,
                        quantize_groupwise, unpack_codes)
from .methods import (DEFAULT_ALPHA_GRID, PRESEARCHED_GAMMA,
                      PRESEARCHED_WINDOW, SearchResult, candidate_scale,
                      full_search_faq, fuse_stats, normalize_scale,
                      quant_error, search_alpha, site_stat_for_method,
                      window_preview)
from .calibration import run_calibration
from .apply import quantize_model, report_summary
from .stats import site_stat, merge_stats

__all__ = [
    "QuantSpec", "QuantizedTensor", "dequantize_groupwise",
    "effective_group_size", "pack_codes", "quant_dequant",
    "quantize_groupwise", "unpack_codes",
    "DEFAULT_ALPHA_GRID", "PRESEARCHED_GAMMA", "PRESEARCHED_WINDOW",
    "SearchResult", "candidate_scale", "full_search_faq", "fuse_stats",
    "normalize_scale", "quant_error", "search_alpha", "site_stat_for_method",
    "window_preview",
    "run_calibration", "quantize_model", "report_summary",
    "site_stat", "merge_stats",
]
