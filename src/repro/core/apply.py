"""Model-level quantization: apply RTN / AWQ / FAQ to a full parameter tree.

Models expose ``quant_site_map() -> {param_path: site_key}`` where each
mapped leaf has shape ``(L, [extra...], n_in, n_out)`` (layer-stacked for
scan; MoE adds an experts dim) and ``stats[site_key]["mean_abs"]`` is
``(L, n_in)``.  Because all per-layer weights are stacked, whole-model
quantization is a few ``vmap`` calls — and trivially layer-parallel in the
distributed path.

Two output modes:

* ``"fake"``   — same-structure params with each quantized weight replaced
  by its dequantized reconstruction (runs through the unchanged model;
  used by evaluation benchmarks).
* ``"packed"`` — quantized leaves become :class:`QuantizedTensor` (packed
  uint8 codes + group scales + act_scale); the model's linear dispatch
  routes these through the dequant-matmul kernel (serving path).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .methods import (DEFAULT_ALPHA_GRID, PRESEARCHED_GAMMA,
                      PRESEARCHED_WINDOW, search_alpha, site_stat_for_method)
from .quantizer import QuantSpec, quant_dequant, quantize_groupwise


def _get_path(tree, path):
    for k in path:
        tree = tree[k]
    return tree


def _set_path(tree, path, value):
    if len(path) == 1:
        out = dict(tree)
        out[path[0]] = value
        return out
    out = dict(tree)
    out[path[0]] = _set_path(tree[path[0]], path[1:], value)
    return out


def _quantize_leaf(w, stat, spec, alpha_grid, loss, stats_site, mode):
    """Quantize one (L, [extra...], n_in, n_out) leaf.

    ``stat`` is the (L, n_in) method statistic or None (RTN).
    Returns (new_leaf, report_dict).
    """
    L = w.shape[0]
    n_in, n_out = w.shape[-2], w.shape[-1]
    extra = w.shape[1:-2]
    w_flat = w.reshape((L, -1, n_in, n_out))
    E = w_flat.shape[1]

    if stat is None:  # RTN
        act_scale = None
        report = {}
    else:
        mean_sq = stats_site["mean_sq"] if loss == "diag" else None
        sample = stats_site["sample"] if loss == "sample" else None

        def search_le(w2, a, msq, smp):
            return search_alpha(w2, a, spec, alpha_grid, mean_sq=msq, sample=smp)

        in_e = (0, None, None, None)
        in_l = (0, 0,
                0 if mean_sq is not None else None,
                0 if sample is not None else None)
        res = jax.vmap(jax.vmap(search_le, in_axes=in_e), in_axes=in_l)(
            w_flat, stat, mean_sq, sample)
        act_scale = res.act_scale  # (L, E, n_in)
        report = {"alpha": res.alpha, "loss": res.loss, "rtn_loss": res.rtn_loss}

    if mode == "fake":
        if act_scale is None:
            qd = jax.vmap(jax.vmap(lambda x: quant_dequant(x, spec)))(w_flat)
        else:
            qd = jax.vmap(jax.vmap(lambda x, s: quant_dequant(x, spec, act_scale=s)))(
                w_flat, act_scale)
        new_leaf = qd.reshape(w.shape).astype(w.dtype)
    elif mode == "packed":
        if act_scale is None:
            qt = jax.vmap(jax.vmap(
                lambda x: quantize_groupwise(x, spec, pack=True)))(w_flat)
        else:
            qt = jax.vmap(jax.vmap(
                lambda x, s: quantize_groupwise(x, spec, act_scale=s, pack=True)))(
                w_flat, act_scale)
        # reshape batched QuantizedTensor leaves back to (L, *extra, ...)
        qt = jax.tree_util.tree_map(
            lambda a: a.reshape((L,) + extra + a.shape[2:]), qt)
        new_leaf = qt
    else:
        raise ValueError(f"unknown mode {mode!r}")
    return new_leaf, report


def quantize_model(params: dict, site_map: dict, stats: dict, *,
                   method: str = "faq",
                   spec: QuantSpec = QuantSpec(),
                   gamma: float = PRESEARCHED_GAMMA,
                   window: int = PRESEARCHED_WINDOW,
                   loss: str = "sample",
                   mode: str = "fake",
                   alpha_grid: tuple = DEFAULT_ALPHA_GRID):
    """Quantize every site-mapped leaf of ``params``.

    Returns ``(new_params, report)`` with ``report[path_str]`` holding the
    per-layer chosen α and losses (empty for RTN).
    """
    new_params = params
    report = {}
    for path, site_key in site_map.items():
        w = _get_path(params, path)
        stats_site = stats[site_key] if stats is not None else None
        if method == "rtn":
            stat = None
        else:
            stat = site_stat_for_method(method, stats_site["mean_abs"],
                                        gamma=gamma, window=window)
        new_leaf, rep = _quantize_leaf(w, stat, spec, alpha_grid, loss,
                                       stats_site, mode)
        new_params = _set_path(new_params, path, new_leaf)
        report["/".join(path)] = rep
    return new_params, report


def report_summary(report: dict) -> dict:
    """Aggregate per-site report into scalars for logging/benchmarks."""
    out = {}
    for path, rep in report.items():
        if not rep:
            continue
        loss = float(jnp.mean(rep["loss"]))
        rtn = float(jnp.mean(rep["rtn_loss"]))
        out[path] = {
            "mean_alpha": float(jnp.mean(rep["alpha"])),
            "mean_loss": loss,
            "mean_rtn_loss": rtn,
            "improvement_vs_rtn": (rtn - loss) / max(rtn, 1e-30),
        }
    return out
