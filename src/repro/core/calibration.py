"""Calibration pass: collect per-site activation statistics.

FAQ (like AWQ, unlike GPTQ) needs only full-precision activations, so a
single forward pass over the calibration set yields the statistics for
*every* block at once — including the future-layer statistics FAQ previews.
After this pass, quantization of each layer is independent (layer-parallel;
see DESIGN.md §5).
"""
from __future__ import annotations

from typing import Callable, Iterable

import jax

from .stats import merge_stats


def run_calibration(apply_fn: Callable, params, batches: Iterable) -> dict:
    """Run ``apply_fn(params, batch, collect_stats=True)`` over batches.

    ``apply_fn`` must return ``(logits, aux)`` with ``aux["stats"]`` mapping
    ``site_key -> {"mean_abs": (L, d), "mean_sq": (L, d), "sample": (L, K, d)}``.

    Returns the token-weighted average of the stats across batches.
    """
    acc = None
    acc_tokens = 0.0
    collect = jax.jit(lambda p, b: apply_fn(p, b, collect_stats=True)[1]["stats"])
    for i, batch in enumerate(batches):
        stats = jax.device_get(collect(params, batch))
        tokens = float(_batch_tokens(batch))
        if acc is None:
            acc, acc_tokens = stats, tokens
        else:
            acc = merge_stats(acc, stats, acc_tokens, tokens, batch_index=i)
            acc_tokens += tokens
    if acc is None:
        raise ValueError("empty calibration set")
    return acc


def _batch_tokens(batch) -> int:
    if isinstance(batch, dict):
        leaf = batch.get("tokens", next(iter(batch.values())))
    else:
        leaf = batch
    n = 1
    for s in leaf.shape[:2]:
        n *= s
    return n
