"""RTN / AWQ / FAQ quantization methods.

All three share the group-wise quantizer (:mod:`repro.core.quantizer`);
they differ only in how the per-input-channel smoothing scale ``s`` is
chosen:

* RTN  — no smoothing (``s = 1``).
* AWQ  — ``s = normalize(ā_l ** α)`` with ``ā_l`` the *current layer's*
  mean-|activation| per channel, α grid-searched to minimize the layer's
  quantized-output error.
* FAQ  — identical search, but the statistic is the *future-fused*
  ``ã_l = γ·ā_l + (1-γ)·mean(ā_{l+1..l+j})`` (window-wise preview,
  paper Eq. 4-5).  Pre-searched γ=0.85, j=3 by default; a full (γ, j)
  search is available for the ablation benchmarks (paper Eq. 8).

Loss for the α search (paper Eq. 7): output-MSE of the quantized linear on
calibration activations.  Two estimators are provided:

* ``"sample"`` — exact MSE on a stored token subsample (AWQ reference
  behaviour; default for the small-scale reproduction benchmarks).
* ``"diag"``   — ``Σ E[a_c²]·ΔW_c,·²`` using only per-channel second
  moments (storage O(d) per site; what the distributed large-model path
  uses — see DESIGN.md §5).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .quantizer import QuantSpec, quant_dequant

DEFAULT_ALPHA_GRID = tuple(float(x) for x in jnp.linspace(0.0, 1.0, 21))
PRESEARCHED_GAMMA = 0.85   # paper §3.1
PRESEARCHED_WINDOW = 3     # paper §3.1


# ---------------------------------------------------------------------------
# Scale candidates and search losses
# ---------------------------------------------------------------------------

def normalize_scale(s: jax.Array) -> jax.Array:
    """Geometric-mean-normalize a positive per-channel scale vector.

    Keeps the search scale-invariant (multiplying every channel by a
    constant must not change the quantization) and bounds dynamic range.
    """
    s = jnp.clip(s, 1e-4, None)
    s = s / jnp.exp(jnp.mean(jnp.log(s)))
    return jnp.clip(s, 1e-3, 1e3)


def candidate_scale(a_stat: jax.Array, alpha: jax.Array) -> jax.Array:
    """AWQ-style smoothing scale ``normalize(ā ** α)``."""
    return normalize_scale(jnp.power(jnp.clip(a_stat, 1e-6, None), alpha))


def quant_error(w: jax.Array, spec: QuantSpec,
                act_scale: Optional[jax.Array],
                mean_sq: Optional[jax.Array] = None,
                sample: Optional[jax.Array] = None) -> jax.Array:
    """Output-MSE proxy for quantizing ``w`` with smoothing ``act_scale``."""
    w32 = w.astype(jnp.float32)
    w_hat = quant_dequant(w32, spec, act_scale=act_scale)
    dw = w_hat - w32
    if sample is not None:
        err = sample.astype(jnp.float32) @ dw
        return jnp.mean(err * err)
    assert mean_sq is not None, "need mean_sq for diag loss"
    return jnp.sum(mean_sq[:, None] * dw * dw) / dw.shape[1]


class SearchResult(NamedTuple):
    act_scale: jax.Array      # (n_in,) chosen smoothing scale (1.0 for RTN)
    alpha: jax.Array          # () chosen exponent
    loss: jax.Array           # () loss at the chosen scale
    rtn_loss: jax.Array       # () loss without smoothing (for reporting)


@partial(jax.jit, static_argnames=("spec", "alpha_grid"))
def search_alpha(w: jax.Array, a_stat: jax.Array, spec: QuantSpec,
                 alpha_grid: tuple = DEFAULT_ALPHA_GRID,
                 mean_sq: Optional[jax.Array] = None,
                 sample: Optional[jax.Array] = None) -> SearchResult:
    """Grid-search α minimizing the quantized-output error for one site.

    Sequential (``lax.map``) over the grid so peak memory stays at one
    weight copy regardless of grid size.
    """
    grid = jnp.asarray(alpha_grid, dtype=jnp.float32)

    def loss_at(alpha):
        s = candidate_scale(a_stat, alpha)
        return quant_error(w, spec, s, mean_sq=mean_sq, sample=sample)

    losses = jax.lax.map(loss_at, grid)
    idx = jnp.argmin(losses)
    best_alpha = grid[idx]
    best_scale = candidate_scale(a_stat, best_alpha)
    rtn_loss = quant_error(w, spec, None, mean_sq=mean_sq, sample=sample)
    return SearchResult(act_scale=best_scale, alpha=best_alpha,
                        loss=losses[idx], rtn_loss=rtn_loss)


# ---------------------------------------------------------------------------
# FAQ: window-wise future preview (paper Eq. 4-5)
# ---------------------------------------------------------------------------

def window_preview(stats: jax.Array, window: int) -> jax.Array:
    """``pvw[l] = mean(stats[l+1 .. min(l+window, L-1)])`` along axis 0.

    ``stats`` is (L, d): the same linear site across the L blocks of a
    stack.  The window clamps at the last block; the last block itself has
    no future and returns its own statistic (caller fuses with γ, which
    then degenerates to plain AWQ there — see DESIGN.md §1).
    """
    L = stats.shape[0]
    l = jnp.arange(L)
    hi = jnp.minimum(l + window, L - 1)          # inclusive upper index
    count = (hi - l).astype(stats.dtype)          # 0 for the last block
    # Direct shift-and-mask sum over the (small, j <= 4) window — a cumsum
    # difference here loses bits to cancellation, pushing the "mean" outside
    # the window's [min, max]; this form is exact for window=1.
    window_sum = jnp.zeros_like(stats)
    for j in range(1, window + 1):
        shifted = jnp.roll(stats, -j, axis=0)     # row l holds stats[l+j]
        in_window = (l + j <= hi)[:, None]
        window_sum = window_sum + jnp.where(in_window, shifted, 0.0)
    safe = jnp.maximum(count, 1.0)[:, None]
    pvw = window_sum / safe
    return jnp.where(count[:, None] > 0, pvw, stats)


def fuse_stats(stats: jax.Array, gamma: float, window: int) -> jax.Array:
    """Paper Eq. 5: ``ã = γ·ā + (1-γ)·ā_pvw`` per layer (axis 0 = layer)."""
    pvw = window_preview(stats, window)
    return gamma * stats + (1.0 - gamma) * pvw


# ---------------------------------------------------------------------------
# Per-site entry points, vmapped over the layer axis by callers
# ---------------------------------------------------------------------------

def site_stat_for_method(method: str, mean_abs: jax.Array,
                         gamma: float = PRESEARCHED_GAMMA,
                         window: int = PRESEARCHED_WINDOW) -> Optional[jax.Array]:
    """The (L, d) statistic each method feeds to the α search.

    Returns None for RTN (no smoothing search at all).
    """
    if method == "rtn":
        return None
    if method == "awq":
        return mean_abs
    if method == "faq":
        return fuse_stats(mean_abs, gamma=gamma, window=window)
    raise ValueError(f"unknown method {method!r}")


def full_search_faq(w_stack: jax.Array, mean_abs: jax.Array, spec: QuantSpec,
                    gammas=(0.6, 0.7, 0.8, 0.85, 0.9, 0.95),
                    windows=(1, 2, 3, 4),
                    alpha_grid: tuple = DEFAULT_ALPHA_GRID,
                    mean_sq: Optional[jax.Array] = None,
                    sample: Optional[jax.Array] = None):
    """Paper Eq. 8: joint (γ, j, α) search, per layer.

    ``w_stack`` (L, n_in, n_out); returns per-layer best
    (act_scale (L, n_in), gamma (L,), window (L,), alpha (L,), loss (L,)).
    Python loop over the small (γ, j) grid; α search is jitted per combo.
    """
    L = w_stack.shape[0]
    vsearch = jax.vmap(
        lambda w, a, msq, smp: search_alpha(w, a, spec, alpha_grid,
                                            mean_sq=msq, sample=smp))
    msq = mean_sq if mean_sq is not None else jnp.ones_like(mean_abs)
    best = None
    for gamma in gammas:
        for window in windows:
            fused = fuse_stats(mean_abs, gamma, window)
            if sample is not None:
                res = jax.vmap(lambda w, a, smp: search_alpha(
                    w, a, spec, alpha_grid, sample=smp))(w_stack, fused, sample)
            else:
                res = vsearch(w_stack, fused, msq, None)
            cand = dict(act_scale=res.act_scale, alpha=res.alpha,
                        loss=res.loss,
                        gamma=jnp.full((L,), gamma, jnp.float32),
                        window=jnp.full((L,), window, jnp.int32))
            if best is None:
                best = cand
            else:
                take = cand["loss"] < best["loss"]
                best = {
                    k: jnp.where(take.reshape((-1,) + (1,) * (v.ndim - 1)),
                                 cand[k], v)
                    for k, v in best.items()
                }
    return best
