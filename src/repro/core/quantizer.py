"""Group-wise low-bit weight quantization primitives.

Conventions
-----------
Weights are stored ``(n_in, n_out)`` so that a linear layer computes
``y = x @ W``.  Quantization groups run along the *input-channel* axis
(axis 0), matching AWQ's deployment format: each group of ``group_size``
input channels in each output column shares one (scale, zero) pair.

The paper ("Enhancing Post-Training Quantization via Future Activation
Awareness") adopts **asymmetric** quantization; symmetric is kept as an
option for ablations.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "QuantSpec",
    "QuantizedTensor",
    "effective_group_size",
    "quantize_groupwise",
    "dequantize_groupwise",
    "quant_dequant",
    "pack_codes",
    "unpack_codes",
]


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static description of a weight-quantization format."""

    bits: int = 4
    group_size: int = 128
    symmetric: bool = False  # paper uses asymmetric quantization

    @property
    def levels(self) -> int:
        return 2 ** self.bits

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1)) if self.symmetric else 0

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1 if self.symmetric else 2 ** self.bits - 1


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """A group-wise quantized 2-D weight.

    ``codes``   uint8, either unpacked ``(n_in, n_out)`` or packed
                ``(n_in // 2, n_out)`` (two 4-bit codes per byte) when
                ``packed`` is True.
    ``scale``   f32 ``(n_groups, n_out)``.
    ``zero``    f32 ``(n_groups, n_out)`` (zero-point, already in code units).
    ``act_scale`` optional f32 ``(n_in,)`` AWQ/FAQ per-channel smoothing
                scale *s*: the stored codes quantize ``W * s[:, None]`` and
                the runtime computes ``(x / s) @ deq(codes)``.
    """

    codes: jax.Array
    scale: jax.Array
    zero: jax.Array
    spec: QuantSpec
    n_in: int
    packed: bool
    act_scale: Optional[jax.Array] = None

    def tree_flatten(self):
        children = (self.codes, self.scale, self.zero, self.act_scale)
        aux = (self.spec, self.n_in, self.packed)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scale, zero, act_scale = children
        spec, n_in, packed = aux
        return cls(codes=codes, scale=scale, zero=zero, spec=spec,
                   n_in=n_in, packed=packed, act_scale=act_scale)

    @property
    def shape(self):
        return (self.n_in, self.codes.shape[-1])


def effective_group_size(n_in: int, group_size: int) -> int:
    """Largest divisor of ``n_in`` that is <= the requested group size.

    Keeps group-wise quantization well-defined for channel counts that are
    not multiples of 128 (e.g. hymba's d_model=1600 -> groups of 100).
    """
    if group_size <= 0 or group_size >= n_in:
        return n_in
    if n_in % group_size == 0:
        return group_size
    for g in range(group_size, 0, -1):
        if n_in % g == 0:
            return g
    return 1


def _group_minmax(w: jax.Array, g: int):
    """w: (n_in, n_out) -> per-(group, col) min/max, shapes (n_groups, n_out)."""
    n_in, n_out = w.shape
    wg = w.reshape(n_in // g, g, n_out)
    return wg.min(axis=1), wg.max(axis=1)


def _affine_params(w: jax.Array, spec: QuantSpec, g: int, eps: float = 1e-8):
    """Per-(group, col) scale/zero for the given spec."""
    lo, hi = _group_minmax(w, g)
    if spec.symmetric:
        amax = jnp.maximum(jnp.abs(lo), jnp.abs(hi))
        scale = jnp.maximum(amax / spec.qmax, eps)
        zero = jnp.zeros_like(scale)
    else:
        # Asymmetric: range [lo, hi] -> [0, 2^b - 1]; include 0 in range so
        # exact zeros stay exact (standard practice).
        lo = jnp.minimum(lo, 0.0)
        hi = jnp.maximum(hi, 0.0)
        scale = jnp.maximum((hi - lo) / (spec.levels - 1), eps)
        zero = jnp.round(-lo / scale)
    return scale, zero


def quantize_groupwise(
    w: jax.Array,
    spec: QuantSpec,
    act_scale: Optional[jax.Array] = None,
    pack: bool = False,
) -> QuantizedTensor:
    """Quantize ``w`` (optionally pre-scaled by ``act_scale``) group-wise."""
    w = w.astype(jnp.float32)
    if act_scale is not None:
        w = w * act_scale[:, None].astype(jnp.float32)
    n_in, n_out = w.shape
    g = effective_group_size(n_in, spec.group_size)
    scale, zero = _affine_params(w, spec, g)
    s_full = jnp.repeat(scale, g, axis=0)
    z_full = jnp.repeat(zero, g, axis=0)
    codes = jnp.clip(jnp.round(w / s_full) + z_full, spec.qmin, spec.qmax)
    if spec.symmetric:
        # store with bias so uint8 can hold it
        codes = codes - spec.qmin
        zero = zero - spec.qmin
    codes = codes.astype(jnp.uint8)
    if pack:
        codes = pack_codes(codes, spec.bits)
    return QuantizedTensor(codes=codes, scale=scale, zero=zero, spec=spec,
                           n_in=n_in, packed=pack, act_scale=act_scale)


def dequantize_groupwise(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_groupwise` (up to rounding).

    Returns the *smoothed-domain* weight ``deq(codes)``; callers holding an
    ``act_scale`` must divide rows by it (or divide activations) to recover
    the original-domain weight.
    """
    codes = qt.codes
    if qt.packed:
        codes = unpack_codes(codes, qt.spec.bits, qt.n_in)
    n_in = qt.n_in
    g = n_in // qt.scale.shape[0]
    s_full = jnp.repeat(qt.scale, g, axis=0)
    z_full = jnp.repeat(qt.zero, g, axis=0)
    return ((codes.astype(jnp.float32) - z_full) * s_full).astype(dtype)


def quant_dequant(w: jax.Array, spec: QuantSpec,
                  act_scale: Optional[jax.Array] = None) -> jax.Array:
    """Fake-quantization: returns the original-domain reconstruction.

    ``deq(Q(W * s)) / s`` — the weight actually realized at inference time.
    """
    orig_dtype = w.dtype
    qt = quantize_groupwise(w, spec, act_scale=act_scale, pack=False)
    w_hat = dequantize_groupwise(qt)
    if act_scale is not None:
        w_hat = w_hat / act_scale[:, None].astype(jnp.float32)
    return w_hat.astype(orig_dtype)


# ---------------------------------------------------------------------------
# Packing.  4-bit codes pack two-per-byte along the input axis: byte i holds
# code[2i] in the low nibble and code[2i+1] in the high nibble.  3-bit codes
# reuse the 4-bit container (storage honesty noted in DESIGN.md); 8-bit is a
# no-op.
# ---------------------------------------------------------------------------

def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    if bits > 4:
        return codes
    n_in = codes.shape[0]
    if n_in % 2 != 0:
        raise ValueError(f"packing needs even n_in, got {n_in}")
    lo = codes[0::2, :].astype(jnp.uint8)
    hi = codes[1::2, :].astype(jnp.uint8)
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_codes(packed: jax.Array, bits: int, n_in: int) -> jax.Array:
    if bits > 4:
        return packed
    lo = packed & jnp.uint8(0x0F)
    hi = (packed >> 4) & jnp.uint8(0x0F)
    out = jnp.stack([lo, hi], axis=1).reshape(n_in, packed.shape[-1])
    return out


def storage_bits(qt: QuantizedTensor) -> float:
    """Average stored bits per weight element (for reporting)."""
    n_in, n_out = qt.shape
    code_bits = qt.codes.size * 8
    meta_bits = (qt.scale.size + qt.zero.size) * 32
    act_bits = 0 if qt.act_scale is None else qt.act_scale.size * 32
    return (code_bits + meta_bits + act_bits) / (n_in * n_out)


def numpy_quant_reference(w: np.ndarray, spec: QuantSpec,
                          act_scale: Optional[np.ndarray] = None) -> np.ndarray:
    """Pure-numpy oracle for quant_dequant (used by property tests)."""
    w = w.astype(np.float64)
    if act_scale is not None:
        w = w * act_scale[:, None].astype(np.float64)
    n_in, n_out = w.shape
    g = effective_group_size(n_in, spec.group_size)
    wg = w.reshape(n_in // g, g, n_out)
    lo, hi = wg.min(axis=1), wg.max(axis=1)
    if spec.symmetric:
        amax = np.maximum(np.abs(lo), np.abs(hi))
        scale = np.maximum(amax / spec.qmax, 1e-8)
        zero = np.zeros_like(scale)
        qmin, qmax = spec.qmin, spec.qmax
    else:
        lo = np.minimum(lo, 0.0)
        hi = np.maximum(hi, 0.0)
        scale = np.maximum((hi - lo) / (spec.levels - 1), 1e-8)
        zero = np.round(-lo / scale)
        qmin, qmax = 0, spec.levels - 1
    s_full = np.repeat(scale, g, axis=0)
    z_full = np.repeat(zero, g, axis=0)
    codes = np.clip(np.round(w / s_full) + z_full, qmin, qmax)
    w_hat = (codes - z_full) * s_full
    if act_scale is not None:
        w_hat = w_hat / act_scale[:, None].astype(np.float64)
    return w_hat
