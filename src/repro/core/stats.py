"""Per-site activation statistics collected during the calibration pass.

Models call :func:`site_stat` on the input activation of every quantizable
linear site.  Inside a ``lax.scan`` over layers the returned dict is a scan
output, so per-layer stats come back stacked ``(L, d)`` for free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Number of token rows kept per site for the exact ("sample") search loss.
SAMPLE_ROWS = 64


def site_stat(x: jax.Array, sample_rows: int = SAMPLE_ROWS) -> dict:
    """Statistics of one site's input activation ``x`` of shape (..., d).

    mean_abs/mean_sq are per-channel over all leading dims; ``sample`` keeps
    the first ``sample_rows`` token rows (deterministic) for the exact loss.
    """
    d = x.shape[-1]
    flat = x.reshape(-1, d).astype(jnp.float32)
    rows = min(sample_rows, flat.shape[0])
    return {
        "mean_abs": jnp.mean(jnp.abs(flat), axis=0),
        "mean_sq": jnp.mean(flat * flat, axis=0),
        "sample": flat[:rows],
    }


def merge_stats(acc: dict, new: dict, acc_weight: float, new_weight: float,
                batch_index: int | None = None) -> dict:
    """Weighted running merge of two stat pytrees (same structure).

    The moment statistics are exact weighted averages.  The ``(K, d)``
    ``sample`` rows are filled round-robin across calibration batches:
    merging batch ``t`` (the ``t``-th batch after the first, so ``t >= 1``)
    replaces the rows at indices ``i % (t + 1) == t`` with batch ``t``'s
    rows — systematic reservoir filling that leaves each of the ``t + 1``
    batches seen so far holding roughly ``K / (t + 1)`` rows.  Keeping
    only batch 0's rows (the old behavior) biased the exact "sample"
    search loss to whatever distribution the first batch happened to have.

    ``batch_index`` is the 1-based merge step; when ``None`` it is
    inferred from the weight ratio (exact for equal-sized batches).
    """
    tot = acc_weight + new_weight
    wa, wb = acc_weight / tot, new_weight / tot
    t = batch_index if batch_index is not None else max(
        1, int(round(acc_weight / new_weight)))

    def merge_site(a, b):
        k = a["sample"].shape[-2]
        take_new = (jnp.arange(k) % (t + 1)) == t
        return {
            "mean_abs": wa * a["mean_abs"] + wb * b["mean_abs"],
            "mean_sq": wa * a["mean_sq"] + wb * b["mean_sq"],
            "sample": jnp.where(take_new[:, None], b["sample"], a["sample"]),
        }

    return {k: merge_site(acc[k], new[k]) for k in acc}
