"""Per-site activation statistics collected during the calibration pass.

Models call :func:`site_stat` on the input activation of every quantizable
linear site.  Inside a ``lax.scan`` over layers the returned dict is a scan
output, so per-layer stats come back stacked ``(L, d)`` for free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Number of token rows kept per site for the exact ("sample") search loss.
SAMPLE_ROWS = 64


def site_stat(x: jax.Array, sample_rows: int = SAMPLE_ROWS) -> dict:
    """Statistics of one site's input activation ``x`` of shape (..., d).

    mean_abs/mean_sq are per-channel over all leading dims; ``sample`` keeps
    the first ``sample_rows`` token rows (deterministic) for the exact loss.
    """
    d = x.shape[-1]
    flat = x.reshape(-1, d).astype(jnp.float32)
    rows = min(sample_rows, flat.shape[0])
    return {
        "mean_abs": jnp.mean(jnp.abs(flat), axis=0),
        "mean_sq": jnp.mean(flat * flat, axis=0),
        "sample": flat[:rows],
    }


def merge_stats(acc: dict, new: dict, acc_weight: float, new_weight: float) -> dict:
    """Weighted running merge of two stat pytrees (same structure)."""
    tot = acc_weight + new_weight
    wa, wb = acc_weight / tot, new_weight / tot

    def merge_site(a, b):
        return {
            "mean_abs": wa * a["mean_abs"] + wb * b["mean_abs"],
            "mean_sq": wa * a["mean_sq"] + wb * b["mean_sq"],
            "sample": a["sample"],  # keep the first batch's subsample
        }

    return {k: merge_site(acc[k], new[k]) for k in acc}
