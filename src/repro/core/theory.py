"""Numeric verification of the paper's Theorem 1 (FAQ error < AWQ error).

Theorem 1 (paper §2.3) asserts that, under (i) a dominant activation
channel in the current layer plus persistently-important weight positions
in subsequent layers, and (ii) AWQ's scale rule ``s = a^c``, the fused
future-aware scale ``Σ_l γ^l (a_l)^c`` yields a smaller quantized-output
error than the current-layer-only scale.

The theorem is a constructed scenario, not a universal inequality; the
mechanism that makes it hold (and that drives the paper's empirical
results, especially Table 3's variance reduction) is:

* channel importance is *persistent across depth* (the residual stream
  carries the same dominant channels forward), so future-layer statistics
  are correlated, independently-noised observations of the same underlying
  importance vector;
* the per-layer statistic estimated from a small/biased calibration set is
  noisy; fusing a window of future layers is a shrinkage estimator with
  lower variance, so the chosen scale is closer to the true-distribution
  optimum with high probability.

:func:`theorem1_check` builds exactly this scenario — persistent lognormal
channel importances with one strong outlier channel, per-layer jitter, a
tiny calibration sample per layer — and evaluates the realized
quantization error **on the true activation distribution** for the AWQ
scale (layer-i statistic only) vs the FAQ scale (window-fused statistic).
Across seeds δ_FAQ < δ_AWQ in ≳90% of draws (see tests/test_theory.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .methods import candidate_scale, fuse_stats
from .quantizer import QuantSpec, quant_dequant


class Theorem1Result(NamedTuple):
    delta_awq: jax.Array
    delta_faq: jax.Array


ALPHAS = (0.0, 0.15, 0.3, 0.45, 0.6, 0.75, 0.9)


def _true_error(a_true: jax.Array, w: jax.Array, stat: jax.Array,
                calib_sample: jax.Array, spec: QuantSpec) -> jax.Array:
    """α chosen on the (noisy) calibration loss; error scored on truth."""
    best_loss, best_true = jnp.inf, jnp.inf
    for alpha in ALPHAS:
        s = candidate_scale(stat, alpha)
        w_hat = quant_dequant(w, spec, act_scale=s)
        dw = w_hat - w
        cal_loss = jnp.linalg.norm(calib_sample @ dw)
        true_err = jnp.linalg.norm(a_true @ dw)
        pick = cal_loss < best_loss
        best_loss = jnp.where(pick, cal_loss, best_loss)
        best_true = jnp.where(pick, true_err, best_true)
    return best_true


def theorem1_check(key, n: int = 256, n_out: int = 256,
                   n_future: int = 3, t_calib: int = 8,
                   gamma: float = 0.85,
                   spec: QuantSpec = QuantSpec(bits=3, group_size=128),
                   ) -> Theorem1Result:
    ks = jax.random.split(key, 8 + n_future)
    # persistent channel importances + one dominant outlier channel (thm (i))
    chan = jnp.exp(jax.random.normal(ks[0], (n,)) * 1.2)
    chan = chan.at[0].mul(20.0)
    w = jax.random.normal(ks[1], (n, n_out)) * 0.1
    a_true = jax.random.normal(ks[2], (2048, n)) * chan

    # per-layer noisy calibration statistics (current + futures)
    stats = []
    for l in range(1 + n_future):
        jitter = jnp.exp(jax.random.normal(ks[3 + l], (n,)) * 0.4)
        a_l = jax.random.normal(jax.random.fold_in(ks[7], l),
                                (t_calib, n)) * (chan * jitter)
        stats.append(jnp.mean(jnp.abs(a_l), axis=0))
    stats = jnp.stack(stats)

    calib_sample = jax.random.normal(ks[-1], (t_calib, n)) * stats[0]

    s_awq_stat = stats[0]
    s_faq_stat = fuse_stats(stats, gamma=gamma, window=n_future)[0]

    return Theorem1Result(
        delta_awq=_true_error(a_true, w, s_awq_stat, calib_sample, spec),
        delta_faq=_true_error(a_true, w, s_faq_stat, calib_sample, spec),
    )


def theorem1_win_rate(n_seeds: int = 16, **kw) -> float:
    """Fraction of seeds where δ_FAQ < δ_AWQ (used by tests + benchmarks)."""
    wins = 0
    for seed in range(n_seeds):
        r = theorem1_check(jax.random.PRNGKey(seed), **kw)
        wins += bool(r.delta_faq < r.delta_awq)
    return wins / n_seeds
