"""Deterministic, shardable, resumable synthetic data pipeline."""
from .synthetic import DataConfig, SyntheticLM, calibration_batches
