"""Deterministic synthetic LM data pipeline.

A fixed random bigram ("structured Zipf") language: a seeded transition
matrix over the vocab gives the data real learnable structure, so tiny
LMs trained here reach meaningfully-different perplexities and the
quantization benchmarks (paper-table analogs) measure something real.

Properties needed at scale and provided here:
* **index-addressable**: sequence ``i`` depends only on ``(seed, i)`` —
  no shared iterator state, so any host can materialize any shard.
* **shardable**: host ``h`` of ``H`` takes indices ``i*H + h``.
* **resumable**: a step counter fully determines the next batch
  (checkpoint restores data position exactly; elastic restarts with a
  different host count re-shard deterministically).
* **bias knob** for the calibration-robustness experiments (paper
  Table 3): ``first_token_range`` restricts the starting state, skewing
  the sampled distribution exactly like topic-biased calibration text.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 512
    seed: int = 1234
    zipf_a: float = 1.2        # unigram skew
    branching: int = 24        # plausible successors per token


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.Generator(np.random.PCG64(cfg.seed))
        v = cfg.vocab_size
        # Zipf-ish unigram prior
        prior = 1.0 / np.arange(1, v + 1) ** cfg.zipf_a
        prior /= prior.sum()
        self.prior_cum = np.cumsum(prior)
        # per-token successor sets with random weights
        succ = rng.integers(0, v, size=(v, cfg.branching))
        w = rng.dirichlet(np.ones(cfg.branching) * 0.5, size=v)
        trans = np.zeros((v, v), np.float64)
        rows = np.repeat(np.arange(v), cfg.branching)
        trans[rows, succ.reshape(-1)] += w.reshape(-1)
        trans += 1e-3 * prior[None, :]     # smoothing mass
        trans /= trans.sum(axis=1, keepdims=True)
        self.trans_cum = np.cumsum(trans, axis=1)

    def sequence(self, index: int, length: int,
                 first_token_range: Optional[Tuple[int, int]] = None
                 ) -> np.ndarray:
        """Deterministic sequence for a global index."""
        rng = np.random.Generator(np.random.PCG64((self.cfg.seed << 20)
                                                  ^ (index + 1)))
        out = np.empty(length, np.int32)
        if first_token_range is not None:
            lo, hi = first_token_range
            out[0] = rng.integers(lo, hi)
        else:
            out[0] = np.searchsorted(self.prior_cum, rng.random())
        u = rng.random(length - 1)
        for t in range(1, length):
            out[t] = np.searchsorted(self.trans_cum[out[t - 1]], u[t - 1])
        return out

    def batch(self, step: int, batch_size: int, length: int,
              host: int = 0, n_hosts: int = 1,
              first_token_range: Optional[Tuple[int, int]] = None) -> dict:
        """Batch for a global step; host h materializes its shard only.

        Index layout is delegated to ``dist.elastic.resume_batch_indices``
        (the single source of truth), so elastic restarts resume the exact
        same global sample stream by construction."""
        from repro.dist.elastic import resume_batch_indices
        idx = resume_batch_indices(step, batch_size, host, n_hosts)
        toks = np.stack([self.sequence(i, length, first_token_range)
                         for i in idx])
        return {"tokens": toks, "labels": toks}

    def perplexity_upper_bound(self) -> float:
        """Entropy of the true process (nats) -> the floor a perfect model
        can reach; useful to sanity-check training."""
        # H(next | prev) under the stationary-ish prior
        trans = np.diff(np.concatenate([np.zeros((self.cfg.vocab_size, 1)),
                                        self.trans_cum], axis=1), axis=1)
        prior = np.diff(np.concatenate([[0.0], self.prior_cum]))
        h = -np.sum(prior[:, None] * trans * np.log(np.maximum(trans, 1e-12)))
        return float(np.exp(h))


def calibration_batches(data: SyntheticLM, n_samples: int, length: int,
                        batch_size: int = 8, biased: bool = False,
                        seed_offset: int = 10_000_000):
    """Calibration set of ``n_samples`` sequences (disjoint from training
    indices via a large offset).  ``biased=True`` restricts start states,
    reproducing paper-Table-3-style calibration bias."""
    rng_range = (0, max(2, data.cfg.vocab_size // 64)) if biased else None
    batches = []
    i = 0
    while i < n_samples:
        bs = min(batch_size, n_samples - i)
        toks = np.stack([data.sequence(seed_offset + i + j, length, rng_range)
                         for j in range(bs)])
        batches.append({"tokens": toks})
        i += bs
    return batches
