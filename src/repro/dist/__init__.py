"""Distribution subsystem: sharding rules, checkpointing, elastic re-mesh.

Three small, orthogonal modules (contracts in DESIGN.md §6):

* :mod:`repro.dist.sharding`   — logical-axis -> mesh-axis rule tables and
  the ``shard_hint`` / ``axis_rules`` context machinery every model uses.
* :mod:`repro.dist.checkpoint` — atomic directory checkpoints with async
  writes, retention GC and dtype-preserving restore.
* :mod:`repro.dist.elastic`    — mesh re-planning after host loss and
  deterministic data-pipeline resume indices.
"""
from . import checkpoint, elastic, sharding  # noqa: F401
