"""Shared pytree key-path walking for prefix/annotation trees.

Both :mod:`repro.dist.sharding` (logical-axis annotation trees) and
:mod:`repro.dist.checkpoint` (NamedSharding prefix trees) walk a
user-supplied side tree along ``tree_flatten_with_path`` key paths; this
is the one implementation of the key normalization and descent.
"""
from __future__ import annotations


def path_key(entry):
    """The plain dict-key / index / field-name behind a pytree key entry
    (DictKey.key, SequenceKey.idx, FlattenedIndexKey.key, GetAttrKey.name
    — or the entry itself for plain keys)."""
    for attr in ("key", "idx", "name"):
        if hasattr(entry, attr):
            return getattr(entry, attr)
    return entry


def descend(node, path, is_leaf):
    """Walk ``node`` along ``path``, stopping early at ``is_leaf`` nodes.

    Returns the reached node — the caller decides whether it is a valid
    leaf — or ``None`` when the path leaves the tree (missing key, wrong
    container kind), which every caller treats as 'no annotation'.
    """
    for k in path:
        if node is None or is_leaf(node):
            break
        key = path_key(k)
        if isinstance(node, dict):
            node = node.get(key)
        elif isinstance(node, (list, tuple)):
            node = node[key] if isinstance(key, int) \
                and 0 <= key < len(node) else None
        elif isinstance(key, str):
            node = getattr(node, key, None)
        else:
            return None
    return node
