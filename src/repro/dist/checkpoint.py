"""Atomic directory checkpoints for arbitrary pytrees.

Layout: ``<dir>/step_XXXXXXXX/{manifest.json, data.bin}``.  Writes land
in a per-writer ``step_XXXXXXXX.<host>-<pid>-<n>.tmp`` scratch directory
and are renamed into place, so a reader (or :func:`latest_step`) never
observes a partial checkpoint, a crash mid-save leaves the previous
step as the newest complete one, and concurrent saves of the same step
cannot interleave their files (last completed rename wins).  Any save or
:func:`latest_step` sweeps crashed writers' ``.tmp`` dirs — recognized
by a dead pid of *this* host in the name — so they cannot leak disk, and
*recovers* (promotes) a dead writer's tmp that holds the only complete
copy of its step; live writers (this process's registry, this host's
live pids) and other hosts' tmps are never touched.  In multi-process
runs only process 0 writes (the host snapshot is a collective) —
DESIGN.md §6.2.  Restore is *target-directed*: the caller supplies a
pytree of the expected structure and gets the same structure back with
saved values — dtypes are taken from the manifest (bf16 params and int32
counters round-trip exactly), and optimizer NamedTuples re-form because
the target's treedef is reused rather than serialized.

``save_async`` snapshots device arrays to host synchronously (so the
training loop may donate/overwrite them immediately) and performs the
file I/O on a background thread; ``wait_pending`` joins all outstanding
writers and re-raises the first failure.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import socket
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import _tree

_STEP_RE = re.compile(r"^step_(\d{8,})$")   # 8+: steps >= 1e8 grow digits
# writer tmps are step_XXXXXXXX.<host>-<pid>-<n>.tmp; <host> is sanitized
# to contain no "-" so the parse is unambiguous.  A bare step_XXXXXXXX.tmp
# (no owner info, e.g. pre-upgrade leftovers) is always reclaimable.
_TMP_RE = re.compile(r"^(step_\d{8,})(?:\.(.+)-(\d+)-\d+)?\.tmp$")
_RAW_HOST = socket.gethostname() or "host"
# sanitized name + short hash: sanitization maps e.g. "gpu-01" and "gpu_01"
# to the same string, and a collision would let one host pid-check (and
# sweep) another's live tmp on a shared filesystem
_HOST = (re.sub(r"[^A-Za-z0-9_]", "_", _RAW_HOST) + "_"
         + hashlib.md5(_RAW_HOST.encode()).hexdigest()[:8])
_PENDING: list = []
_PENDING_LOCK = threading.Lock()
_ACTIVE_TMPS: set = set()
_ACTIVE_LOCK = threading.Lock()
_TMP_COUNTER = iter(range(1 << 62))


def _step_name(step: int) -> str:
    return f"step_{step:08d}"


def _leaf_key(path) -> str:
    return jax.tree_util.keystr(path)


def _check_keep(keep: Optional[int]):
    if keep is not None and keep < 1:
        raise ValueError(f"keep must be >= 1 (got {keep}); the checkpoint "
                         f"just written always survives GC")


def _host_tree(tree):
    """Snapshot every leaf to host memory.  Leaves sharded across
    *processes* are allgathered first (a collective — every process must
    call this), so the snapshot is the full global value; process 0 then
    does the writing (see save/save_async)."""
    def get(x):
        if isinstance(x, jax.Array) and not x.is_fully_addressable:
            from jax.experimental import multihost_utils
            x = multihost_utils.process_allgather(x, tiled=True)
        return np.asarray(jax.device_get(x))

    return jax.tree_util.tree_map(get, tree)


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True           # exists, different user
    return True


def _proc_start_time(pid: int) -> Optional[float]:
    """Unix epoch start time of ``pid`` (Linux /proc; None if unknowable)."""
    try:
        with open(f"/proc/{pid}/stat") as f:
            # starttime is field 22 (1-indexed); split after the ')' that
            # ends comm so spaces in the process name can't shift fields
            ticks = int(f.read().rsplit(")", 1)[1].split()[19])
        with open("/proc/stat") as f:
            btime = next(int(line.split()[1]) for line in f
                         if line.startswith("btime"))
        return btime + ticks / os.sysconf("SC_CLK_TCK")
    except (OSError, ValueError, IndexError, StopIteration):
        return None


def _writer_alive(pid: int, tmp_mtime: float) -> bool:
    """Is the tmp's recorded writer pid still that writer?  A pid that is
    alive but *started after the tmp was created* was recycled (e.g.
    after a reboot) — the original writer is dead and its tmp is fair
    game for sweep/recovery."""
    if not _pid_alive(pid):
        return False
    start = _proc_start_time(pid)
    return start is None or start <= tmp_mtime + 1.0  # 1s clock slack


def _reclaim_stale_tmps(ckpt_dir: str):
    """Remove crashed-save scratch dirs for *any* step (they are full
    checkpoint size; leaking them until that exact step is re-saved could
    fill the disk) — but never an in-flight writer's tmp:

    * this process's live writers are registered in ``_ACTIVE_TMPS``
      *before* their mkdir, so membership is checked per-path at deletion
      time (no snapshot TOCTOU);
    * this host's other processes are recognized by the pid encoded in
      the tmp name and skipped while that pid is alive;
    * other hosts' tmps (shared checkpoint filesystem) are never touched
      — a machine-local pid check says nothing about them."""
    for d in os.listdir(ckpt_dir):
        m = _TMP_RE.match(d)
        if not m:
            continue
        path = os.path.join(ckpt_dir, d)
        with _ACTIVE_LOCK:
            if path in _ACTIVE_TMPS:
                continue
        host, pid = m.group(2), m.group(3)
        if host is not None:
            if host != _HOST:
                continue       # another machine's writer: liveness of its
                               # pid is unknowable here, never touch it
            pid = int(pid)
            try:
                mtime = os.stat(path).st_mtime
            except OSError:
                continue       # vanished under us (racing reclaimer)
            if pid != os.getpid() and _writer_alive(pid, mtime):
                continue
        # A dead writer's tmp that holds a *complete* checkpoint is a
        # retired-aside dir from a re-save that crashed between its two
        # renames (or a crash after the manifest landed).  If the step has
        # no final dir, that tmp is the only surviving copy — recover it
        # instead of sweeping it.
        final = os.path.join(ckpt_dir, m.group(1))
        if not os.path.isdir(final) and _manifest_ok(path):
            try:
                os.replace(path, final)
                continue
            except OSError:
                pass           # lost the race to another recoverer
        shutil.rmtree(path, ignore_errors=True)


def _manifest_ok(path: str) -> bool:
    """True iff ``path`` holds a parseable manifest (a kill mid-manifest
    write must not let recovery promote a corrupt checkpoint)."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            json.load(f)
        return True
    except (OSError, ValueError):
        return False


def _write_dir(ckpt_dir: str, step: int, host_tree, keep: Optional[int],
               fault_hook=None):
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, _step_name(step))
    _reclaim_stale_tmps(ckpt_dir)
    # per-writer unique tmp: concurrent saves of the same step never share
    # a scratch directory, so a complete checkpoint is always one writer's
    # whole output (last os.replace wins)
    tmp = f"{final}.{_HOST}-{os.getpid()}-{next(_TMP_COUNTER)}.tmp"
    with _ACTIVE_LOCK:
        _ACTIVE_TMPS.add(tmp)
    os.makedirs(tmp)
    try:
        leaves = jax.tree_util.tree_flatten_with_path(host_tree)[0]
        manifest = []
        with open(os.path.join(tmp, "data.bin"), "wb") as f:
            offset = 0
            for path, leaf in leaves:
                arr = np.asarray(leaf)
                buf = arr.tobytes()
                f.write(buf)
                manifest.append({"key": _leaf_key(path),
                                 "dtype": str(arr.dtype),
                                 "shape": list(arr.shape),
                                 "offset": offset,
                                 "nbytes": len(buf)})
                offset += len(buf)
            f.flush()
            os.fsync(f.fileno())
        if fault_hook is not None:
            # fault-injection seam (serve/faults.py): raises between the
            # data write and manifest promotion — the window a crash
            # must leave only an unpromoted .tmp, never a half-step
            fault_hook()
        # manifest lands via its own write-then-rename so a kill mid-write
        # leaves only manifest.json.part — a scratch dir counts as a
        # complete checkpoint iff manifest.json exists *and parses*
        with open(os.path.join(tmp, "manifest.json.part"), "w") as f:
            json.dump({"step": step, "leaves": manifest}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(os.path.join(tmp, "manifest.json.part"),
                   os.path.join(tmp, "manifest.json"))
        last_err = None
        for _ in range(3):
            try:
                if os.path.isdir(final):
                    # never destroy a complete checkpoint before its
                    # replacement is in place: retire it aside with an
                    # atomic rename, promote, then drop the retired copy
                    # (a crash between the renames leaves the retired dir
                    # as a reclaimable .tmp, not a lost step)
                    retired = f"{final}.{_HOST}-{os.getpid()}-{next(_TMP_COUNTER)}.tmp"
                    with _ACTIVE_LOCK:
                        _ACTIVE_TMPS.add(retired)
                    try:
                        os.replace(final, retired)
                        try:
                            os.replace(tmp, final)
                        except OSError:
                            os.replace(retired, final)   # roll back
                            raise
                        shutil.rmtree(retired, ignore_errors=True)
                    finally:
                        with _ACTIVE_LOCK:
                            _ACTIVE_TMPS.discard(retired)
                else:
                    os.replace(tmp, final)
                last_err = None
                break
            except OSError as e:   # racing promoter of the same step
                last_err = e
        if last_err is not None:
            raise last_err
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    finally:
        with _ACTIVE_LOCK:
            _ACTIVE_TMPS.discard(tmp)
    if keep is not None:
        _gc(ckpt_dir, keep, step)
    return final


def save(ckpt_dir: str, step: int, tree, *, keep: Optional[int] = None,
         fault_hook=None) -> str:
    """Atomically write ``tree`` as ``<ckpt_dir>/step_XXXXXXXX``.

    ``keep`` (optional) retains only the newest ``keep`` complete
    checkpoints after a successful write.  Returns the checkpoint path.
    ``fault_hook`` (tests) runs between the data write and manifest
    promotion; whatever it raises must leave no half-written step.

    Multi-process runs: every process must call this (the host snapshot
    allgathers process-sharded leaves, a collective), but only process 0
    touches the filesystem — one writer per checkpoint dir.
    """
    _check_keep(keep)
    host_tree = _host_tree(tree)
    if jax.process_index() != 0:
        return os.path.join(ckpt_dir, _step_name(step))
    return _write_dir(ckpt_dir, step, host_tree, keep,
                      fault_hook=fault_hook)


def save_async(ckpt_dir: str, step: int, tree,
               *, keep: Optional[int] = None,
               fault_hook=None) -> threading.Thread:
    """Like :func:`save` but the file I/O runs on a background thread.

    The device->host snapshot happens before returning, so callers may
    mutate/donate the tree immediately.  Join via :func:`wait_pending`.
    """
    _check_keep(keep)
    host_tree = _host_tree(tree)
    if jax.process_index() != 0:         # see save(): process 0 writes
        t = threading.Thread(target=lambda: None, daemon=True)
        t.start()
        return t
    record = {"exc": None}

    def work():
        try:
            _write_dir(ckpt_dir, step, host_tree, keep,
                       fault_hook=fault_hook)
        except BaseException as e:  # re-raised by wait_pending
            record["exc"] = e

    t = threading.Thread(target=work, daemon=True,
                         name=f"ckpt-save-{step}")
    # register and start under one lock: wait_pending swaps the list under
    # the same lock, so it can never join a not-yet-started thread
    with _PENDING_LOCK:
        _PENDING.append((t, record))
        t.start()
    return t


def wait_pending():
    """Block until every outstanding :func:`save_async` finishes; re-raise
    the first writer failure."""
    with _PENDING_LOCK:
        pending, _PENDING[:] = _PENDING[:], []
    first_exc = None
    for t, record in pending:
        t.join()
        if first_exc is None and record["exc"] is not None:
            first_exc = record["exc"]
    if first_exc is not None:
        raise first_exc


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest *complete* checkpoint step in ``ckpt_dir`` (None if none).

    In-flight / crashed ``.tmp`` directories are never candidates, but the
    dead-writer sweep (which *recovers* a complete retired checkpoint whose
    re-save crashed between renames) runs first — a restart must see the
    newest complete step even if it was mid-retirement at crash time, or it
    would silently resume an older lineage."""
    if not os.path.isdir(ckpt_dir):
        return None
    _reclaim_stale_tmps(ckpt_dir)
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := _STEP_RE.match(d)) and
             os.path.isfile(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def _gc(ckpt_dir: str, keep: int, written_step: int):
    # only *complete* checkpoints count toward keep (and only those are
    # deleted): an incomplete manifest-less dir must neither displace a
    # real rollback point nor be destroyed while possibly mid-promote.
    # Retention is scoped to steps <= the one just written, so re-saving
    # an older step (rollback) can never GC its own fresh checkpoint;
    # steps *newer* than the written one are deliberately untouched —
    # whether they are a concurrent forward save or an abandoned lineage
    # is the caller's call, not GC's (a rollback should clear them or
    # restore an explicit step rather than latest_step).
    steps = sorted(int(m.group(1)) for d in os.listdir(ckpt_dir)
                   if (m := _STEP_RE.match(d)) and
                   int(m.group(1)) <= written_step and
                   os.path.isfile(os.path.join(ckpt_dir, d, "manifest.json")))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, _step_name(s)),
                      ignore_errors=True)


def _sharding_at(shardings, path):
    """Walk a (possibly prefix-) tree of NamedShardings along ``path``;
    ``None`` anywhere means 'no placement constraint for this subtree'."""
    node = _tree.descend(shardings, path,
                         lambda n: isinstance(n, jax.sharding.Sharding))
    return node if isinstance(node, jax.sharding.Sharding) else None


def restore(ckpt_dir: str, step: int, target, shardings=None):
    """Read ``step`` back in the shape of ``target`` (a pytree whose
    structure — including NamedTuples — defines the result's structure).

    Dtypes come from the manifest, not the target, so mixed-precision
    trees round-trip bit-exactly.  ``shardings`` (optional) is a matching
    or prefix tree of ``NamedSharding``s: leaves under a sharding are
    device_put with it, subtrees under ``None`` stay unconstrained.
    """
    path = os.path.join(ckpt_dir, _step_name(step))
    man_path = os.path.join(path, "manifest.json")
    for _ in range(40):                 # ~2s bound on the promote window
        if os.path.isfile(man_path):
            break
        if os.path.isdir(ckpt_dir):
            _reclaim_stale_tmps(ckpt_dir)   # may recover a retired ckpt
        if os.path.isfile(man_path):
            break
        # a live writer mid-promote of exactly this step briefly leaves
        # only its (complete) .tmp on disk; wait for its rename to land
        if not (os.path.isdir(ckpt_dir) and any(
                (m := _TMP_RE.match(d)) and m.group(1) == _step_name(step)
                for d in os.listdir(ckpt_dir))):
            break
        time.sleep(0.05)
    if not os.path.isfile(man_path):
        raise FileNotFoundError(f"no checkpoint {_step_name(step)} "
                                f"in {ckpt_dir}")
    with open(man_path) as f:
        manifest = {e["key"]: e for e in json.load(f)["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    out = []
    # per-leaf seek+read: only one host copy of each leaf is ever resident
    # beyond its device buffer (a whole-file blob would double peak RSS on
    # multi-GB checkpoints)
    with open(os.path.join(path, "data.bin"), "rb") as f:
        for leaf_path, _ in flat:
            key = _leaf_key(leaf_path)
            entry = manifest.get(key)
            if entry is None:
                raise KeyError(f"checkpoint {_step_name(step)} has no leaf "
                               f"{key!r} (tree structure changed?)")
            f.seek(entry["offset"])
            buf = f.read(entry["nbytes"])
            arr = np.frombuffer(
                buf, dtype=np.dtype(entry["dtype"]),
                count=int(np.prod(entry["shape"], dtype=np.int64))
            ).reshape(entry["shape"])
            sh = _sharding_at(shardings, leaf_path)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
