"""Elastic re-mesh planning and deterministic data resume.

When a host dies mid-run the job restarts on fewer chips.  Two things
must re-derive deterministically (DESIGN.md §6.3):

* the mesh — :func:`plan_mesh` shrinks the **data** axis (model
  parallelism is fixed by the checkpointed weight layout) to the largest
  grid that fits the surviving chips, never idling a full replica row;
* the data position — :func:`resume_batch_indices` reproduces exactly
  the sequence indices :meth:`repro.data.synthetic.SyntheticLM.batch`
  hands a given ``(step, host, n_hosts)``, so a restart with a different
  host count continues the same global sample stream with no skips or
  repeats.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple


class MeshPlan(NamedTuple):
    """A usable ``pods x data x model`` grid over surviving chips."""
    data: int
    model: int
    pods: int
    used_chips: int
    idle_chips: int
    old_data: Optional[int]

    @property
    def data_scale(self) -> Optional[float]:
        """new/old data-parallel width (per-replica batch rescale factor);
        None when the pre-failure width is unknown."""
        return None if self.old_data is None else self.data / self.old_data


def plan_mesh(chips: int, *, model: int, old_data: Optional[int] = None,
              pods: int = 1) -> MeshPlan:
    """Largest ``pods x data x model`` grid on ``chips`` surviving chips.

    ``model`` (and ``pods``) are fixed — the checkpointed weight shards
    assume them — so only the data axis shrinks: ``data =
    chips // (pods * model)``.  Leftover chips (< pods*model of them, a
    partial replica row) idle until the host is replaced.  Raises
    ``RuntimeError`` when not even one replica fits.
    """
    if model < 1 or pods < 1:
        raise ValueError(f"model={model} and pods={pods} must be >= 1")
    data = chips // (pods * model)
    if data < 1:
        raise RuntimeError(
            f"{chips} chips cannot hold one pods={pods} x model={model} "
            f"replica ({pods * model} chips needed)")
    used = pods * data * model
    return MeshPlan(data=data, model=model, pods=pods, used_chips=used,
                    idle_chips=chips - used, old_data=old_data)


def resume_batch_indices(step: int, batch_per_host: int, host: int,
                         n_hosts: int) -> Tuple[int, ...]:
    """Global sequence indices host ``host`` of ``n_hosts`` draws at
    ``step`` — the exact strided layout of ``SyntheticLM.batch`` (host
    shards interleave so the global batch is invariant to ``n_hosts``)."""
    if not 0 <= host < n_hosts:
        raise ValueError(f"host {host} out of range for n_hosts={n_hosts}")
    base = step * batch_per_host * n_hosts
    return tuple(base + j * n_hosts + host for j in range(batch_per_host))
