"""Logical-axis sharding rules (GSPMD annotations for every code path).

Models annotate tensors with *logical* axis names ("batch", "heads",
"ff", ...); a rule table maps each logical name to zero or more *mesh*
axes.  One table per execution regime:

* :data:`DEFAULT_RULES`        — training / calibration: batch+FSDP over
  ``(pod, data)``, tensor-parallel weights over ``model``.
* :data:`SERVE_PREFILL_RULES`  — prefill additionally sequence-shards
  activations over ``model`` (long prompts; weight layout unchanged).
* :data:`SERVE_DECODE_RULES`   — the 2D-TP decode layout: weights split
  over (data=input-dim, model=output-dim); ``qin: None`` is the explicit
  opt-in marker for the packed-domain transfer constraint in
  :func:`repro.kernels.ops.quant_matmul` (see DESIGN.md §6.1).

The mapping is *best-effort by construction* (DESIGN.md §6.1): a rule is
dropped for a given tensor dimension when the mesh axis is absent from
the active mesh, already used by an earlier dimension of the same tensor
(each mesh axis at most once per spec, earlier dims win), or does not
divide the dimension size (replicate rather than pad).  This is what
lets one model definition lower on a 16x16 pod, a 2x16x16 twin-pod, 8
virtual CPU devices, or a single CPU without edits.
"""
from __future__ import annotations

import contextlib
import logging
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from . import _tree

logger = logging.getLogger(__name__)

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

DEFAULT_RULES = {
    # data / activation axes
    "batch":    ("pod", "data"),
    "seq":      None,
    "embed":    None,
    # weight / head axes (tensor parallel)
    "heads":    "model",
    "kv_heads": "model",
    "kv_seq":   "model",     # fallback when the head count doesn't divide
    "ff":       "model",
    "vocab":    "model",
    "expert":   "model",
    "experts":  "model",     # stacked expert dim in MoE param trees
    "fsdp":     ("pod", "data"),
    # QuantizedTensor children (non-None here = packed-domain constraint
    # in kernels/ops.py stays OFF; see SERVE_DECODE_RULES)
    "qin":      ("pod", "data"),
    "qout":     "model",
    "qgroups":  None,
}

SERVE_PREFILL_RULES = dict(DEFAULT_RULES, seq="model")

SERVE_DECODE_RULES = dict(
    DEFAULT_RULES,
    # qin=None REPLICATES the packed input dim — it is deliberately not
    # "data": kernels/ops.py treats a None "qin" rule as the explicit
    # opt-in to constrain packed weights so cross-device movement happens
    # in the uint8 domain (mapping qin to a mesh axis would turn that
    # branch off, not shard the weights harder).
    qin=None,
)


# ---------------------------------------------------------------------------
# Active-context machinery
# ---------------------------------------------------------------------------

_CTX = threading.local()


def _stack():
    if not hasattr(_CTX, "stack"):
        _CTX.stack = []
    return _CTX.stack


@contextlib.contextmanager
def axis_rules(mesh, rules: Optional[dict] = None):
    """Activate ``(mesh, rules)`` for :func:`shard_hint` /
    :func:`active_rule` in this thread.  Nestable; inner wins."""
    _stack().append((mesh, DEFAULT_RULES if rules is None else rules))
    try:
        yield mesh
    finally:
        _stack().pop()


def active_mesh():
    """The mesh of the innermost :func:`axis_rules` context (or None)."""
    s = _stack()
    return s[-1][0] if s else None


def active_rules() -> dict:
    s = _stack()
    return s[-1][1] if s else DEFAULT_RULES


def active_rule(name: str):
    """The mesh-axis mapping the active rule table gives ``name``."""
    return active_rules().get(name)


@contextlib.contextmanager
def row_parallel():
    """Mark a region whose quantized matmuls are *row-parallel* (weight
    sharded on the input dim, e.g. attention ``wo`` / MLP ``w_down``).

    Under :data:`SERVE_DECODE_RULES` the ``qin: None`` rule arms the
    packed-domain transfer constraint in :func:`repro.kernels.ops
    .quant_matmul`, which forces a *column* layout ``P(None, "model")``
    on every 2-D codes tensor.  For row-parallel sites that layout
    contradicts the placement chosen from ``param_axes()`` and would
    insert a per-layer weight reshard.  Re-binding ``qin`` to ``model``
    inside this context disarms the branch (the rule is no longer None)
    and matches the actual row layout.  No-op without an active mesh or
    when ``qin`` is already bound.
    """
    mesh = active_mesh()
    if mesh is None or active_rule("qin") is not None:
        yield
        return
    with axis_rules(mesh, dict(active_rules(), qin="model")):
        yield


# ---------------------------------------------------------------------------
# Logical axes -> PartitionSpec
# ---------------------------------------------------------------------------

def _mesh_axis_sizes(mesh) -> dict:
    # jax.sharding.Mesh.shape is an OrderedDict {axis: size}; tests use a
    # duck-typed stand-in with a plain dict.
    return dict(mesh.shape)


# Divisibility fallbacks already warned about, keyed on
# (axes, shape, dim, logical name, dropped mesh axes) — silent
# replication during serve should show up in logs exactly once per
# distinct site.  The logical name is part of the key: two sites that
# agree on position and shape but drop a *different* logical axis are
# different warnings, and must not mask each other.
_WARNED_DROPS: set = set()


def _warn_dropped(axes, shape, dim, name, cand, total):
    if shape[dim] == 1:
        return  # replicating a singleton dim loses nothing
    key = (tuple(axes), tuple(shape), dim, name, cand)
    if key in _WARNED_DROPS:
        return
    _WARNED_DROPS.add(key)
    logger.warning(
        "logical_to_spec: replicating dim %d (logical %r, size %d) of "
        "shape %s — mesh axes %s have total size %d which does not divide "
        "it; tensor stays correct but this site is NOT sharded",
        dim, name, shape[dim], tuple(shape), cand, total)


def logical_to_spec(axes: Sequence[Optional[str]], *, shape: Sequence[int],
                    mesh, rules: Optional[dict] = None) -> P:
    """Map per-dimension logical names to a PartitionSpec on ``mesh``.

    ``axes[i]`` names dimension ``i`` of a tensor with concrete ``shape``;
    ``None`` entries replicate.  Rule entries may name one mesh axis or a
    tuple of mesh axes (sharded over their product).  Fallbacks, in order:
    mesh axes absent from ``mesh`` are dropped; mesh axes already claimed
    by an earlier dimension are dropped (each-axis-used-once priority);
    if the surviving axes' product doesn't divide ``shape[i]``, the
    dimension replicates.
    """
    rules = active_rules() if rules is None else rules
    sizes = _mesh_axis_sizes(mesh)
    used: set = set()
    entries = []
    for dim, name in enumerate(axes):
        rule = rules.get(name) if name is not None else None
        if rule is None:
            entries.append(None)
            continue
        cand = (rule,) if isinstance(rule, str) else tuple(rule)
        cand = tuple(a for a in cand if a in sizes and a not in used)
        if not cand:
            entries.append(None)
            continue
        total = 1
        for a in cand:
            total *= sizes[a]
        if shape[dim] % total != 0:
            _warn_dropped(axes, shape, dim, name, cand, total)
            entries.append(None)
            continue
        used.update(cand)
        entries.append(cand[0] if len(cand) == 1 else cand)
    return P(*entries)


def shard_hint(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` under the active mesh; identity when no
    mesh is active (single-process CPU runs, shard_map bodies, tests)."""
    mesh = active_mesh()
    if mesh is None or x.ndim != len(axes):
        return x
    spec = logical_to_spec(axes, shape=x.shape, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Tree-level shardings
# ---------------------------------------------------------------------------

def _axes_at(axes_tree, path):
    """Walk a nested axes tree along a pytree key path; the first
    tuple/list hit is the leaf annotation (stacked-layer params share
    one annotation per site), anything else means 'replicate'."""
    node = _tree.descend(axes_tree, path,
                         lambda n: isinstance(n, (tuple, list)))
    return node if isinstance(node, (tuple, list)) else None


def tree_shardings(mesh, specs, axes_tree, rules: Optional[dict] = None):
    """NamedSharding tree for ``specs`` (arrays or ShapeDtypeStructs) from
    a matching tree of per-dimension logical-axis annotations.

    Paths absent from ``axes_tree`` (or annotated ``None``) replicate.
    Annotations shorter/longer than the leaf rank are padded/truncated
    with ``None`` so scalar extras ("len", "step") never error.
    """
    def one(path, leaf):
        ax = _axes_at(axes_tree, path)
        if ax is None:
            return NamedSharding(mesh, P())
        ax = list(ax)[:len(leaf.shape)]
        ax += [None] * (len(leaf.shape) - len(ax))
        return NamedSharding(mesh, logical_to_spec(ax, shape=leaf.shape,
                                                   mesh=mesh, rules=rules))

    return jax.tree_util.tree_map_with_path(one, specs)


def tree_hint(tree, axes_tree):
    """:func:`shard_hint` over a whole pytree (inside jit): constrain every
    leaf to the spec its ``axes_tree`` annotation resolves to under the
    active mesh/rules.  Identity when no mesh is active.  Used to pin
    cache pytrees to a stable layout across decode steps."""
    mesh = active_mesh()
    if mesh is None or not isinstance(mesh, jax.sharding.Mesh):
        return tree

    def one(path, leaf):
        ax = _axes_at(axes_tree, path)
        if ax is None:
            spec = P()
        else:
            ax = list(ax)[:len(leaf.shape)]
            ax += [None] * (len(leaf.shape) - len(ax))
            spec = logical_to_spec(ax, shape=leaf.shape, mesh=mesh)
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(one, tree)
