"""Pallas TPU kernels (validated in interpret mode on CPU) + jnp oracles."""
from .ops import (decode_attention, decode_attention_q8,
                  paged_decode_attention, paged_decode_attention_q8,
                  quant_error_batch, quant_matmul, quant_matmul_experts)
from .flash_attention import flash_attention_pallas, flash_attention_ref
from .flash_decode import (flash_decode_paged_pallas,
                           flash_decode_paged_q8_pallas,
                           flash_decode_pallas, flash_decode_q8_pallas)
