"""Pallas TPU kernels (validated in interpret mode on CPU) + jnp oracles."""
from .ops import quant_error_batch, quant_matmul, quant_matmul_experts
from .flash_attention import flash_attention_pallas, flash_attention_ref
