"""Pallas TPU kernel: causal flash attention (forward).

Motivation from the roofline iteration log (EXPERIMENTS.md §Perf): after
the sharding fixes, train/prefill cells are memory-term-bound and the
dominant bytes are the attention score matrices — a pure-jnp chunked
attention still round-trips (B, H, Tq, chunk) scores through HBM each
chunk.  This kernel keeps the running max / denominator / output
accumulator in VMEM scratch across the K-block loop, so score traffic
never leaves the chip: HBM bytes drop from O(T²) to O(T·hd).

Layout: q/k/v are (BH, T, hd) — batch and (already-repeated) heads
flattened by the wrapper.  Grid is (BH, nq, nk) with the K axis innermost
("arbitrary"); fully-future K blocks are skipped under causal masking via
pl.when, halving compute for causal runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, bq: int, bk: int, scale: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: skip blocks strictly in the future of every query in the tile
    run = True
    if causal:
        run = (ik * bk) <= (iq * bq + bq - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = 128, bk: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q/k/v: (BH, T, hd) with hd <= 128.  Returns (BH, T, hd)."""
    bh, t, hd = q.shape
    bq = min(bq, t)
    bk = min(bk, t)
    assert t % bq == 0 and t % bk == 0, (t, bq, bk)
    grid = (bh, t // bq, t // bk)
    scale = hd ** -0.5
    return pl.pallas_call(
        functools.partial(_kernel, causal=causal, bq=bq, bk=bk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """Pure-jnp oracle: full masked softmax attention."""
    bh, t, hd = q.shape
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((t, t), bool))
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bts,bsd->btd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
