"""Pallas TPU kernel: causal flash attention (forward).

Motivation from the roofline iteration log (EXPERIMENTS.md §Perf): after
the sharding fixes, train/prefill cells are memory-term-bound and the
dominant bytes are the attention score matrices — a pure-jnp chunked
attention still round-trips (B, H, Tq, chunk) scores through HBM each
chunk.  This kernel keeps the running max / denominator / output
accumulator in VMEM scratch across the K-block loop, so score traffic
never leaves the chip: HBM bytes drop from O(T²) to O(T·hd).

Layout: GQA-grouped — q is (BKH, G, T, hd) against the *unrepeated*
k/v (BKH, T, hd), so the kernel streams each KV head's cache once for
all G query heads in its group instead of re-reading a head-repeated
copy (the prefill analogue of the decode-side GQA rationale: repeating
KV to q-heads replicates the cache and multiplies K/V HBM traffic by
G).  A 3-D q (BH, T, hd) is accepted as the G=1 / MHA layout.  Grid is
(BKH, nq, nk) with the K axis innermost ("arbitrary"); fully-future K
blocks are skipped under causal masking via pl.when, halving compute
for causal runs.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, g: int, bq: int, bk: int, hd: int, scale: float,
            t_valid: int | None):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: skip blocks strictly in the future of every query in the tile
    run = True
    if causal:
        run = (ik * bk) <= (iq * bq + bq - 1)

    @pl.when(run)
    def _block():
        # (G, bq, hd) -> (G*bq, hd): all grouped query heads share this
        # KV head's k/v block, fetched once
        q = q_ref[0].astype(jnp.float32).reshape(g * bq, hd) * scale
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if t_valid is not None:
            # padded tail keys (t not on the block grid) must not attend
            kpos = ik * bk + jax.lax.broadcasted_iota(
                jnp.int32, (g * bq, bk), 1)
            s = jnp.where(kpos < t_valid, s, NEG_INF)
        if causal:
            # row r of the flattened (G, bq) tile is query position
            # iq*bq + r % bq (group index r // bq shares the position)
            r = jax.lax.broadcasted_iota(jnp.int32, (g * bq, bk), 0)
            qpos = iq * bq + r % bq
            kpos = ik * bk + jax.lax.broadcasted_iota(
                jnp.int32, (g * bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).reshape(g, bq, hd).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = 128, bk: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: (BKH, G, T, hd) grouped GQA — or (BH, T, hd) for G=1/MHA —
    against unrepeated k/v (BKH, T, hd) with hd <= 128.  Returns q's
    shape."""
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    bkh, g, t, hd = q.shape
    assert k.shape[0] == bkh and k.shape[1] == t, (q.shape, k.shape)
    bq = min(bq, t)
    bk = min(bk, t)
    # t need not land on the block grid (odd prompt lengths): pad q/k/v
    # up to a common multiple of both block sizes and mask padded key
    # positions inside the kernel; padded query rows are sliced away.
    # When t already divides, t_valid stays None and the lowered kernel
    # is bit-identical to the unpadded build.
    step = bq * bk // math.gcd(bq, bk)
    t_pad = -(-t // step) * step
    t_valid = None
    if t_pad != t:
        pad = ((0, t_pad - t), (0, 0))
        q = jnp.pad(q, ((0, 0), (0, 0)) + pad)
        k = jnp.pad(k, ((0, 0),) + pad)
        v = jnp.pad(v, ((0, 0),) + pad)
        t_valid = t
    grid = (bkh, t_pad // bq, t_pad // bk)
    scale = hd ** -0.5
    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, g=g, bq=bq, bk=bk, hd=hd,
                          scale=scale, t_valid=t_valid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, bq, hd), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, bq, hd), lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bkh, g, t_pad, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * bq, 1), jnp.float32),
            pltpu.VMEM((g * bq, 1), jnp.float32),
            pltpu.VMEM((g * bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    out = out[:, :, :t]
    return out[:, 0] if squeeze else out


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """Pure-jnp oracle: full masked softmax attention."""
    bh, t, hd = q.shape
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((t, t), bool))
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bts,bsd->btd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
