"""Pallas TPU kernel family: split-KV (flash-decoding) decode attention.

Decode attention is the hottest loop of the serving engine: every step,
every layer scores one query position against the whole KV cache.  The
pure-jnp path (now the oracle in :mod:`.ref`) upcasts the entire
``(B, S, KH, hd)`` cache to f32 score matrices in HBM and always pays
for ``max_len`` positions regardless of the slot's live length.  These
kernels fix both:

* **Split-KV with a cross-split combine.**  The grid is
  ``(B, KH, n_splits)`` — each split covers ``bs`` consecutive cache
  positions, computes a local softmax ``(m, l, p·V)`` over its block,
  and the per-split partials are merged by an associative logsumexp
  combine (:func:`_combine`) outside the kernel.  Score matrices never
  round-trip HBM in f32; only the tiny ``(ns, G, hd)`` partials do.
* **Length-aware cost.**  ``cache_len`` is scalar-prefetched (SMEM).
  Splits past a slot's live length skip all compute under ``pl.when``,
  and their BlockSpec index_map clamps to the last live block — Pallas
  skips re-fetching a block whose indices match the previous grid step,
  so HBM traffic *and* FLOPs track ``cache_len``, not ``max_len``.
* **GQA-grouped queries.**  q is reshaped ``(B, KH, G, hd)`` and scored
  against the *unrepeated* cache — the kernel-side analogue of the
  sharding rationale in the jnp oracle (repeating KV to q-heads forces
  an SPMD reshard that replicates the cache in f32).
* **int8 fold** (`*_q8`).  The per-(token, head) scales multiply the
  score matrix / probability weights inside the kernel, so int8 codes
  are consumed in their packed domain and never hit HBM as f32.
* **In-kernel page gather** (`*_paged*`).  The page table is
  scalar-prefetched and the K/V index_maps read physical pages straight
  out of the shared page store — the dense-HBM ``gather_pages``
  round-trip is gone from the decode path.

Layouts are the caches' *native* ones — ``(B, KH, S, hd)`` dense,
``(P, KH, ps, hd)`` paged — so callers no longer transpose the cache
every step.  ``window`` applies the hymba/local-attention sliding mask
(positions ``[cache_len - window, cache_len)``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Kernel bodies (shared between the dense and paged variants: only the
# BlockSpec index maps differ — logical split positions are identical)
# ---------------------------------------------------------------------------

def _decode_body(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                 ks_ref=None, vs_ref=None, *, bs, window, scale):
    """One split: local softmax over ``bs`` cache positions.

    Writes the unnormalized partial ``(p @ V, m, l)``; dead splits (fully
    past ``cache_len`` / fully below the window) write the identity of
    the combine monoid ``(0, -inf, 0)`` without touching the MXU.
    """
    b = pl.program_id(0)
    s = pl.program_id(2)
    length = len_ref[b]
    start = s * bs
    run = start < length
    if window is not None:
        run = jnp.logical_and(run, start + bs > length - window)

    @pl.when(run)
    def _live():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bs, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        sc = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if ks_ref is not None:
            # int8 fold: per-(token, head) K scale into the score row
            sc = sc * jnp.transpose(ks_ref[0, 0])         # (1, bs)
        kpos = start + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        mask = kpos < length
        if window is not None:
            mask = jnp.logical_and(mask, kpos >= length - window)
        sc = jnp.where(mask, sc, NEG_INF)
        m = jnp.max(sc, axis=-1, keepdims=True)           # (G, 1)
        p = jnp.exp(sc - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        if vs_ref is not None:
            # int8 fold: per-(token, head) V scale into the prob weights
            p = p * jnp.transpose(vs_ref[0, 0])
        # hard-zero masked prob columns and V rows: a partial last
        # block's out-of-bounds K/V region is undefined (NaN-filled in
        # interpret mode), and IEEE 0 * NaN = NaN would otherwise leak
        # through the V dot even though exp(-1e30 - m) underflows to 0
        p = jnp.where(mask, p, 0.0)
        v = jnp.where(jnp.transpose(mask), v, 0.0)
        o_ref[0, 0, 0] = jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_ref[0, 0, 0] = m
        l_ref[0, 0, 0] = l

    @pl.when(jnp.logical_not(run))
    def _dead():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)


def _dense_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  bs, window, scale):
    _decode_body(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                 bs=bs, window=window, scale=scale)


def _dense_q8_kernel(len_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
                     o_ref, m_ref, l_ref, *, bs, window, scale):
    _decode_body(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                 ks_ref, vs_ref, bs=bs, window=window, scale=scale)


def _paged_kernel(table_ref, len_ref, q_ref, k_ref, v_ref,
                  o_ref, m_ref, l_ref, *, bs, window, scale):
    del table_ref  # consumed by the index maps
    _decode_body(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                 bs=bs, window=window, scale=scale)


def _paged_q8_kernel(table_ref, len_ref, q_ref, k_ref, ks_ref, v_ref,
                     vs_ref, o_ref, m_ref, l_ref, *, bs, window, scale):
    del table_ref
    _decode_body(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                 ks_ref, vs_ref, bs=bs, window=window, scale=scale)


# ---------------------------------------------------------------------------
# Cross-split combine + index maps
# ---------------------------------------------------------------------------

def _combine(o, m, l):
    """Merge per-split partials: ``(o_i, m_i, l_i)`` over the split axis.

    Standard flash-decoding reduction — with ``M = max_i m_i`` and
    ``w_i = exp(m_i - M)``: ``out = sum(w_i o_i) / sum(w_i l_i)``.  The
    per-split merge is associative, so split order (and dead splits,
    which contribute ``(0, -inf, 0)``) cannot change the result.
    """
    big_m = jnp.max(m, axis=2, keepdims=True)             # (B,KH,1,G,1)
    w = jnp.exp(m - big_m)
    l_tot = jnp.sum(w * l, axis=2)                        # (B,KH,G,1)
    acc = jnp.sum(w * o, axis=2)                          # (B,KH,G,hd)
    return acc / jnp.maximum(l_tot, 1e-30)


def _first_live(len_b, window, bs):
    """Index of the first split the sliding window can reach."""
    return jnp.maximum(len_b - window, 0) // bs


def _last_live(len_b, bs):
    """Index of the last live split (0 when the slot is empty)."""
    return jnp.maximum((len_b + bs - 1) // bs - 1, 0)


def _dense_kv_map(bs, window):
    """Clamp dead splits onto the nearest live block: consecutive grid
    steps with identical block indices are not re-fetched, so cache HBM
    traffic tracks ``cache_len``."""
    def imap(b, h, s, len_ref):
        hi = _last_live(len_ref[b], bs)
        idx = jnp.minimum(s, hi)
        if window is not None:
            lo = _first_live(len_ref[b], window, bs)
            idx = jnp.clip(s, lo, jnp.maximum(hi, lo))
        return (b, h, idx, 0)
    return imap


def _paged_kv_map(ps, window):
    """Like :func:`_dense_kv_map` but the clamped *logical* block index
    goes through the scalar-prefetched page table — the kernel reads
    K/V pages directly from the shared page store."""
    def imap(b, h, s, table_ref, len_ref):
        hi = _last_live(len_ref[b], ps)
        idx = jnp.minimum(s, hi)
        if window is not None:
            lo = _first_live(len_ref[b], window, ps)
            idx = jnp.clip(s, lo, jnp.maximum(hi, lo))
        return (table_ref[b, idx], h, 0, 0)
    return imap


def _out_specs(g, hd):
    def omap(b, h, s, *scalar_refs):
        return (b, h, s, 0, 0)
    return [pl.BlockSpec((1, 1, 1, g, hd), omap),
            pl.BlockSpec((1, 1, 1, g, 1), omap),
            pl.BlockSpec((1, 1, 1, g, 1), omap)]


def _out_shapes(b, kh, ns, g, hd):
    return [jax.ShapeDtypeStruct((b, kh, ns, g, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, kh, ns, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, kh, ns, g, 1), jnp.float32)]


_SEMANTICS = _CompilerParams(
    dimension_semantics=("parallel", "parallel", "arbitrary"))


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("window", "bs", "interpret"))
def flash_decode_pallas(q, k_cache, v_cache, cache_len, *, window=None,
                        bs=128, interpret=True):
    """q: (B, 1, H, hd); caches: (B, KH, S, hd) *native* layout;
    cache_len: (B,) int32.  Returns (B, 1, H, hd)."""
    b, _, h, hd = q.shape
    kh, s = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    qg = q[:, 0].reshape(b, kh, g, hd)
    bs = min(bs, s)
    ns = -(-s // bs)
    lens = jnp.broadcast_to(cache_len, (b,)).astype(jnp.int32)
    kv = _dense_kv_map(bs, window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kh, ns),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b_, h_, s_, lr: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), kv),
            pl.BlockSpec((1, 1, bs, hd), kv),
        ],
        out_specs=_out_specs(g, hd),
    )
    o, m, l = pl.pallas_call(
        functools.partial(_dense_kernel, bs=bs, window=window,
                          scale=hd ** -0.5),
        grid_spec=grid_spec,
        out_shape=_out_shapes(b, kh, ns, g, hd),
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(lens, qg, k_cache, v_cache)
    return _combine(o, m, l).reshape(b, 1, h, hd).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bs", "interpret"))
def flash_decode_q8_pallas(q, k_codes, k_scale, v_codes, v_scale, cache_len,
                           *, window=None, bs=128, interpret=True):
    """int8-KV variant: codes (B, KH, S, hd) int8, scales (B, KH, S, 1)
    f32, folded inside the kernel (codes never dequantize in HBM)."""
    b, _, h, hd = q.shape
    kh, s = k_codes.shape[1], k_codes.shape[2]
    g = h // kh
    qg = q[:, 0].reshape(b, kh, g, hd)
    bs = min(bs, s)
    ns = -(-s // bs)
    lens = jnp.broadcast_to(cache_len, (b,)).astype(jnp.int32)
    kv = _dense_kv_map(bs, window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kh, ns),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b_, h_, s_, lr: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), kv),
            pl.BlockSpec((1, 1, bs, 1), kv),
            pl.BlockSpec((1, 1, bs, hd), kv),
            pl.BlockSpec((1, 1, bs, 1), kv),
        ],
        out_specs=_out_specs(g, hd),
    )
    o, m, l = pl.pallas_call(
        functools.partial(_dense_q8_kernel, bs=bs, window=window,
                          scale=hd ** -0.5),
        grid_spec=grid_spec,
        out_shape=_out_shapes(b, kh, ns, g, hd),
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(lens, qg, k_codes, k_scale, v_codes, v_scale)
    return _combine(o, m, l).reshape(b, 1, h, hd).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def flash_decode_paged_pallas(q, k_store, v_store, page_table, cache_len, *,
                              window=None, interpret=True):
    """Paged variant: stores (P, KH, ps, hd); page_table (B, NP) int32
    physical ids (unmapped entries point at the pinned trash page).
    One split per page; the table is scalar-prefetched so the K/V
    index_maps gather pages in-kernel."""
    b, _, h, hd = q.shape
    kh, ps = k_store.shape[1], k_store.shape[2]
    g = h // kh
    qg = q[:, 0].reshape(b, kh, g, hd)
    n_pages = page_table.shape[1]
    lens = jnp.broadcast_to(cache_len, (b,)).astype(jnp.int32)
    table = page_table.astype(jnp.int32)
    kv = _paged_kv_map(ps, window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kh, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda b_, h_, s_, tr, lr: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd), kv),
            pl.BlockSpec((1, 1, ps, hd), kv),
        ],
        out_specs=_out_specs(g, hd),
    )
    o, m, l = pl.pallas_call(
        functools.partial(_paged_kernel, bs=ps, window=window,
                          scale=hd ** -0.5),
        grid_spec=grid_spec,
        out_shape=_out_shapes(b, kh, n_pages, g, hd),
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(table, lens, qg, k_store, v_store)
    return _combine(o, m, l).reshape(b, 1, h, hd).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def flash_decode_paged_q8_pallas(q, k_codes, k_scale, v_codes, v_scale,
                                 page_table, cache_len, *, window=None,
                                 interpret=True):
    """Paged int8-KV variant: scale stores (P, KH, ps, 1) are paged
    alongside the codes, gathered by the same table and folded
    in-kernel."""
    b, _, h, hd = q.shape
    kh, ps = k_codes.shape[1], k_codes.shape[2]
    g = h // kh
    qg = q[:, 0].reshape(b, kh, g, hd)
    n_pages = page_table.shape[1]
    lens = jnp.broadcast_to(cache_len, (b,)).astype(jnp.int32)
    table = page_table.astype(jnp.int32)
    kv = _paged_kv_map(ps, window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kh, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda b_, h_, s_, tr, lr: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd), kv),
            pl.BlockSpec((1, 1, ps, 1), kv),
            pl.BlockSpec((1, 1, ps, hd), kv),
            pl.BlockSpec((1, 1, ps, 1), kv),
        ],
        out_specs=_out_specs(g, hd),
    )
    o, m, l = pl.pallas_call(
        functools.partial(_paged_q8_kernel, bs=ps, window=window,
                          scale=hd ** -0.5),
        grid_spec=grid_spec,
        out_shape=_out_shapes(b, kh, n_pages, g, hd),
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(table, lens, qg, k_codes, k_scale, v_codes, v_scale)
    return _combine(o, m, l).reshape(b, 1, h, hd).astype(q.dtype)
