"""Jit'd dispatch wrappers around the Pallas kernels.

On TPU the Pallas kernels run compiled; everywhere else (this CPU
container, the 512-device host dry-run) the pure-jnp reference path is
used so every caller — serving engine, dry-run, tests — shares one entry
point.  ``REPRO_KERNEL_MODE`` overrides: "ref" | "interpret" | "tpu".
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.quantizer import QuantizedTensor
from repro.dist.sharding import (active_mesh, active_rule, logical_to_spec,
                                 shard_hint)
from . import ref as ref_ops
from .flash_decode import (flash_decode_paged_pallas,
                           flash_decode_paged_q8_pallas,
                           flash_decode_pallas, flash_decode_q8_pallas)
from .quant_error import quant_error_pallas
from .quant_matmul import quant_matmul_pallas


def _mode() -> str:
    forced = os.environ.get("REPRO_KERNEL_MODE")
    if forced:
        return forced
    return "tpu" if jax.default_backend() == "tpu" else "ref"


def quant_matmul(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """``(x / act_scale) @ dequant(qt)`` for arbitrary leading x dims."""
    mode = _mode()
    if mode == "ref" or not qt.packed or qt.spec.bits > 4:
        # Decode-serving layouts opt in (rules set "qin" to None) to a
        # constraint that moves weights cross-device in the packed uint8
        # domain instead of dequantized f32 (EXPERIMENTS.md §Perf iter 1).
        # Applied only on explicit opt-in: under default rules the
        # constraint pessimizes GSPMD's own dot partitioning (iter 1d).
        if qt.codes.ndim == 2 and active_rule("qin") is None:
            qt = QuantizedTensor(
                codes=shard_hint(qt.codes, "qin", "qout"),
                scale=shard_hint(qt.scale, "qgroups", "qout"),
                zero=shard_hint(qt.zero, "qgroups", "qout"),
                spec=qt.spec, n_in=qt.n_in, packed=qt.packed,
                act_scale=qt.act_scale)
        return ref_ops.quant_matmul_ref(x, qt)
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if qt.act_scale is not None:
        x2 = x2 / qt.act_scale.astype(x2.dtype)
    # The kernel wrapper pads m and n up to the tiles it actually picks
    # and slices the result, so the dispatch passes shapes through
    # unchanged — the old pad-rows-to-min(128, m) here became redundant
    # (and it never covered the dimension that actually crashed: n_out
    # not a multiple of the 128 tile, e.g. hymba's d_model=1600).
    out = quant_matmul_pallas(x2, qt.codes, qt.scale, qt.zero,
                              interpret=(mode != "tpu"))
    return out.reshape(lead + (qt.codes.shape[-1],)).astype(x.dtype)


def quant_error_batch(w: jax.Array, scales: jax.Array, mean_sq: jax.Array,
                      spec) -> jax.Array:
    """Fused multi-candidate quant-error (α search inner loop)."""
    mode = _mode()
    if mode == "ref":
        return ref_ops.quant_error_ref(w, scales, mean_sq, spec)
    return quant_error_pallas(w, scales, mean_sq, spec,
                              interpret=(mode != "tpu"))


def quant_matmul_experts(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """Per-expert dequant matmul: x (E, C, d) with qt codes (E, d[/2], f).

    Same grouped-dequant math as quant_matmul: the ref path is vmapped
    over the expert axis; the kernel path (interpret/tpu) unrolls the
    (static) expert axis into per-expert ``quant_matmul_pallas`` calls,
    so MoE serving consumes packed expert weights through the same
    dequant-GEMM kernel as the dense matmuls."""
    mode = _mode()
    if mode == "ref" or not qt.packed or qt.spec.bits > 4:
        def one(xe, codes, scale, zero, act):
            sub = QuantizedTensor(codes=codes, scale=scale, zero=zero,
                                  spec=qt.spec, n_in=qt.n_in,
                                  packed=qt.packed, act_scale=act)
            return ref_ops.quant_matmul_ref(xe, sub)

        if qt.act_scale is None:
            return jax.vmap(lambda xe, c, s, z: one(xe, c, s, z, None))(
                x, qt.codes, qt.scale, qt.zero)
        return jax.vmap(one)(x, qt.codes, qt.scale, qt.zero, qt.act_scale)

    outs = []
    for e in range(qt.codes.shape[0]):
        xe = x[e]
        if qt.act_scale is not None:
            xe = xe / qt.act_scale[e].astype(xe.dtype)
        outs.append(quant_matmul_pallas(xe, qt.codes[e], qt.scale[e],
                                        qt.zero[e],
                                        interpret=(mode != "tpu")))
    return jnp.stack(outs).astype(x.dtype)


# ---------------------------------------------------------------------------
# Decode attention (the serving engine's hottest loop).  All entry
# points take the caches' *native* layouts — dense (B, KH, S, hd),
# paged stores (P, KH, ps, hd) — q (B, 1, H, hd), cache_len (B,) int32.
# Ref mode transposes into the jnp oracles (bit-identical to the
# pre-kernel call sites); otherwise the split-KV flash-decode Pallas
# kernels run (interpret off-TPU).
#
# When a real mesh with a non-trivial "model" axis is active and both
# head counts divide it, the whole family runs under a head-axis
# ``shard_map``: each device owns H/m query heads and KH/m KV heads, so
# split-KV attention and the in-kernel page gather stay device-local and
# the decode step needs no KV-cache collectives at all (attention is
# exactly parallel over heads — per-head softmax, no cross-head math).
# Otherwise (no mesh, model=1, or non-dividing head counts) the local
# body runs directly and GSPMD handles whatever layout it was given.
# ---------------------------------------------------------------------------

def _tp_mesh(n_q_heads: int, n_kv_heads: int):
    """The active mesh iff head-axis shard_map is applicable, else None."""
    mesh = active_mesh()
    if not isinstance(mesh, jax.sharding.Mesh):
        return None
    m = dict(mesh.shape).get("model", 1)
    if m <= 1 or n_q_heads % m or n_kv_heads % m:
        return None
    return mesh


def _batch_entry(n: int, mesh):
    """PartitionSpec entry for a batch dim of size ``n`` (None / "data" /
    ("pod","data") ... depending on the mesh and divisibility)."""
    return logical_to_spec(("batch",), shape=(n,), mesh=mesh)[0]


def _decode_attention_local(q, k_cache, v_cache, cache_len, *, window, mode):
    if mode == "ref":
        return ref_ops.decode_attention_ref(
            q, k_cache.transpose(0, 2, 1, 3), v_cache.transpose(0, 2, 1, 3),
            cache_len, window=window)
    return flash_decode_pallas(q, k_cache, v_cache, cache_len,
                               window=window, interpret=(mode != "tpu"))


def _decode_attention_q8_local(q, k_codes, k_scale, v_codes, v_scale,
                               cache_len, *, window, mode):
    if mode == "ref":
        return ref_ops.decode_attention_q8_ref(
            q, k_codes.transpose(0, 2, 1, 3), k_scale.transpose(0, 2, 1, 3),
            v_codes.transpose(0, 2, 1, 3), v_scale.transpose(0, 2, 1, 3),
            cache_len, window=window)
    return flash_decode_q8_pallas(q, k_codes, k_scale, v_codes, v_scale,
                                  cache_len, window=window,
                                  interpret=(mode != "tpu"))


def _paged_decode_attention_local(q, k_store, v_store, page_table, cache_len,
                                  *, window, mode):
    if mode == "ref":
        return ref_ops.paged_decode_attention_ref(
            q, k_store, v_store, page_table, cache_len, window=window)
    return flash_decode_paged_pallas(q, k_store, v_store, page_table,
                                     cache_len, window=window,
                                     interpret=(mode != "tpu"))


def _paged_decode_attention_q8_local(q, k_codes, k_scale, v_codes, v_scale,
                                     page_table, cache_len, *, window, mode):
    if mode == "ref":
        return ref_ops.paged_decode_attention_q8_ref(
            q, k_codes, k_scale, v_codes, v_scale, page_table, cache_len,
            window=window)
    return flash_decode_paged_q8_pallas(q, k_codes, k_scale, v_codes,
                                        v_scale, page_table, cache_len,
                                        window=window,
                                        interpret=(mode != "tpu"))


def _dense_shard_map(body, mesh, q, n_kv: int):
    """Head-axis shard_map wrapper for dense-cache entries: q and the
    output shard heads (dim 2), every (B, KH, S, hd)-shaped cache operand
    shards KV heads (dim 1), lengths shard batch."""
    b = _batch_entry(q.shape[0], mesh)
    qspec = P(b, None, "model", None)
    kvspec = P(b, "model", None, None)
    n_caches = n_kv  # cache-layout operands between q and cache_len
    in_specs = (qspec,) + (kvspec,) * n_caches + (P(b),)
    return shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=qspec,
                     check_rep=False)


def _paged_shard_map(body, mesh, q, n_stores: int):
    """Head-axis shard_map wrapper for paged entries: page stores
    (P, KH, ps, hd) shard KV heads (dim 1) with the page dim replicated;
    page tables replicate across "model" (each device gathers its own
    head slice through the same table)."""
    b = _batch_entry(q.shape[0], mesh)
    qspec = P(b, None, "model", None)
    store = P(None, "model", None, None)
    in_specs = (qspec,) + (store,) * n_stores + (P(b, None), P(b))
    return shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=qspec,
                     check_rep=False)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *, window=None) -> jax.Array:
    """Single-position attention against a (possibly longer) cache."""
    body = functools.partial(_decode_attention_local, window=window,
                             mode=_mode())
    mesh = _tp_mesh(q.shape[2], k_cache.shape[1])
    if mesh is not None:
        body = _dense_shard_map(body, mesh, q, 2)
    return body(q, k_cache, v_cache, cache_len)


def decode_attention_q8(q, k_codes, k_scale, v_codes, v_scale, cache_len, *,
                        window=None):
    """int8-KV decode attention; scales stay folded in the consumer."""
    body = functools.partial(_decode_attention_q8_local, window=window,
                             mode=_mode())
    mesh = _tp_mesh(q.shape[2], k_codes.shape[1])
    if mesh is not None:
        body = _dense_shard_map(body, mesh, q, 4)
    return body(q, k_codes, k_scale, v_codes, v_scale, cache_len)


def paged_decode_attention(q, k_store, v_store, page_table, cache_len, *,
                           window=None):
    """Decode attention against the shared page store via the table."""
    body = functools.partial(_paged_decode_attention_local, window=window,
                             mode=_mode())
    mesh = _tp_mesh(q.shape[2], k_store.shape[1])
    if mesh is not None:
        body = _paged_shard_map(body, mesh, q, 2)
    return body(q, k_store, v_store, page_table, cache_len)


def paged_decode_attention_q8(q, k_codes, k_scale, v_codes, v_scale,
                              page_table, cache_len, *, window=None):
    """Paged int8-KV decode attention (scales paged alongside codes)."""
    body = functools.partial(_paged_decode_attention_q8_local, window=window,
                             mode=_mode())
    mesh = _tp_mesh(q.shape[2], k_codes.shape[1])
    if mesh is not None:
        body = _paged_shard_map(body, mesh, q, 4)
    return body(q, k_codes, k_scale, v_codes, v_scale, page_table, cache_len)


# ---------------------------------------------------------------------------
# Verify attention (speculative decoding, DESIGN.md §12).  q carries T
# speculative positions per slot; position i attends keys at cache
# positions < base_len[b] + i + 1 (its own fresh entry included) —
# shifted-causal over the tail, length-masked below it.  Ref mode runs
# one fused masked einsum over all T positions (the cycle-cost win: one
# score/softmax pass per layer instead of T); kernel modes unroll T
# calls of the same split-KV flash-decode kernel the non-speculative
# loop runs, each position with its own cache_len — so per mode, verify
# row i computes exactly what the sequential decode step would.  T is a
# small static K+1, so either form stays one fused XLA program inside
# the engine's jitted cycle.
# ---------------------------------------------------------------------------

def _verify_attention_local(q, k_cache, v_cache, base_len, *, window, mode):
    if mode == "ref":
        return ref_ops.verify_attention_ref(
            q, k_cache.transpose(0, 2, 1, 3), v_cache.transpose(0, 2, 1, 3),
            base_len, window=window)
    outs = [_decode_attention_local(q[:, i:i + 1], k_cache, v_cache,
                                    base_len + i + 1, window=window,
                                    mode=mode)
            for i in range(q.shape[1])]
    return jnp.concatenate(outs, axis=1)


def _verify_attention_q8_local(q, k_codes, k_scale, v_codes, v_scale,
                               base_len, *, window, mode):
    if mode == "ref":
        return ref_ops.verify_attention_q8_ref(
            q, k_codes.transpose(0, 2, 1, 3), k_scale.transpose(0, 2, 1, 3),
            v_codes.transpose(0, 2, 1, 3), v_scale.transpose(0, 2, 1, 3),
            base_len, window=window)
    outs = [_decode_attention_q8_local(q[:, i:i + 1], k_codes, k_scale,
                                       v_codes, v_scale, base_len + i + 1,
                                       window=window, mode=mode)
            for i in range(q.shape[1])]
    return jnp.concatenate(outs, axis=1)


def _paged_verify_attention_local(q, k_store, v_store, page_table, base_len,
                                  *, window, mode):
    if mode == "ref":
        return ref_ops.paged_verify_attention_ref(
            q, k_store, v_store, page_table, base_len, window=window)
    outs = [_paged_decode_attention_local(q[:, i:i + 1], k_store, v_store,
                                          page_table, base_len + i + 1,
                                          window=window, mode=mode)
            for i in range(q.shape[1])]
    return jnp.concatenate(outs, axis=1)


def _paged_verify_attention_q8_local(q, k_codes, k_scale, v_codes, v_scale,
                                     page_table, base_len, *, window, mode):
    if mode == "ref":
        return ref_ops.paged_verify_attention_q8_ref(
            q, k_codes, k_scale, v_codes, v_scale, page_table, base_len,
            window=window)
    outs = [_paged_decode_attention_q8_local(q[:, i:i + 1], k_codes, k_scale,
                                             v_codes, v_scale, page_table,
                                             base_len + i + 1, window=window,
                                             mode=mode)
            for i in range(q.shape[1])]
    return jnp.concatenate(outs, axis=1)


def verify_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     base_len: jax.Array, *, window=None) -> jax.Array:
    """Multi-position decode attention: q (B, T, H, hd), dense caches in
    native (B, KH, S, hd) layout, base_len (B,) valid entries *before*
    the burst (the T fresh K/V entries are already written)."""
    body = functools.partial(_verify_attention_local, window=window,
                             mode=_mode())
    mesh = _tp_mesh(q.shape[2], k_cache.shape[1])
    if mesh is not None:
        # one shard_map around the whole burst — kernel modes unroll the
        # per-position loop *inside* it, never nesting shard_maps
        body = _dense_shard_map(body, mesh, q, 2)
    return body(q, k_cache, v_cache, base_len)


def verify_attention_q8(q, k_codes, k_scale, v_codes, v_scale, base_len, *,
                        window=None):
    """int8-KV variant of :func:`verify_attention`."""
    body = functools.partial(_verify_attention_q8_local, window=window,
                             mode=_mode())
    mesh = _tp_mesh(q.shape[2], k_codes.shape[1])
    if mesh is not None:
        body = _dense_shard_map(body, mesh, q, 4)
    return body(q, k_codes, k_scale, v_codes, v_scale, base_len)


def paged_verify_attention(q, k_store, v_store, page_table, base_len, *,
                           window=None):
    """:func:`verify_attention` against the shared page store."""
    body = functools.partial(_paged_verify_attention_local, window=window,
                             mode=_mode())
    mesh = _tp_mesh(q.shape[2], k_store.shape[1])
    if mesh is not None:
        body = _paged_shard_map(body, mesh, q, 2)
    return body(q, k_store, v_store, page_table, base_len)


def paged_verify_attention_q8(q, k_codes, k_scale, v_codes, v_scale,
                              page_table, base_len, *, window=None):
    """Paged int8-KV variant of :func:`verify_attention`."""
    body = functools.partial(_paged_verify_attention_q8_local, window=window,
                             mode=_mode())
    mesh = _tp_mesh(q.shape[2], k_codes.shape[1])
    if mesh is not None:
        body = _paged_shard_map(body, mesh, q, 4)
    return body(q, k_codes, k_scale, v_codes, v_scale, page_table, base_len)
