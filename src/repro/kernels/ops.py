"""Jit'd dispatch wrappers around the Pallas kernels.

On TPU the Pallas kernels run compiled; everywhere else (this CPU
container, the 512-device host dry-run) the pure-jnp reference path is
used so every caller — serving engine, dry-run, tests — shares one entry
point.  ``REPRO_KERNEL_MODE`` overrides: "ref" | "interpret" | "tpu".
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantizedTensor
from repro.dist.sharding import active_rule, shard_hint
from . import ref as ref_ops
from .flash_decode import (flash_decode_paged_pallas,
                           flash_decode_paged_q8_pallas,
                           flash_decode_pallas, flash_decode_q8_pallas)
from .quant_error import quant_error_pallas
from .quant_matmul import quant_matmul_pallas


def _mode() -> str:
    forced = os.environ.get("REPRO_KERNEL_MODE")
    if forced:
        return forced
    return "tpu" if jax.default_backend() == "tpu" else "ref"


def quant_matmul(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """``(x / act_scale) @ dequant(qt)`` for arbitrary leading x dims."""
    mode = _mode()
    if mode == "ref" or not qt.packed or qt.spec.bits > 4:
        # Decode-serving layouts opt in (rules set "qin" to None) to a
        # constraint that moves weights cross-device in the packed uint8
        # domain instead of dequantized f32 (EXPERIMENTS.md §Perf iter 1).
        # Applied only on explicit opt-in: under default rules the
        # constraint pessimizes GSPMD's own dot partitioning (iter 1d).
        if qt.codes.ndim == 2 and active_rule("qin") is None:
            qt = QuantizedTensor(
                codes=shard_hint(qt.codes, "qin", "qout"),
                scale=shard_hint(qt.scale, "qgroups", "qout"),
                zero=shard_hint(qt.zero, "qgroups", "qout"),
                spec=qt.spec, n_in=qt.n_in, packed=qt.packed,
                act_scale=qt.act_scale)
        return ref_ops.quant_matmul_ref(x, qt)
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if qt.act_scale is not None:
        x2 = x2 / qt.act_scale.astype(x2.dtype)
    # The kernel wrapper pads m and n up to the tiles it actually picks
    # and slices the result, so the dispatch passes shapes through
    # unchanged — the old pad-rows-to-min(128, m) here became redundant
    # (and it never covered the dimension that actually crashed: n_out
    # not a multiple of the 128 tile, e.g. hymba's d_model=1600).
    out = quant_matmul_pallas(x2, qt.codes, qt.scale, qt.zero,
                              interpret=(mode != "tpu"))
    return out.reshape(lead + (qt.codes.shape[-1],)).astype(x.dtype)


def quant_error_batch(w: jax.Array, scales: jax.Array, mean_sq: jax.Array,
                      spec) -> jax.Array:
    """Fused multi-candidate quant-error (α search inner loop)."""
    mode = _mode()
    if mode == "ref":
        return ref_ops.quant_error_ref(w, scales, mean_sq, spec)
    return quant_error_pallas(w, scales, mean_sq, spec,
                              interpret=(mode != "tpu"))


def quant_matmul_experts(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """Per-expert dequant matmul: x (E, C, d) with qt codes (E, d[/2], f).

    Same grouped-dequant math as quant_matmul: the ref path is vmapped
    over the expert axis; the kernel path (interpret/tpu) unrolls the
    (static) expert axis into per-expert ``quant_matmul_pallas`` calls,
    so MoE serving consumes packed expert weights through the same
    dequant-GEMM kernel as the dense matmuls."""
    mode = _mode()
    if mode == "ref" or not qt.packed or qt.spec.bits > 4:
        def one(xe, codes, scale, zero, act):
            sub = QuantizedTensor(codes=codes, scale=scale, zero=zero,
                                  spec=qt.spec, n_in=qt.n_in,
                                  packed=qt.packed, act_scale=act)
            return ref_ops.quant_matmul_ref(xe, sub)

        if qt.act_scale is None:
            return jax.vmap(lambda xe, c, s, z: one(xe, c, s, z, None))(
                x, qt.codes, qt.scale, qt.zero)
        return jax.vmap(one)(x, qt.codes, qt.scale, qt.zero, qt.act_scale)

    outs = []
    for e in range(qt.codes.shape[0]):
        xe = x[e]
        if qt.act_scale is not None:
            xe = xe / qt.act_scale[e].astype(xe.dtype)
        outs.append(quant_matmul_pallas(xe, qt.codes[e], qt.scale[e],
                                        qt.zero[e],
                                        interpret=(mode != "tpu")))
    return jnp.stack(outs).astype(x.dtype)


# ---------------------------------------------------------------------------
# Decode attention (the serving engine's hottest loop).  All entry
# points take the caches' *native* layouts — dense (B, KH, S, hd),
# paged stores (P, KH, ps, hd) — q (B, 1, H, hd), cache_len (B,) int32.
# Ref mode transposes into the jnp oracles (bit-identical to the
# pre-kernel call sites); otherwise the split-KV flash-decode Pallas
# kernels run (interpret off-TPU).
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *, window=None) -> jax.Array:
    """Single-position attention against a (possibly longer) cache."""
    mode = _mode()
    if mode == "ref":
        return ref_ops.decode_attention_ref(
            q, k_cache.transpose(0, 2, 1, 3), v_cache.transpose(0, 2, 1, 3),
            cache_len, window=window)
    return flash_decode_pallas(q, k_cache, v_cache, cache_len,
                               window=window, interpret=(mode != "tpu"))


def decode_attention_q8(q, k_codes, k_scale, v_codes, v_scale, cache_len, *,
                        window=None):
    """int8-KV decode attention; scales stay folded in the consumer."""
    mode = _mode()
    if mode == "ref":
        return ref_ops.decode_attention_q8_ref(
            q, k_codes.transpose(0, 2, 1, 3), k_scale.transpose(0, 2, 1, 3),
            v_codes.transpose(0, 2, 1, 3), v_scale.transpose(0, 2, 1, 3),
            cache_len, window=window)
    return flash_decode_q8_pallas(q, k_codes, k_scale, v_codes, v_scale,
                                  cache_len, window=window,
                                  interpret=(mode != "tpu"))


def paged_decode_attention(q, k_store, v_store, page_table, cache_len, *,
                           window=None):
    """Decode attention against the shared page store via the table."""
    mode = _mode()
    if mode == "ref":
        return ref_ops.paged_decode_attention_ref(
            q, k_store, v_store, page_table, cache_len, window=window)
    return flash_decode_paged_pallas(q, k_store, v_store, page_table,
                                     cache_len, window=window,
                                     interpret=(mode != "tpu"))


def paged_decode_attention_q8(q, k_codes, k_scale, v_codes, v_scale,
                              page_table, cache_len, *, window=None):
    """Paged int8-KV decode attention (scales paged alongside codes)."""
    mode = _mode()
    if mode == "ref":
        return ref_ops.paged_decode_attention_q8_ref(
            q, k_codes, k_scale, v_codes, v_scale, page_table, cache_len,
            window=window)
    return flash_decode_paged_q8_pallas(q, k_codes, k_scale, v_codes,
                                        v_scale, page_table, cache_len,
                                        window=window,
                                        interpret=(mode != "tpu"))


# ---------------------------------------------------------------------------
# Verify attention (speculative decoding, DESIGN.md §12).  q carries T
# speculative positions per slot; position i attends keys at cache
# positions < base_len[b] + i + 1 (its own fresh entry included) —
# shifted-causal over the tail, length-masked below it.  Ref mode runs
# one fused masked einsum over all T positions (the cycle-cost win: one
# score/softmax pass per layer instead of T); kernel modes unroll T
# calls of the same split-KV flash-decode kernel the non-speculative
# loop runs, each position with its own cache_len — so per mode, verify
# row i computes exactly what the sequential decode step would.  T is a
# small static K+1, so either form stays one fused XLA program inside
# the engine's jitted cycle.
# ---------------------------------------------------------------------------

def verify_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     base_len: jax.Array, *, window=None) -> jax.Array:
    """Multi-position decode attention: q (B, T, H, hd), dense caches in
    native (B, KH, S, hd) layout, base_len (B,) valid entries *before*
    the burst (the T fresh K/V entries are already written)."""
    if _mode() == "ref":
        return ref_ops.verify_attention_ref(
            q, k_cache.transpose(0, 2, 1, 3), v_cache.transpose(0, 2, 1, 3),
            base_len, window=window)
    outs = [decode_attention(q[:, i:i + 1], k_cache, v_cache,
                             base_len + i + 1, window=window)
            for i in range(q.shape[1])]
    return jnp.concatenate(outs, axis=1)


def verify_attention_q8(q, k_codes, k_scale, v_codes, v_scale, base_len, *,
                        window=None):
    """int8-KV variant of :func:`verify_attention`."""
    if _mode() == "ref":
        return ref_ops.verify_attention_q8_ref(
            q, k_codes.transpose(0, 2, 1, 3), k_scale.transpose(0, 2, 1, 3),
            v_codes.transpose(0, 2, 1, 3), v_scale.transpose(0, 2, 1, 3),
            base_len, window=window)
    outs = [decode_attention_q8(q[:, i:i + 1], k_codes, k_scale, v_codes,
                                v_scale, base_len + i + 1, window=window)
            for i in range(q.shape[1])]
    return jnp.concatenate(outs, axis=1)


def paged_verify_attention(q, k_store, v_store, page_table, base_len, *,
                           window=None):
    """:func:`verify_attention` against the shared page store."""
    if _mode() == "ref":
        return ref_ops.paged_verify_attention_ref(
            q, k_store, v_store, page_table, base_len, window=window)
    outs = [paged_decode_attention(q[:, i:i + 1], k_store, v_store,
                                   page_table, base_len + i + 1,
                                   window=window)
            for i in range(q.shape[1])]
    return jnp.concatenate(outs, axis=1)


def paged_verify_attention_q8(q, k_codes, k_scale, v_codes, v_scale,
                              page_table, base_len, *, window=None):
    """Paged int8-KV variant of :func:`verify_attention`."""
    if _mode() == "ref":
        return ref_ops.paged_verify_attention_q8_ref(
            q, k_codes, k_scale, v_codes, v_scale, page_table, base_len,
            window=window)
    outs = [paged_decode_attention_q8(q[:, i:i + 1], k_codes, k_scale,
                                      v_codes, v_scale, page_table,
                                      base_len + i + 1, window=window)
            for i in range(q.shape[1])]
    return jnp.concatenate(outs, axis=1)
