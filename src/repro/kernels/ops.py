"""Jit'd dispatch wrappers around the Pallas kernels.

On TPU the Pallas kernels run compiled; everywhere else (this CPU
container, the 512-device host dry-run) the pure-jnp reference path is
used so every caller — serving engine, dry-run, tests — shares one entry
point.  ``REPRO_KERNEL_MODE`` overrides: "ref" | "interpret" | "tpu".
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantizedTensor
from repro.dist.sharding import active_rule, shard_hint
from . import ref as ref_ops
from .quant_error import quant_error_pallas
from .quant_matmul import quant_matmul_pallas


def _mode() -> str:
    forced = os.environ.get("REPRO_KERNEL_MODE")
    if forced:
        return forced
    return "tpu" if jax.default_backend() == "tpu" else "ref"


def quant_matmul(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """``(x / act_scale) @ dequant(qt)`` for arbitrary leading x dims."""
    mode = _mode()
    if mode == "ref" or not qt.packed or qt.spec.bits > 4:
        # Decode-serving layouts opt in (rules set "qin" to None) to a
        # constraint that moves weights cross-device in the packed uint8
        # domain instead of dequantized f32 (EXPERIMENTS.md §Perf iter 1).
        # Applied only on explicit opt-in: under default rules the
        # constraint pessimizes GSPMD's own dot partitioning (iter 1d).
        if qt.codes.ndim == 2 and active_rule("qin") is None:
            qt = QuantizedTensor(
                codes=shard_hint(qt.codes, "qin", "qout"),
                scale=shard_hint(qt.scale, "qgroups", "qout"),
                zero=shard_hint(qt.zero, "qgroups", "qout"),
                spec=qt.spec, n_in=qt.n_in, packed=qt.packed,
                act_scale=qt.act_scale)
        return ref_ops.quant_matmul_ref(x, qt)
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if qt.act_scale is not None:
        x2 = x2 / qt.act_scale.astype(x2.dtype)
    # The kernel wrapper pads m and n up to the tiles it actually picks
    # and slices the result, so the dispatch passes shapes through
    # unchanged — the old pad-rows-to-min(128, m) here became redundant
    # (and it never covered the dimension that actually crashed: n_out
    # not a multiple of the 128 tile, e.g. hymba's d_model=1600).
    out = quant_matmul_pallas(x2, qt.codes, qt.scale, qt.zero,
                              interpret=(mode != "tpu"))
    return out.reshape(lead + (qt.codes.shape[-1],)).astype(x.dtype)


def quant_error_batch(w: jax.Array, scales: jax.Array, mean_sq: jax.Array,
                      spec) -> jax.Array:
    """Fused multi-candidate quant-error (α search inner loop)."""
    mode = _mode()
    if mode == "ref":
        return ref_ops.quant_error_ref(w, scales, mean_sq, spec)
    return quant_error_pallas(w, scales, mean_sq, spec,
                              interpret=(mode != "tpu"))


def quant_matmul_experts(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """Per-expert dequant matmul: x (E, C, d) with qt codes (E, d[/2], f).

    vmapped over the expert axis; each expert uses the same grouped-dequant
    math as quant_matmul (ref path on CPU, kernel path on TPU)."""
    def one(xe, codes, scale, zero, act):
        sub = QuantizedTensor(codes=codes, scale=scale, zero=zero,
                              spec=qt.spec, n_in=qt.n_in, packed=qt.packed,
                              act_scale=act)
        return ref_ops.quant_matmul_ref(xe, sub)

    if qt.act_scale is None:
        return jax.vmap(lambda xe, c, s, z: one(xe, c, s, z, None))(
            x, qt.codes, qt.scale, qt.zero)
    return jax.vmap(one)(x, qt.codes, qt.scale, qt.zero, qt.act_scale)
