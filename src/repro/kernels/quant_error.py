"""Pallas TPU kernel: fused quantization-error evaluation for the α search.

The calibration hot-spot: AWQ/FAQ grid-search evaluates, for every
candidate smoothing scale s_a,

    err[a] = Σ_ij  mean_sq_i · ( deq(Q(W·s_a))_ij / s_a,i  −  W_ij )²

A naive implementation materializes the fake-quantized weight in HBM per
grid point (|grid| × weight-sized traffic).  This kernel streams each W
block into VMEM **once per candidate** and performs
scale→quantize→dequantize→unscale→weighted-error in-register, emitting
only the (A,) error accumulators — turning an HBM-bound search into a
compute-bound one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams

from repro.core.quantizer import QuantSpec


def _kernel(w_ref, s_ref, msq_ref, out_ref, *, g: int, spec: QuantSpec):
    kk = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((kk == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[...].astype(jnp.float32)        # (bk, bn)
    s = s_ref[...].astype(jnp.float32)        # (1, bk)
    msq = msq_ref[...].astype(jnp.float32)    # (1, bk)
    bk, bn = w.shape

    ws = w * s.reshape(bk, 1)
    wg = ws.reshape(bk // g, g, bn)
    lo = wg.min(axis=1)
    hi = wg.max(axis=1)
    if spec.symmetric:
        amax = jnp.maximum(jnp.abs(lo), jnp.abs(hi))
        scale = jnp.maximum(amax / spec.qmax, 1e-8)
        zero = jnp.zeros_like(scale)
        qmin, qmax = spec.qmin, spec.qmax
    else:
        lo = jnp.minimum(lo, 0.0)
        hi = jnp.maximum(hi, 0.0)
        scale = jnp.maximum((hi - lo) / (spec.levels - 1), 1e-8)
        zero = jnp.round(-lo / scale)
        qmin, qmax = 0, spec.levels - 1
    s_full = jnp.repeat(scale, g, axis=0)
    z_full = jnp.repeat(zero, g, axis=0)
    codes = jnp.clip(jnp.round(ws / s_full) + z_full, qmin, qmax)
    w_hat = (codes - z_full) * s_full / s.reshape(bk, 1)
    dw = w_hat - w
    out_ref[...] += jnp.sum(msq.reshape(bk, 1) * dw * dw)


@functools.partial(jax.jit, static_argnames=("spec", "bk", "bn", "interpret"))
def quant_error_pallas(w: jax.Array, scales: jax.Array, mean_sq: jax.Array,
                       spec: QuantSpec, *, bk: int = 256, bn: int = 256,
                       interpret: bool = True) -> jax.Array:
    """w: (k, n); scales: (A, k); mean_sq: (k,).  Returns (A,) f32 errors
    normalized by n (matches :func:`repro.kernels.ref.quant_error_ref`)."""
    k, n = w.shape
    a = scales.shape[0]
    from repro.core.quantizer import effective_group_size
    g = effective_group_size(k, spec.group_size)
    bk = min(bk, k)
    bn = min(bn, n)
    if bk % g != 0 or k % bk != 0:
        bk = g  # group size divides k by construction (same invariant
        #         as quant_matmul_pallas), so bk=g always tiles K
    assert k % bk == 0, (k, bk, g)  # repro: noqa[RPR007] bk=g fallback above guarantees this
    # n need not divide the tile: zero-pad the weight columns.  A padded
    # column has w=0 in every group, so lo=hi=0 -> scale clamps to 1e-8,
    # zero=0, codes=0, w_hat=0 — its error contribution is exactly 0 in
    # both the symmetric and asymmetric branches, and the final /n uses
    # the original n.
    pad_n = (-n) % bn
    if pad_n:
        w = jnp.pad(w, ((0, 0), (0, pad_n)))
    np_ = n + pad_n

    grid = (a, k // bk, np_ // bn)
    msq2 = mean_sq.reshape(1, k)
    out = pl.pallas_call(
        functools.partial(_kernel, g=g, spec=spec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bn), lambda aa, kk, j: (kk, j)),
            pl.BlockSpec((1, bk), lambda aa, kk, j: (aa, kk)),
            pl.BlockSpec((1, bk), lambda aa, kk, j: (0, kk)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda aa, kk, j: (aa, 0)),
        out_shape=jax.ShapeDtypeStruct((a, 1), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary",                                              "arbitrary")),
        interpret=interpret,
    )(w, scales, msq2)
    return out[:, 0] / n
