"""Pallas TPU kernel: W4A16 grouped dequant-matmul.

The serving hot-spot of the FAQ/AWQ deployment format.  Int4 weight codes
are packed two-per-byte in HBM; each grid step stages a ``(bk/2, bn)``
packed block plus its per-group scales/zeros into VMEM, dequantizes
in-register, and feeds the MXU with a ``(bm, bk) @ (bk, bn)`` matmul,
accumulating in f32 across the K grid axis.

TPU adaptation notes (vs. AWQ's CUDA dequant-GEMM):
  * HBM->VMEM staging is expressed with BlockSpecs; the MXU dims (bm, bn)
    are multiples of 128 and bk is a multiple of the quant group size so a
    scale group never straddles K blocks.
  * The nibble unpack is an interleave on the second-minor axis
    (stack + reshape), which Mosaic lowers to vector ops; validated here
    in interpret mode (this container is CPU-only).
  * The per-channel AWQ/FAQ smoothing scale is folded into the activation
    *outside* the kernel (one fused elementwise op), keeping the kernel a
    pure grouped-dequant GEMM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams


def _kernel(x_ref, codes_ref, scale_ref, zero_ref, out_ref, *, bk: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...]                    # (bk//2, bn) uint8
    lo = (codes & jnp.uint8(0x0F)).astype(jnp.float32)
    hi = ((codes >> 4) & jnp.uint8(0x0F)).astype(jnp.float32)
    w = jnp.stack([lo, hi], axis=1).reshape(bk, codes.shape[-1])

    scale = scale_ref[...]                    # (bk//g, bn)
    zero = zero_ref[...]
    g = bk // scale.shape[0]
    s_full = jnp.repeat(scale, g, axis=0)
    z_full = jnp.repeat(zero, g, axis=0)
    w = (w - z_full) * s_full                 # dequant in VMEM

    x = x_ref[...].astype(jnp.float32)        # (bm, bk)
    out_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def quant_matmul_pallas(x: jax.Array, codes: jax.Array, scale: jax.Array,
                        zero: jax.Array, *, bm: int = 128, bn: int = 128,
                        bk: int = 128, interpret: bool = True) -> jax.Array:
    """x: (m, k) float; codes: (k//2, n) packed uint8;
    scale/zero: (k//g, n) f32.  Returns (m, n) f32."""
    m, k = x.shape
    n = codes.shape[-1]
    n_groups = scale.shape[0]
    g = k // n_groups
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    if bk % g != 0 or k % bk != 0:
        bk = g  # never straddle a quant group across K blocks; the
        #         group size always divides k, so this also covers
        #         k not a multiple of the default tile
    assert k % bk == 0, (k, bk, g)  # repro: noqa[RPR007] bk=g fallback above guarantees this
    assert bk % 2 == 0, (  # repro: noqa[RPR007] packing invariant, not a tile-shape constraint
        f"quant group size must be even to unpack nibble-packed codes "
        f"in K blocks (bk={bk})")
    # m and n need not divide the MXU tile (hymba's d_model=1600 leaves
    # 1600 % 128 = 64): pad both up to the tile and slice the result.
    # Padded activation rows are zeros; padded weight columns carry
    # scale = zero = 0, so they dequantize to (0 - 0) * 0 = 0 — either
    # way the padded region contributes nothing and is sliced away.
    pad_m = (-m) % bm
    pad_n = (-n) % bn
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    if pad_n:
        codes = jnp.pad(codes, ((0, 0), (0, pad_n)))
        scale = jnp.pad(scale, ((0, 0), (0, pad_n)))
        zero = jnp.pad(zero, ((0, 0), (0, pad_n)))
    mp, np_ = m + pad_m, n + pad_n

    grid = (mp // bm, np_ // bn, k // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // g, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // g, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel",                                              "arbitrary")),
        interpret=interpret,
    )(x, codes, scale, zero)
    return out[:m, :n]
