"""Pure-jnp oracles for the Pallas kernels (and the portable CPU path)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantizer import (QuantSpec, QuantizedTensor,
                                  dequantize_groupwise)


def quant_matmul_ref(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """``(x / act_scale) @ dequant(qt)`` — oracle for the W4A16 kernel."""
    if qt.act_scale is not None:
        x = x / qt.act_scale.astype(x.dtype)
    w = dequantize_groupwise(qt, dtype=x.dtype)
    return x @ w


def dequant_ref(codes: jax.Array, scale: jax.Array, zero: jax.Array,
                n_in: int) -> jax.Array:
    """Unpack + dequantize packed 4-bit codes: oracle for the kernel's
    in-VMEM dequant stage.  codes: (n_in//2, n_out) uint8."""
    lo = (codes & jnp.uint8(0x0F)).astype(jnp.float32)
    hi = ((codes >> 4) & jnp.uint8(0x0F)).astype(jnp.float32)
    w = jnp.stack([lo, hi], axis=1).reshape(n_in, codes.shape[-1])
    g = n_in // scale.shape[0]
    s_full = jnp.repeat(scale, g, axis=0)
    z_full = jnp.repeat(zero, g, axis=0)
    return (w - z_full) * s_full


def quant_error_ref(w: jax.Array, scales: jax.Array, mean_sq: jax.Array,
                    spec: QuantSpec) -> jax.Array:
    """Weighted quantization error for a batch of candidate smoothing
    scales — oracle for the fused quant-error kernel.

    w: (k, n); scales: (A, k) candidate act_scales; mean_sq: (k,).
    Returns (A,) with err[a] = sum(mean_sq[:,None] * dW_a**2) / n.
    """
    from repro.core.quantizer import quant_dequant

    def one(s):
        w_hat = quant_dequant(w, spec, act_scale=s)
        dw = w_hat.astype(jnp.float32) - w.astype(jnp.float32)
        return jnp.sum(mean_sq[:, None] * dw * dw) / w.shape[1]

    return jax.vmap(one)(scales)
