"""Pure-jnp oracles for the Pallas kernels (and the portable CPU path)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantizer import (QuantSpec, QuantizedTensor,
                                  dequantize_groupwise)


def quant_matmul_ref(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """``(x / act_scale) @ dequant(qt)`` — oracle for the W4A16 kernel."""
    if qt.act_scale is not None:
        x = x / qt.act_scale.astype(x.dtype)
    w = dequantize_groupwise(qt, dtype=x.dtype)
    return x @ w


def dequant_ref(codes: jax.Array, scale: jax.Array, zero: jax.Array,
                n_in: int) -> jax.Array:
    """Unpack + dequantize packed 4-bit codes: oracle for the kernel's
    in-VMEM dequant stage.  codes: (n_in//2, n_out) uint8."""
    lo = (codes & jnp.uint8(0x0F)).astype(jnp.float32)
    hi = ((codes >> 4) & jnp.uint8(0x0F)).astype(jnp.float32)
    w = jnp.stack([lo, hi], axis=1).reshape(n_in, codes.shape[-1])
    g = n_in // scale.shape[0]
    s_full = jnp.repeat(scale, g, axis=0)
    z_full = jnp.repeat(zero, g, axis=0)
    return (w - z_full) * s_full


# ---------------------------------------------------------------------------
# Decode attention oracles (the portable CPU serving path).  Layout note:
# these take the *gathered* (B, S, KH, hd) layout the pre-kernel code
# used; the kernels and the ops dispatch take the caches' native
# (B, KH, S, hd) / (P, KH, ps, hd) layouts and the dispatch transposes
# before calling in here — bit-identical to the old call sites.
# ---------------------------------------------------------------------------

def decode_attention_ref(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, cache_len: jax.Array,
                         window: Optional[int] = None) -> jax.Array:
    """Single-position attention against a (possibly longer) cache.

    q: (B, 1, H, hd); caches: (B, S, KH, hd); cache_len: (B,) int32 —
    number of valid cache entries per batch element *including* the
    current token's k/v (per-slot lengths enable continuous batching).

    GQA is computed in grouped form — q reshaped to (B, KH, G, hd) and
    einsummed against the *unrepeated* cache.  This keeps the cache's
    sequence sharding intact (repeating KV to q-heads forces an SPMD
    reshard that replicates the whole cache in f32 — the dominant
    collective of the baseline decode cells; EXPERIMENTS.md §Perf).
    Softmax over the sharded S axis costs only tiny stat psums.
    """
    b, _, h, hd = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    qg = q.astype(jnp.float32).reshape(b, kh, g, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg,
                        k_cache.astype(jnp.float32)) * hd ** -0.5
    cache_len = jnp.broadcast_to(cache_len, (b,))
    kpos = jnp.arange(s)
    mask = kpos[None, None, None, :] < cache_len[:, None, None, None]
    if window is not None:
        mask &= (kpos[None, None, None, :]
                 >= (cache_len[:, None, None, None] - window))
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def decode_attention_q8_ref(q, k_codes, k_scale, v_codes, v_scale,
                            cache_len, window=None):
    """decode_attention against an int8 cache: scales fold into the score
    matrix / probability weights, so the cache is consumed in int8."""
    b, _, h, hd = q.shape
    s, kh = k_codes.shape[1], k_codes.shape[2]
    g = h // kh
    qg = q.astype(jnp.float32).reshape(b, kh, g, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg,
                        k_codes.astype(jnp.float32)) * hd ** -0.5
    scores = scores * k_scale[..., 0].transpose(0, 2, 1)[:, :, None, :]
    cache_len = jnp.broadcast_to(cache_len, (b,))
    kpos = jnp.arange(s)
    mask = kpos[None, None, None, :] < cache_len[:, None, None, None]
    if window is not None:
        mask &= (kpos[None, None, None, :]
                 >= (cache_len[:, None, None, None] - window))
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    pv = p * v_scale[..., 0].transpose(0, 2, 1)[:, :, None, :]
    out = jnp.einsum("bkgs,bskd->bkgd", pv, v_codes.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def verify_attention_ref(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, base_len: jax.Array,
                         window: Optional[int] = None) -> jax.Array:
    """Multi-position decode attention (speculative verify, one fused
    masked einsum).

    q: (B, T, H, hd); caches: (B, S, KH, hd); base_len: (B,) valid
    entries *before* the burst.  Position ``i`` sees keys at cache
    positions ``< base_len + i + 1`` (shifted-causal over the burst, its
    own fresh entry included) — row ``i`` computes exactly what
    :func:`decode_attention_ref` would with ``cache_len = base_len+i+1``,
    but all T positions share one score/softmax/value pass instead of T
    separate attention dispatches per layer.
    """
    b, t, h, hd = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    qg = q.astype(jnp.float32).reshape(b, t, kh, g, hd)
    scores = jnp.einsum("btkgd,bskd->btkgs", qg,
                        k_cache.astype(jnp.float32)) * hd ** -0.5
    base_len = jnp.broadcast_to(base_len, (b,))
    lens = base_len[:, None] + 1 + jnp.arange(t)          # (B, T)
    kpos = jnp.arange(s)
    mask = kpos[None, None, :] < lens[..., None]          # (B, T, S)
    if window is not None:
        mask &= kpos[None, None, :] >= (lens[..., None] - window)
    scores = jnp.where(mask[:, :, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("btkgs,bskd->btkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, t, h, hd).astype(q.dtype)


def verify_attention_q8_ref(q, k_codes, k_scale, v_codes, v_scale,
                            base_len, window=None):
    """:func:`verify_attention_ref` against an int8 cache — the scale
    folds of :func:`decode_attention_q8_ref` applied over T positions."""
    b, t, h, hd = q.shape
    s, kh = k_codes.shape[1], k_codes.shape[2]
    g = h // kh
    qg = q.astype(jnp.float32).reshape(b, t, kh, g, hd)
    scores = jnp.einsum("btkgd,bskd->btkgs", qg,
                        k_codes.astype(jnp.float32)) * hd ** -0.5
    k_fold = k_scale[..., 0].transpose(0, 2, 1)           # (B, KH, S)
    scores = scores * k_fold[:, None, :, None, :]
    base_len = jnp.broadcast_to(base_len, (b,))
    lens = base_len[:, None] + 1 + jnp.arange(t)
    kpos = jnp.arange(s)
    mask = kpos[None, None, :] < lens[..., None]
    if window is not None:
        mask &= kpos[None, None, :] >= (lens[..., None] - window)
    scores = jnp.where(mask[:, :, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    v_fold = v_scale[..., 0].transpose(0, 2, 1)
    pv = p * v_fold[:, None, :, None, :]
    out = jnp.einsum("btkgs,bskd->btkgd", pv, v_codes.astype(jnp.float32))
    return out.reshape(b, t, h, hd).astype(q.dtype)


def paged_verify_attention_ref(q, k_store, v_store, page_table, base_len,
                               window=None):
    """Fused verify attention against a paged cache (gather + mask)."""
    k = gather_pages(k_store, page_table)
    v = gather_pages(v_store, page_table)
    return verify_attention_ref(q, k, v, base_len, window=window)


def paged_verify_attention_q8_ref(q, k_codes, k_scale, v_codes, v_scale,
                                  page_table, base_len, window=None):
    """Fused paged int8 verify attention (scales paged with codes)."""
    k = gather_pages(k_codes, page_table)
    ks = gather_pages(k_scale, page_table)
    v = gather_pages(v_codes, page_table)
    vs = gather_pages(v_scale, page_table)
    return verify_attention_q8_ref(q, k, ks, v, vs, base_len,
                                   window=window)


def gather_pages(store: jax.Array, page_table: jax.Array) -> jax.Array:
    """Materialize each slot's logical KV view from the shared page store.

    store: (P, KH, ps, d) — one layer's physical pages; page_table:
    (B, NP) int32 physical ids per logical block.  Returns
    (B, NP*ps, KH, d), the layout ``decode_attention_ref`` consumes.
    Unmapped table entries point at the trash page (id 0); its contents
    sit at positions >= the slot's cache length, which the attention
    mask already discards.
    """
    g = jnp.take(store, page_table, axis=0)        # (B, NP, KH, ps, d)
    b, n_pages, kh, ps, d = g.shape
    return g.transpose(0, 1, 3, 2, 4).reshape(b, n_pages * ps, kh, d)


def paged_decode_attention_ref(q, k_store, v_store, page_table, cache_len,
                               window=None):
    """:func:`decode_attention_ref` against a paged cache: gather K/V
    pages via the table into a dense HBM copy, then the masked einsum —
    the HBM round-trip the paged flash-decode kernel deletes."""
    k = gather_pages(k_store, page_table)
    v = gather_pages(v_store, page_table)
    return decode_attention_ref(q, k, v, cache_len, window=window)


def paged_decode_attention_q8_ref(q, k_codes, k_scale, v_codes, v_scale,
                                  page_table, cache_len, window=None):
    """:func:`decode_attention_q8_ref` against paged int8 stores — the
    scales are paged alongside the codes, so the int8 fold is
    preserved and the cache is consumed in int8."""
    k = gather_pages(k_codes, page_table)
    ks = gather_pages(k_scale, page_table)
    v = gather_pages(v_codes, page_table)
    vs = gather_pages(v_scale, page_table)
    return decode_attention_q8_ref(q, k, ks, v, vs, cache_len,
                                   window=window)


def quant_error_ref(w: jax.Array, scales: jax.Array, mean_sq: jax.Array,
                    spec: QuantSpec) -> jax.Array:
    """Weighted quantization error for a batch of candidate smoothing
    scales — oracle for the fused quant-error kernel.

    w: (k, n); scales: (A, k) candidate act_scales; mean_sq: (k,).
    Returns (A,) with err[a] = sum(mean_sq[:,None] * dW_a**2) / n.
    """
    from repro.core.quantizer import quant_dequant

    def one(s):
        w_hat = quant_dequant(w, spec, act_scale=s)
        dw = w_hat.astype(jnp.float32) - w.astype(jnp.float32)
        return jnp.sum(mean_sq[:, None] * dw * dw) / w.shape[1]

    return jax.vmap(one)(scales)
