"""Launchers: production mesh, dry-run driver, train/serve/quantize entry
points.  NOTE: dryrun must be imported first in its own process (it sets
XLA_FLAGS before jax initializes)."""
