import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell the appropriate step function (train_step / prefill /
decode_step) is lowered with ShapeDtypeStruct inputs under explicit
in/out shardings on the production mesh, compiled, and its
memory_analysis / cost_analysis / collective-transfer bytes are recorded
to ``reports/dryrun/<cell>.json``.  Serving cells lower against the
**packed FAQ-quantized** parameter representation — the paper's
deployment format is what the fleet would actually run.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --cell train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""

import argparse
import gc
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPE_CELLS, cell_applicable
from repro.core import QuantSpec
from repro.dist.sharding import (SERVE_DECODE_RULES, SERVE_PREFILL_RULES,
                                 axis_rules, tree_shardings)
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as S
from repro.models.registry import build_model
from repro.train.optimizer import AdamW
from repro.train.trainer import TrainConfig, make_train_step

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")

# v5e target constants (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s/link

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_COLL_OP_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3fn": 1,
                "f8e5m2": 1, "s16": 2, "u16": 2}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-buffer bytes of every collective op in the (per-device,
    post-partitioning) HLO.  Handles tuple-typed variadic collectives and
    async -start forms (the matching -done carries no new buffer)."""
    out = {}
    for line in hlo_text.splitlines():
        m = _COLL_OP_RE.search(line)
        if not m or "-done(" in line:
            continue
        op = m.group(1)
        lhs = line[:m.start()]
        nbytes = 0
        for tm in _TYPE_RE.finditer(lhs):
            dtype, dims = tm.group(1), tm.group(2)
            if dtype not in _DTYPE_BYTES:
                continue
            b = _DTYPE_BYTES[dtype]
            if dims:
                for d in dims.split(","):
                    b *= int(d)
            nbytes += b
        out[op] = out.get(op, 0) + nbytes
    out["total"] = sum(out.values())
    return out


def _moment_dtype(cfg) -> str:
    """fp32 Adam moments, except the >100B monsters (memory-fit note in
    EXPERIMENTS.md)."""
    big = cfg.name in ("llama3-405b", "llama4-maverick-400b-a17b")
    return "bfloat16" if big else "float32"


def _scaled_layers(cfg, n: int):
    """Same arch with n layers per stack (for the while-loop cost fix)."""
    over = {"n_layers": n}
    if cfg.n_encoder_layers:
        over["n_encoder_layers"] = n
    if cfg.slstm_every:
        # keep L1/L2 variants pure-mLSTM; the sLSTM delta is an analytic
        # add-on in the roofline script (documented there)
        over["slstm_every"] = 0
    return cfg.scaled(**over)


def lower_cell(arch: str, cell_name: str, multi_pod: bool = False,
               cfg_override=None):
    """Build and lower one cell.  Returns (lowered, meta)."""
    cfg = cfg_override if cfg_override is not None else ARCHS[arch]
    cell = SHAPE_CELLS[cell_name]
    if not cell_applicable(cfg, cell):
        return None, {"skipped": "long_500k needs sub-quadratic attention; "
                                 f"{cfg.family} is full-attention"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    batch_sds = S.input_specs(cfg, cell)

    with axis_rules(mesh):
        batch_sh = S.batch_shardings(mesh, batch_sds)
        if cell.kind == "train":
            cfg_t = cfg.scaled(remat=True)
            model = build_model(cfg_t)
            tcfg = TrainConfig(moment_dtype=_moment_dtype(cfg))
            train_step, opt = make_train_step(model, tcfg)
            p_sds = S.param_specs(model)
            p_sh = S.param_shardings(mesh, model, p_sds)
            o_sds = jax.eval_shape(opt.init, p_sds)
            o_sh = type(o_sds)(step=NamedSharding(mesh, P()),
                               m=jax.tree_util.tree_map(lambda s: s, p_sh),
                               v=jax.tree_util.tree_map(lambda s: s, p_sh))
            metrics_sh = None
            fn = jax.jit(train_step,
                         in_shardings=(p_sh, o_sh, batch_sh),
                         out_shardings=(p_sh, o_sh, metrics_sh))
            lowered = fn.lower(p_sds, o_sds, batch_sds)
        else:
            qspec = QuantSpec(bits=4, group_size=128)
            qp_sds = S.quantized_param_specs(model, cfg, qspec)
            qp_sh = S.quantized_param_shardings(mesh, model, qp_sds)
            cache_len = cell.seq_len
            if cfg.family == "vlm":
                cache_len += cfg.patch_len  # patches prepend to the prompt
            if cell.kind == "prefill":
                with axis_rules(mesh, SERVE_PREFILL_RULES):
                    qp_sh = S.quantized_param_shardings(
                        mesh, model, qp_sds, rules=SERVE_PREFILL_RULES)
                    c_sds = S.cache_specs(model, cell.global_batch, cache_len)
                    c_sh = S.cache_shardings(mesh, model, c_sds)
                    extra = {k: batch_sds[k] for k in ("frames", "patches")
                             if k in batch_sds}
                    extra_sh = {k: batch_sh[k] for k in extra}

                    def prefill_fn(params, tokens, cache, extra):
                        return model.prefill(params, tokens, cache, **extra)

                    fn = jax.jit(prefill_fn,
                                 in_shardings=(qp_sh, batch_sh["tokens"],
                                               c_sh, extra_sh),
                                 out_shardings=(None, c_sh))
                    lowered = fn.lower(qp_sds, batch_sds["tokens"], c_sds,
                                       extra)
            else:  # decode — 2D-TP serving layout (perf iteration 1)
                with axis_rules(mesh, SERVE_DECODE_RULES):
                    qp_sh = S.quantized_param_shardings(
                        mesh, model, qp_sds, rules=SERVE_DECODE_RULES)
                    c_sds = S.cache_specs(model, cell.global_batch, cache_len)
                    c_sh = S.cache_shardings(mesh, model, c_sds,
                                             rules=SERVE_DECODE_RULES)
                    tok_sh = S.batch_shardings(mesh, batch_sds,
                                               rules=SERVE_DECODE_RULES)
                    fn = jax.jit(model.decode_step,
                                 in_shardings=(qp_sh, c_sh, tok_sh["tokens"]),
                                 out_shardings=(None, c_sh))
                    lowered = fn.lower(qp_sds, c_sds, batch_sds["tokens"])
    return lowered, {"mesh": "2x16x16" if multi_pod else "16x16",
                     "kind": cell.kind}


def run_cell(arch: str, cell_name: str, multi_pod: bool = False,
             force: bool = False) -> dict:
    os.makedirs(REPORT_DIR, exist_ok=True)
    tag = f"{arch}__{cell_name}__{'2x16x16' if multi_pod else '16x16'}"
    out_path = os.path.join(REPORT_DIR, tag + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    record = {"arch": arch, "cell": cell_name,
              "mesh": "2x16x16" if multi_pod else "16x16"}
    t0 = time.time()
    try:
        lowered, meta = lower_cell(arch, cell_name, multi_pod)
        record.update(meta)
        if lowered is None:
            record["status"] = "skipped"
        else:
            record["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            record["compile_s"] = round(time.time() - t1, 1)
            mem = compiled.memory_analysis()
            record["memory"] = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes":
                    getattr(mem, "generated_code_size_in_bytes", None),
            }
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0]
            record["cost_raw"] = {k: v for k, v in cost.items()
                                  if k in ("flops", "bytes accessed",
                                           "transcendentals")}
            record["collectives_raw"] = collective_bytes(compiled.as_text())
            del compiled

            # --- while-loop trip-count correction ------------------------
            # XLA's cost analysis counts a scan body once, not xL.  Lower
            # the same cell at L=1 and L=2 in cost mode (inner chunk loops
            # forced to one trip so attention/mLSTM FLOPs count fully) and
            # extrapolate: cost(L) = c1 + (L-1) * (c2 - c1).
            from repro.models import common as _common
            cfg = ARCHS[arch]
            _common.set_cost_mode(True)
            try:
                per_l = {}
                for n in (1, 2):
                    lo, _ = lower_cell(arch, cell_name, multi_pod,
                                       cfg_override=_scaled_layers(cfg, n))
                    co = lo.compile()
                    c = co.cost_analysis()
                    if isinstance(c, (list, tuple)):
                        c = c[0]
                    per_l[n] = {
                        "flops": float(c.get("flops", 0.0)),
                        "bytes": float(c.get("bytes accessed", 0.0)),
                        "coll": collective_bytes(co.as_text()),
                    }
                    del co, lo
                L = cfg.n_layers
                c1, c2 = per_l[1], per_l[2]
                record["cost"] = {
                    "flops": max(0.0, c1["flops"]
                                 + (L - 1) * (c2["flops"] - c1["flops"])),
                    "bytes_accessed": max(0.0, c1["bytes"]
                                          + (L - 1) * (c2["bytes"] - c1["bytes"])),
                }
                coll = {}
                keys = set(c1["coll"]) | set(c2["coll"])
                for k in keys:
                    a, b = c1["coll"].get(k, 0), c2["coll"].get(k, 0)
                    # graph-level optimization differences between the L=1
                    # and L=2 lowers can make the diff slightly negative on
                    # tiny terms; clamp (documented in EXPERIMENTS.md)
                    coll[k] = max(0, a + (L - 1) * (b - a))
                record["collectives"] = coll
                record["cost_per_layer"] = per_l
            finally:
                _common.set_cost_mode(False)
            record["status"] = "ok"
        del lowered
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
    gc.collect()
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    status = record["status"]
    extra = "" if status != "error" else " :: " + record["error"][:120]
    print(f"[{time.strftime('%H:%M:%S')}] {tag}: {status} "
          f"(lower {record.get('lower_s', '-')}s, "
          f"compile {record.get('compile_s', '-')}s){extra}", flush=True)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    cells = [args.cell] if args.cell else list(SHAPE_CELLS)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_err = n_skip = 0
    for multi_pod in meshes:
        for arch in archs:
            for cell in cells:
                rec = run_cell(arch, cell, multi_pod, force=args.force)
                st = rec["status"]
                n_ok += st == "ok"
                n_err += st == "error"
                n_skip += st == "skipped"
    print(f"done: {n_ok} ok, {n_skip} skipped-by-design, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
