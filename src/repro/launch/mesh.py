"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any jax
initialization).
"""
from __future__ import annotations

import jax


def _take_devices(shape, what: str):
    n = 1
    for s in shape:
        n *= int(s)
    devices = jax.devices()
    if len(devices) < n:
        raise ValueError(
            f"{what}: mesh shape {tuple(shape)} requires {n} devices but "
            f"only {len(devices)} are available "
            f"({devices[0].platform if devices else 'no'} backend). "
            f"For CPU testing set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            f"the first jax import.")
    return devices[:n]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    devices = _take_devices(shape, "make_production_mesh")
    return jax.make_mesh(shape, axes, devices=devices)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over local devices (CPU tests of the sharded paths)."""
    devices = _take_devices((data, model), "make_local_mesh")
    return jax.make_mesh((data, model), ("data", "model"), devices=devices)
