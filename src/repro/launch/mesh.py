"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any jax
initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    return jax.make_mesh(shape, axes, devices=devices)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices are actually present
    (CPU tests of the sharded code paths)."""
    devices = jax.devices()[:data * model]
    return jax.make_mesh((data, model), ("data", "model"), devices=devices)
