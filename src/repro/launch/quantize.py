"""Distributed PTQ driver: calibrate once, quantize layer-parallel.

The systems property DESIGN.md §5 identifies: FAQ (unlike GPTQ-family
methods) needs only full-precision activation statistics, collected in a
single forward pass for *all* layers at once — after which each
(site, layer) weight quantizes independently.  This driver exploits that:

1. **Calibration** runs under pjit on whatever mesh is available (stats
   reductions over the batch are handled by GSPMD; outputs are tiny
   per-channel vectors).
2. **Quantization work units** — one per (site, layer[, expert]) — are
   partitioned round-robin across processes; each process quantizes its
   slice with the vmapped α search and saves the packed shards through
   dist/checkpoint.  On a pod this turns PTQ of a 405B model into an
   embarrassingly parallel minutes-scale job; on this container
   (process_count == 1) the same code runs the full set locally.

    PYTHONPATH=src python -m repro.launch.quantize --arch llama3-8b --tiny
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.core import QuantSpec, quantize_model, report_summary, run_calibration
from repro.core.apply import _get_path, _quantize_leaf, _set_path
from repro.core.methods import site_stat_for_method
from repro.data.synthetic import DataConfig, SyntheticLM, calibration_batches
from repro.dist import checkpoint as ckpt
from repro.models.registry import build_model


def work_units(site_map: dict) -> list:
    """One unit per mapped parameter path (each vmaps over layers/experts
    internally; the unit is the natural save/shard granularity)."""
    return sorted(site_map.items(), key=lambda kv: "/".join(kv[0]))


def quantize_distributed(model, params, stats, *, method="faq",
                         spec=QuantSpec(), loss="sample", mode="packed",
                         process_index=None, process_count=None):
    """Quantize this process's share of the work units.

    Returns (partial_params, report): ``partial_params`` contains only the
    units owned by this process (plus all unquantized leaves); merging is
    a checkpoint-directory union across processes.
    """
    pi = jax.process_index() if process_index is None else process_index
    pc = jax.process_count() if process_count is None else process_count
    units = work_units(model.quant_site_map())
    own = units[pi::pc]
    new_params = params
    report = {}
    for path, site_key in own:
        w = _get_path(params, path)
        stats_site = stats[site_key]
        stat = None if method == "rtn" else site_stat_for_method(
            method, stats_site["mean_abs"])
        leaf, rep = _quantize_leaf(w, stat, spec,
                                   tuple(jnp.linspace(0, 1, 21).tolist()),
                                   loss, stats_site, mode)
        new_params = _set_path(new_params, path, leaf)
        report["/".join(path)] = rep
    return new_params, report, [ "/".join(p) for p, _ in own ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=sorted(ARCHS))
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--method", default="faq")
    ap.add_argument("--calib-n", type=int, default=16)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].tiny() if args.tiny else ARCHS[args.arch]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size))

    t0 = time.time()
    batches = [{k: jnp.asarray(v) for k, v in b.items()}
               for b in calibration_batches(data, args.calib_n, 64)]
    stats = run_calibration(model.forward, params, batches)
    t_cal = time.time() - t0

    t0 = time.time()
    qparams, report, owned = quantize_distributed(
        model, params, stats, method=args.method,
        spec=QuantSpec(bits=args.bits, group_size=64))
    t_q = time.time() - t0
    print(f"process {jax.process_index()}/{jax.process_count()}: "
          f"calibrated in {t_cal:.1f}s, quantized {len(owned)} units "
          f"in {t_q:.1f}s: {owned}")
    for site, s in report_summary(report).items():
        print(f"  {site:24s} alpha={s['mean_alpha']:.2f} "
              f"(+{100 * s['improvement_vs_rtn']:.1f}% vs RTN)")
    if args.out:
        ckpt.save(args.out, 0, {"qparams": qparams})
        print("saved to", args.out)


if __name__ == "__main__":
    main()
