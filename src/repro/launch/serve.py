"""Production serving entry point: load a checkpoint (or init), calibrate,
FAQ-quantize to packed int4, and serve synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --tiny \
        --requests 4

Tensor-parallel serving (DESIGN.md §13): ``--mesh DATA,MODEL`` builds a
local device mesh and hands it to the engine — weights, KV caches, and
the flash-decode dispatch all shard along the model axis.  For CPU
smoke tests set ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
before launch so enough virtual devices exist.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core import QuantSpec, quantize_model, run_calibration
from repro.data.synthetic import DataConfig, SyntheticLM, calibration_batches
from repro.dist import checkpoint as ckpt
from repro.launch.mesh import make_local_mesh
from repro.models.registry import build_model
from repro.obs import Tracer, profile_session
from repro.serve.draft import registry_draft, self_int8_draft
from repro.serve.engine import Request, ServeEngine
from repro.serve.faults import FaultConfig, FaultInjector
from repro.serve.overload import SLOConfig
from repro.serve.spec import SpecConfig


def parse_chunk(arg):
    """'auto' | int tokens | 0/'none' to disable chunked prefill."""
    if arg == "auto":
        return "auto"
    try:
        n = int(arg)
    except ValueError:
        if arg.lower() in ("none", "off"):
            return None
        raise argparse.ArgumentTypeError(
            f"--prefill-chunk expects 'auto', an int, or 0/none, got {arg!r}")
    return n if n > 0 else None


def parse_mesh(arg):
    """'DATA,MODEL' -> (data, model), with clear errors for bad input."""
    if arg is None:
        return None
    try:
        data, model = (int(x) for x in arg.split(","))
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--mesh expects 'DATA,MODEL' (two comma-separated ints), "
            f"got {arg!r}")
    if data < 1 or model < 1:
        raise argparse.ArgumentTypeError(
            f"--mesh sizes must be >= 1, got {arg!r}")
    return data, model


def parse_at(arg):
    """Comma-separated 0-based event indices -> tuple of ints."""
    if not arg:
        return ()
    try:
        return tuple(int(x) for x in arg.split(","))
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"expected comma-separated ints, got {arg!r}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=sorted(ARCHS))
    # BooleanOptionalAction so --no-tiny can actually select the full
    # config (the old store_true/default=True combo was impossible to
    # disable from the command line)
    ap.add_argument("--tiny", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--method", default="faq", choices=["rtn", "awq", "faq"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--calib-n", type=int, default=16)
    ap.add_argument("--n-slots", type=int, default=4,
                    help="decode batch width (continuous-batching slots)")
    ap.add_argument("--max-len", type=int, default=128,
                    help="per-slot KV-cache capacity (prompt + new tokens)")
    ap.add_argument("--paged", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="paged KV cache with shared-prefix reuse "
                         "(DESIGN.md §10); --no-paged keeps the dense "
                         "per-slot cache")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per physical KV page (paged mode)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="page-pool capacity; default sizes it so every "
                         "slot can hold a full max_len sequence")
    ap.add_argument("--prefill-chunk", type=parse_chunk, default="auto",
                    metavar="auto|N|0",
                    help="chunked prefill: split long admissions into "
                         "bucket-sized chunks so one long prompt can't "
                         "stall other slots' first tokens (DESIGN.md "
                         "§14); 'auto' picks the second-largest bucket, "
                         "an int rounds up to the bucket grid, 0 "
                         "restores monolithic prefill")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding draft depth (tokens "
                         "proposed per cycle; 0 disables — DESIGN.md §12)")
    ap.add_argument("--draft", default="self-int8",
                    help="draft source for --spec-k: 'self-int8' (FAQ "
                         "int8 self-draft sharing the target's KV) or a "
                         "registry config name for an independent draft "
                         "model")
    ap.add_argument("--mesh", type=parse_mesh, default=None,
                    metavar="DATA,MODEL",
                    help="serve tensor-parallel on a (data, model) device "
                         "mesh, e.g. --mesh 1,4 (requires data*model "
                         "devices; DESIGN.md §13)")
    # -- overload response (DESIGN.md §16) --
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request SLO relative to submission; enables "
                         "SLO-aware admission (doomed requests shed early)")
    ap.add_argument("--slo-margin", type=float, default=1.0,
                    help="shed when now + margin*queue_delay_est exceeds "
                         "the deadline")
    ap.add_argument("--quota-tokens", type=int, default=0,
                    help="per-tenant in-flight token quota (0 = off)")
    # -- deterministic fault injection (serve/faults.py) --
    ap.add_argument("--fault-alloc-at", type=parse_at, default=(),
                    metavar="I,J,...",
                    help="veto the i-th page allocations (0-based) to "
                         "exercise backpressure/preemption")
    ap.add_argument("--fault-alloc-every", type=int, default=0,
                    help="veto every Nth page allocation")
    ap.add_argument("--fault-preempt-at", type=parse_at, default=(),
                    metavar="I,J,...",
                    help="force-preempt the latest-deadline slot at the "
                         "i-th serve-loop iterations")
    ap.add_argument("--fault-stall-at", type=parse_at, default=(),
                    metavar="I,J,...",
                    help="inject a slow step at the i-th loop iterations")
    ap.add_argument("--fault-stall-s", type=float, default=0.0)
    ap.add_argument("--fault-seed", type=int, default=0)
    # -- observability (DESIGN.md §17) --
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export the request/step trace as Chrome/"
                         "Perfetto trace_event JSON (open in "
                         "ui.perfetto.dev)")
    ap.add_argument("--trace-capacity", type=int, default=8192,
                    help="trace ring-buffer size (oldest events drop "
                         "beyond it)")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="wrap the run in a jax.profiler trace "
                         "(TensorBoard-compatible) and annotate jitted "
                         "dispatches")
    args = ap.parse_args()

    mesh = None
    if args.mesh is not None:
        mesh = make_local_mesh(*args.mesh)
        print(f"mesh: data={args.mesh[0]} model={args.mesh[1]} over "
              f"{len(mesh.devices.flat)} {mesh.devices.flat[0].platform} "
              f"devices")

    cfg = ARCHS[args.arch].tiny() if args.tiny else ARCHS[args.arch]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        step = ckpt.latest_step(args.ckpt_dir)
        if step is not None:
            params = ckpt.restore(args.ckpt_dir, step,
                                  {"params": params})["params"]
            print(f"loaded checkpoint step {step}")

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size))
    calib = calibration_batches(data, args.calib_n, 64)
    stats = run_calibration(model.forward, params,
                            [{k: jnp.asarray(v) for k, v in b.items()}
                             for b in calib])
    qparams, _ = quantize_model(params, model.quant_site_map(), stats,
                                method=args.method,
                                spec=QuantSpec(bits=args.bits, group_size=64),
                                mode="packed")
    spec_cfg = None
    if args.spec_k > 0:
        # the self-draft re-quantizes the *serving* weights at int8 (the
        # packed codes are all it needs) with the same calibration stats
        if args.draft == "self-int8":
            draft = self_int8_draft(model, qparams, stats)
        else:
            draft = registry_draft(args.draft, tiny=args.tiny)
        spec_cfg = SpecConfig(k=args.spec_k, draft=draft)
    slo = None
    if args.deadline_s is not None or args.quota_tokens > 0:
        slo = SLOConfig(margin=args.slo_margin,
                        quota_tokens=args.quota_tokens,
                        seed=args.fault_seed)
    faults = None
    if (args.fault_alloc_at or args.fault_alloc_every
            or args.fault_preempt_at or args.fault_stall_at):
        faults = FaultInjector(FaultConfig(
            seed=args.fault_seed,
            alloc_fail_at=args.fault_alloc_at,
            alloc_fail_every=args.fault_alloc_every,
            preempt_at=args.fault_preempt_at,
            stall_at=args.fault_stall_at, stall_s=args.fault_stall_s))
    tracer = (Tracer(capacity=args.trace_capacity)
              if args.trace_out else None)
    eng = ServeEngine(model, qparams,
                      n_slots=min(args.n_slots, args.requests),
                      max_len=args.max_len, paged=args.paged,
                      page_size=args.page_size, n_pages=args.n_pages,
                      prefill_chunk=args.prefill_chunk,
                      spec=spec_cfg, mesh=mesh, slo=slo, faults=faults,
                      tracer=tracer, profile=bool(args.profile_dir))
    if args.paged and not eng.paged:
        print("note: model cache layout does not support paging; "
              "serving from the dense cache")
    if spec_cfg is not None and eng._spec is None:
        print("note: model lacks the span-write decode path; serving "
              "non-speculatively")
    reqs = [Request(rid=i, prompt=data.sequence(40_000_000 + i, 12),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    if args.deadline_s is not None:
        t_sub = eng.clock()
        for r in reqs:
            r.arrival = t_sub
            r.deadline = t_sub + args.deadline_s
    t0 = time.time()
    with profile_session(args.profile_dir):
        results = eng.serve(reqs)
    dt = time.time() - t0
    tok = sum(len(v) for v in results.values())
    for rid in sorted(results):
        print(f"req {rid}: {results[rid].tolist()}")
    m = eng.metrics()
    print(f"{tok} tokens in {dt:.1f}s ({tok/dt:.1f} tok/s, "
          f"{args.method} int{args.bits} packed)")
    print(f"prefill: {m['prefill_batches']} batches / "
          f"{m['prefill_traces']} traces (buckets {m['buckets']}, "
          f"chunk {m['prefill_chunk'] or 'off'}, "
          f"{m['chunked_admissions']} chunked), "
          f"decode: {m['decode_steps']} steps, "
          f"retraces: {m['retrace_count']}")
    retraced = {k: v for k, v in m["retrace_by_entry"].items() if v}
    if retraced:
        print(f"retraces by entry: {retraced}")
    if m["paged"]:
        print(f"paged: page_size={m['page_size']}, "
              f"peak {m['pages_peak']}/{m['pages_total']} pages "
              f"({m['peak_cache_bytes']/1e6:.2f} MB), "
              f"prefix hits {m['prefix_hits']} "
              f"({m['prefix_hit_tokens']} tokens skipped), "
              f"cow copies {m['cow_copies']}")
    if slo is not None or faults is not None or m["preempted"]:
        print(f"overload: shed {m['shed']} "
              f"(+{m['shed_retried']} retried), "
              f"expired {m['expired']}, truncated {m['truncated']}, "
              f"preempted {m['preempted']}, resumed {m['resumed']}, "
              f"pressure events {m['pressure_events']}")
    if m["faults"] is not None:
        print(f"faults: {m['faults']}")
    if m["spec"]:
        print(f"spec: k={m['spec_k']} draft={m['draft_kind']}, "
              f"accept_rate {m['accept_rate']:.2f}, "
              f"tokens/step {m['tokens_per_step']:.2f}, "
              f"draft share {m['draft_share']:.2f} "
              f"({m['spec_cycles']} cycles, "
              f"{m['draft_steps']} draft steps)")
    if args.trace_out:
        eng.export_trace(args.trace_out)
        print(f"trace: {m['trace']['events']} events "
              f"({m['trace']['dropped']} dropped) -> {args.trace_out} "
              f"(open in ui.perfetto.dev)")
    if args.profile_dir:
        print(f"profile: jax.profiler trace in {args.profile_dir} "
              f"(tensorboard --logdir)")


if __name__ == "__main__":
    main()
