"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape) cell.

Everything here is allocation-free: parameter/optimizer/cache shapes come
from ``jax.eval_shape`` over the real init/quantize functions, so the
dry-run lowers exactly the structures the runtime would build.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.core import QuantSpec
from repro.core.apply import quantize_model
from repro.dist.sharding import logical_to_spec, tree_shardings
from repro.models.registry import build_model

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# Inputs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStructs for the model inputs of one cell."""
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        batch = {"tokens": SDS((b, s), jnp.int32),
                 "labels": SDS((b, s), jnp.int32)}
    elif cell.kind == "prefill":
        batch = {"tokens": SDS((b, s), jnp.int32)}
    else:  # decode
        batch = {"tokens": SDS((b, 1), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = SDS((b, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm" and cell.kind != "decode":
        batch["patches"] = SDS((b, cfg.patch_len, cfg.d_model), jnp.bfloat16)
    return batch


def batch_shardings(mesh, batch: dict, rules=None) -> dict:
    out = {}
    for k, v in batch.items():
        axes = ["batch"] + [None] * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, logical_to_spec(axes, shape=v.shape,
                                                     mesh=mesh, rules=rules))
    return out


# ---------------------------------------------------------------------------
# Params / optimizer / cache specs
# ---------------------------------------------------------------------------

def param_specs(model) -> dict:
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def param_shardings(mesh, model, specs=None):
    specs = specs if specs is not None else param_specs(model)
    return tree_shardings(mesh, specs, model.param_axes())


def stats_specs(model, cfg: ModelConfig) -> dict:
    """Abstract per-site calibration stats (for eval_shape of quantize)."""
    batch = {"tokens": SDS((2, 32), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = SDS((2, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = SDS((2, cfg.patch_len, cfg.d_model), jnp.bfloat16)
    p = param_specs(model)
    _, aux = jax.eval_shape(
        lambda pp, bb: model.forward(pp, bb, collect_stats=True), p, batch)
    return aux["stats"]


def quantized_param_specs(model, cfg: ModelConfig,
                          spec: QuantSpec = QuantSpec(bits=4)) -> dict:
    """Abstract packed-quantized params (the serving representation)."""
    p = param_specs(model)
    stats = stats_specs(model, cfg)

    def quantize(pp, st):
        qp, _ = quantize_model(pp, model.quant_site_map(), st, method="faq",
                               spec=spec, mode="packed", loss="diag")
        return qp

    return jax.eval_shape(quantize, p, stats)


_QT_CHILD_NAMES = ("codes", "scale", "zero", "act_scale")


def quantized_param_shardings(mesh, model, qspecs, rules=None):
    """Shardings for a quantized param tree.

    FP leaves follow param_axes; QuantizedTensor children derive from the
    original weight's axes: codes shard like the weight (input dim halves
    but divisibility is re-checked), group scales/zeros keep only the
    output-dim sharding, act_scale is replicated (small).
    """
    axes = model.param_axes()

    def axes_at(path):
        node = axes
        for k in path:
            if hasattr(k, "key"):
                kk = k.key
            elif hasattr(k, "idx"):
                kk = k.idx
            else:
                kk = k
            if isinstance(node, dict):
                node = node.get(kk) if isinstance(kk, str) else node
                if node is None:
                    return None
                continue
            if isinstance(node, (list, tuple)) and isinstance(kk, int) \
                    and not isinstance(node, tuple):
                node = node[kk]
        return node

    from repro.core.quantizer import QuantizedTensor

    def one(path, leaf):
        # find the param-level path (strip QuantizedTensor child suffix)
        keys = []
        qt_child = None
        for k in path:
            if hasattr(k, "key") and isinstance(k.key, str):
                keys.append(k.key)
            elif hasattr(k, "idx"):
                qt_child = k.idx
        node = axes
        for kk in keys:
            node = node.get(kk) if isinstance(node, dict) else None
            if node is None:
                break
        if node is None or not isinstance(node, (tuple, list)):
            return NamedSharding(mesh, P())
        w_axes = list(node)
        if qt_child is None:           # plain FP leaf
            ax = w_axes
        elif qt_child == 0:            # codes: same layout as the weight
            ax = w_axes
        elif qt_child in (1, 2):       # scale / zero: (…, n_groups, n_out)
            ax = w_axes[:-2] + [None, w_axes[-1]]
        else:                          # act_scale: (…, n_in)
            ax = [None] * (len(leaf.shape))
        ax = ax[:len(leaf.shape)]
        while len(ax) < len(leaf.shape):
            ax.append(None)
        return NamedSharding(mesh, logical_to_spec(ax, shape=leaf.shape,
                                                   mesh=mesh, rules=rules))

    return jax.tree_util.tree_map_with_path(one, qspecs)


def cache_specs(model, batch: int, max_len: int) -> dict:
    return jax.eval_shape(lambda: model.init_cache(batch, max_len))


def cache_shardings(mesh, model, cspecs, rules=None):
    return tree_shardings(mesh, cspecs, model.cache_axes(), rules=rules)
