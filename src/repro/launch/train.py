"""Production training entry point.

Builds the mesh from the available devices (production 16x16 / 2x16x16
on pods; whatever is present elsewhere — a single CPU device degrades to
local training, which is how this container runs it), shards params and
optimizer state via the logical-axis rules, and runs the checkpointed
training loop with automatic resume and elastic re-mesh planning.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --tiny \
        --steps 200 --ckpt-dir reports/launch_train
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPE_CELLS
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.dist import checkpoint as ckpt
from repro.dist.elastic import plan_mesh
from repro.dist.sharding import axis_rules, tree_shardings
from repro.launch import specs as S
from repro.models.registry import build_model
from repro.train.trainer import TrainConfig, make_train_step


def build_mesh():
    n = len(jax.devices())
    if n == 1:
        return None
    plan = plan_mesh(n, model=min(16, n), old_data=max(1, n // 16))
    import numpy as np
    devices = jax.devices()[:plan.used_chips]
    return jax.make_mesh((plan.data, plan.model), ("data", "model"),
                         devices=devices)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None, choices=sorted(SHAPE_CELLS))
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="reports/launch_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].tiny() if args.tiny else ARCHS[args.arch]
    if args.shape:
        cell = SHAPE_CELLS[args.shape]
        args.batch, args.seq = cell.global_batch, cell.seq_len
    model = build_model(cfg)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size))
    tcfg = TrainConfig(lr=3e-3, warmup=20, total_steps=args.steps)
    train_step, opt = make_train_step(model, tcfg)

    mesh = build_mesh()
    ctx = axis_rules(mesh) if mesh is not None else _null_ctx()
    with ctx:
        if mesh is not None:
            p_sh = S.param_shardings(mesh, model)
            init = jax.jit(lambda k: model.init(k), out_shardings=p_sh)
            params = init(jax.random.PRNGKey(0))
        else:
            params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        step_fn = jax.jit(train_step)

        start = 0
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            restored = ckpt.restore(
                args.ckpt_dir, last, {"params": params, "opt": opt_state},
                shardings={"params": S.param_shardings(mesh, model),
                           "opt": None} if mesh is not None else None)
            params, opt_state, start = (restored["params"], restored["opt"],
                                        last)
            print(f"resumed from step {last} "
                  f"(mesh {'x'.join(map(str, mesh.devices.shape)) if mesh else 'local'})")

        t0 = time.time()
        metrics = {}
        for step in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in
                     data.batch(step, args.batch, args.seq,
                                host=jax.process_index(),
                                n_hosts=jax.process_count()).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % 20 == 0:
                print(f"step {step:5d} loss {float(metrics['loss']):.3f}",
                      flush=True)
            if step and step % args.ckpt_every == 0:
                ckpt.save_async(args.ckpt_dir, step,
                                {"params": params, "opt": opt_state})
        ckpt.wait_pending()
        ckpt.save(args.ckpt_dir, args.steps,
                  {"params": params, "opt": opt_state})
        dt = time.time() - t0
        print(f"done {args.steps - start} steps in {dt:.1f}s; "
              f"final loss {float(metrics.get('loss', float('nan'))):.3f}")


class _null_ctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
