"""Model zoo: 10 assigned architectures over shared primitives."""
