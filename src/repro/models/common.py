"""Shared model primitives: norms, RoPE, chunked attention, SwiGLU, linears.

All weights are stored ``(n_in, n_out)`` (``y = x @ W``) so the quantizer's
input-channel-group convention applies directly.  Every quantizable matmul
goes through :func:`qlinear`, which dispatches on the leaf type: plain
arrays matmul directly; :class:`~repro.core.quantizer.QuantizedTensor`
leaves route through the dequant-matmul op (serving path).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantizedTensor
from repro.dist.sharding import row_parallel, shard_hint


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, n_in: int, n_out: int, dtype=jnp.float32,
               scale: Optional[float] = None) -> jax.Array:
    scale = (1.0 / n_in) ** 0.5 if scale is None else scale
    return (jax.random.normal(key, (n_in, n_out)) * scale).astype(dtype)


def stack_layer_params(key, n_layers: int, init_fn):
    """Init per-layer params and stack along a leading L axis (for scan)."""
    keys = jax.random.split(key, n_layers)
    per_layer = [init_fn(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *per_layer)


# ---------------------------------------------------------------------------
# Linear dispatch (FP or quantized)
# ---------------------------------------------------------------------------

def qlinear(x: jax.Array, w) -> jax.Array:
    """``x @ w`` where ``w`` is an array or a QuantizedTensor."""
    if isinstance(w, QuantizedTensor):
        from repro.kernels.ops import quant_matmul
        return quant_matmul(x, w)
    return x @ w.astype(x.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * w.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE for qwen2-vl)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4,
               mrope_sections: Optional[tuple] = None) -> jax.Array:
    """Rotary embedding.

    ``x``: (B, T, H, hd).  ``positions``: (B, T) for standard RoPE or
    (3, B, T) for M-RoPE (temporal/height/width position ids per token;
    text-only inputs pass the same ids three times, which reduces exactly
    to standard RoPE).
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    if mrope_sections is None:
        ang = positions.astype(jnp.float32)[..., None] * freqs  # (B,T,hd/2)
    else:
        assert positions.ndim == 3, "M-RoPE needs (3, B, T) position ids"
        secs = []
        start = 0
        for i, sec in enumerate(mrope_sections):
            f = freqs[start:start + sec]
            secs.append(positions[i].astype(jnp.float32)[..., None] * f)
            start += sec
        ang = jnp.concatenate(secs, axis=-1)            # (B,T,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention: chunked (flash-style) for train/prefill, direct for decode.
# ---------------------------------------------------------------------------

def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    b, t, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, h, n_rep, d)) \
              .reshape(b, t, h * n_rep, d)


# Cost-mode (dry-run cost_analysis only): XLA does not multiply while-loop
# bodies by trip count, so the dry-run's cost variant forces inner chunk
# loops to a single trip (full-T blocks) so their FLOPs are fully counted.
_COST_MODE = False


def set_cost_mode(enabled: bool):
    global _COST_MODE
    _COST_MODE = enabled


def cost_mode() -> bool:
    return _COST_MODE


def layer_scan(body, init, xs):
    """lax.scan for the layer stack; fully unrolled in cost mode so
    cost_analysis counts every layer (XLA never multiplies while-loop
    bodies by trip count)."""
    return jax.lax.scan(body, init, xs, unroll=True if _COST_MODE else 1)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True,
                      window: Optional[int] = None,
                      q_offset: int = 0,
                      chunk: int = 512,
                      kv_lens: Optional[jax.Array] = None) -> jax.Array:
    """Memory-O(T·chunk) attention via a scan over KV chunks.

    q: (B, Tq, H, hd); k, v: (B, Tk, KH, hd) with H % KH == 0 (GQA).
    ``q_offset`` is the absolute position of q[0] (for decode/prefill
    continuation).  ``window`` enables sliding-window masking (hymba).
    ``kv_lens`` (B,) int32 masks keys at positions >= kv_lens[b] — the
    length-aware causal mask for bucket-padded batched prefill, where
    prompts of different true lengths share one padded shape.
    """
    b, tq, h, hd = q.shape
    tk, kh = k.shape[1], k.shape[2]
    if (kv_lens is None and window is not None and causal and tq == tk
            and q_offset == 0 and tk > 2 * window):
        # sliding-window self-attention: block-local path is O(T*2w)
        # instead of O(T^2) (perf iteration 3, EXPERIMENTS.md §Perf)
        return local_window_attention(q, k, v, window)
    if (jax.default_backend() == "tpu" and window is None and q_offset == 0
            and tq == tk and hd <= 128 and tq % 128 == 0
            and kv_lens is None and not _COST_MODE):
        # TPU deployments run the Pallas flash kernel (scores stay in
        # VMEM); CPU/tests keep the chunked jnp path below.  q is passed
        # in grouped GQA layout (BKH, G, T, hd) so the kernel reads the
        # *unrepeated* cache — repeating KV to q-heads would multiply
        # K/V HBM traffic by G and force the same replicating reshard
        # the decode path avoids (see kernels/flash_decode.py).
        from repro.kernels.flash_attention import flash_attention_pallas
        g = h // kh
        qr = q.reshape(b, tq, kh, g, hd).transpose(0, 2, 3, 1, 4) \
             .reshape(b * kh, g, tq, hd)
        kr = k.transpose(0, 2, 1, 3).reshape(b * kh, tk, hd)
        vr = v.transpose(0, 2, 1, 3).reshape(b * kh, tk, hd)
        o = flash_attention_pallas(qr, kr, vr, causal=causal,
                                   interpret=False)
        return o.reshape(b, kh, g, tq, hd).transpose(0, 3, 1, 2, 4) \
                .reshape(b, tq, h, hd)
    k = _repeat_kv(k, h // kh)
    v = _repeat_kv(v, h // kh)
    if _COST_MODE:
        chunk = tk
    chunk = min(chunk, tk)
    n_chunks = tk // chunk
    rem = tk - n_chunks * chunk
    scale = hd ** -0.5
    q32 = q.astype(jnp.float32) * scale
    qpos = q_offset + jnp.arange(tq)

    def attend_block(carry, kb, vb, kpos):
        m, l, acc = carry
        s = jnp.einsum("bqhd,bkhd->bhqk", q32, kb.astype(jnp.float32))
        mask = jnp.ones((tq, kb.shape[1]), dtype=bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        if kv_lens is not None:
            mask_b = mask[None] & (kpos[None, None, :]
                                   < kv_lens[:, None, None])
            mask = mask_b[:, None]            # (B, 1, Tq, Kb)
        else:
            mask = mask[None, None]           # (1, 1, Tq, Kb)
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new)

    init = (jnp.full((b, h, tq), -jnp.inf, jnp.float32),
            jnp.zeros((b, h, tq), jnp.float32),
            jnp.zeros((b, h, tq, hd), jnp.float32))

    if n_chunks > 0:
        kc = k[:, :n_chunks * chunk].reshape(b, n_chunks, chunk, h, hd)
        vc = v[:, :n_chunks * chunk].reshape(b, n_chunks, chunk, h, hd)
        kposc = jnp.arange(n_chunks * chunk).reshape(n_chunks, chunk)

        def body(carry, xs):
            kb, vb, kpos = xs
            return attend_block(carry, kb, vb, kpos), None

        carry, _ = jax.lax.scan(
            body, init,
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), kposc))
    else:
        carry = init
    if rem:
        carry = attend_block(carry, k[:, n_chunks * chunk:],
                             v[:, n_chunks * chunk:],
                             jnp.arange(n_chunks * chunk, tk))
    m, l, acc = carry
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B, Tq, H, hd)


# ``decode_attention`` and friends live in kernels/ops.py now: the jnp
# implementations moved to kernels/ref.py as the oracles of the split-KV
# flash-decode Pallas kernels, and every decode call site dispatches
# through the ops entry points (REPRO_KERNEL_MODE ref/interpret/tpu) in
# the caches' native (B, KH, S, hd) / (P, KH, ps, hd) layouts.


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def swiglu(x: jax.Array, w_gate, w_up, w_down) -> jax.Array:
    g = qlinear(x, w_gate)
    u = qlinear(x, w_up)
    h = jax.nn.silu(g) * u
    h = shard_hint(h, "batch", "seq", "ff")
    with row_parallel():
        return qlinear(h, w_down)


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------

def padded_vocab(vocab_size: int, multiple: int = 256) -> int:
    return ((vocab_size + multiple - 1) // multiple) * multiple


def embed_tokens(embedding: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(embedding, tokens, axis=0)


def logits_from_hidden(x: jax.Array, lm_head, vocab_size: int) -> jax.Array:
    """Final projection.  Logits keep the *padded* vocab width (sharding
    stays clean); padded columns get a -1e30 additive mask so softmax,
    cross-entropy, and argmax all behave as if the vocab were unpadded."""
    out = qlinear(x, lm_head)
    out = shard_hint(out, "batch", "seq", "vocab")
    v_pad = out.shape[-1]
    if v_pad != vocab_size:
        bias = jnp.where(jnp.arange(v_pad) < vocab_size, 0.0, -1e30)
        out = out.astype(jnp.float32) + bias
    return out


def last_valid_hidden(x: jax.Array, lens: jax.Array) -> jax.Array:
    """Gather the hidden state of each row's last valid token.

    x: (B, T, d); lens: (B,) int32 with 1 <= lens[b] <= T.  Returns
    (B, 1, d) — row b's position ``lens[b] - 1``.  Bucket-padded prefill
    uses this instead of ``x[:, -1:]`` so padded tail positions never
    leak into the first sampled token.
    """
    idx = jnp.clip(lens.astype(jnp.int32) - 1, 0, x.shape[1] - 1)
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)


def quantize_kv(x: jax.Array):
    """Per-(token, head) symmetric int8 quantization of fresh K/V entries.

    x: (B, T, KH, hd) -> (codes int8 same shape, scale (B, T, KH, 1) f32).
    Beyond-paper serving feature (cfg.kv_cache_bits=8): halves KV-cache
    HBM footprint/traffic — complements FAQ's 4-bit weights, same
    deployment story."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return codes.astype(jnp.int8), scale


def update_cache_at(cache: jax.Array, new: jax.Array,
                    pos: jax.Array) -> jax.Array:
    """Write the span ``new`` (B, KH, T, hd) into ``cache`` (B, KH, S, hd)
    starting at per-batch positions ``pos`` (B,) — vmapped
    dynamic_update_slice.  T = 1 is the decode hot path; T > 1 writes a
    speculative verify burst in one op (the caller guarantees
    ``pos + T <= S`` for live slots)."""
    pos = jnp.broadcast_to(pos, (cache.shape[0],))
    return jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (0, p, 0)))(
        cache, new, pos)


# ---------------------------------------------------------------------------
# Paged KV cache (serve/pages.py holds the host-side allocator; this is
# the device-side scatter primitive — the gather/attention side lives in
# kernels/flash_decode.py with its jnp oracle in kernels/ref.py)
# ---------------------------------------------------------------------------

def update_pages_at(store: jax.Array, new: jax.Array, page_ids: jax.Array,
                    offsets: jax.Array) -> jax.Array:
    """Write each slot's fresh KV entry into its current physical page.

    store: (P, KH, ps, d); new: (B, KH, 1, d); page_ids/offsets: (B,).
    The engine guarantees every written page is exclusively owned
    (copy-on-write happens host-side first), and inactive slots' tables
    point at the trash page — so the static per-slot write loop never
    races two owners on one page (writes are sequential; only the trash
    page absorbs more than one, and nothing reads it).
    """
    for b in range(new.shape[0]):
        store = jax.lax.dynamic_update_slice(
            store, new[b:b + 1], (page_ids[b], 0, offsets[b], 0))
    return store


def local_window_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           window: int) -> jax.Array:
    """Causal sliding-window self-attention in block-local form.

    q/k/v: (B, T, H|KH, hd).  The sequence is cut into blocks of size
    ``window``; block j's queries attend only to blocks (j-1, j), which
    covers every in-window key exactly once — compute and score traffic
    drop from O(T^2) to O(T * 2*window).  Used by hymba (the attention
    half of its hybrid blocks).
    """
    b, t, h, hd = q.shape
    kh = k.shape[2]
    k = _repeat_kv(k, h // kh)
    v = _repeat_kv(v, h // kh)
    w = window
    pad = (-t) % w
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tp = t + pad
    nb = tp // w
    qb = q.reshape(b, nb, w, h, hd).astype(jnp.float32) * hd ** -0.5
    kb = k.reshape(b, nb, w, h, hd)
    vb = v.reshape(b, nb, w, h, hd)
    zeros = jnp.zeros_like(kb[:, :1])
    k_prev = jnp.concatenate([zeros, kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([zeros, vb[:, :-1]], axis=1)
    k_cat = jnp.concatenate([k_prev, kb], axis=2)   # (B, nb, 2w, H, hd)
    v_cat = jnp.concatenate([v_prev, vb], axis=2)

    i = jnp.arange(w)[:, None]
    l = jnp.arange(2 * w)[None, :]
    dist = i + w - l
    base_mask = (dist >= 0) & (dist < w)            # (w, 2w)

    def block(carry, xs):
        j, qj, kj, vj = xs
        # absolute key positions for validity (padding + first block)
        kpos = j * w - w + jnp.arange(2 * w)
        valid = (kpos >= 0) & (kpos < t)
        mask = base_mask & valid[None, :]
        s = jnp.einsum("bqhd,bkhd->bhqk", qj, kj.astype(jnp.float32))
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, vj.astype(jnp.float32))
        return carry, o

    _, ob = layer_scan(block, None,
                       (jnp.arange(nb),
                        qb.transpose(1, 0, 2, 3, 4),
                        k_cat.transpose(1, 0, 2, 3, 4),
                        v_cat.transpose(1, 0, 2, 3, 4)))
    out = ob.transpose(1, 0, 2, 3, 4).reshape(b, tp, h, hd)[:, :t]
    return out.astype(q.dtype)
