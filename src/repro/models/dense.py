"""Dense llama-style decoder LM (stablelm / llama3 / deepseek-coder).

Functional style: ``init`` builds a nested-dict param tree with per-layer
weights stacked on a leading L axis; ``forward``/``prefill``/``decode_step``
scan over layers.  KV cache layout is ``(L, B, KH, S, hd)`` — kv-heads
before sequence so the sharding-hint priority picks head-sharding when the
head count divides the model axis and falls back to sequence sharding
otherwise (see dist/sharding.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.stats import site_stat
from repro.dist.sharding import row_parallel, shard_hint
from repro.kernels.ops import (decode_attention, decode_attention_q8,
                               paged_decode_attention,
                               paged_decode_attention_q8,
                               paged_verify_attention,
                               paged_verify_attention_q8, verify_attention,
                               verify_attention_q8)
from .common import (layer_scan,
                     apply_rope, chunked_attention, quantize_kv,
                     dense_init, embed_tokens, last_valid_hidden,
                     logits_from_hidden,
                     padded_vocab, qlinear, rms_norm,
                     stack_layer_params, update_cache_at, update_pages_at)


class DenseLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)

    # -- params ------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        hd = cfg.head_dim_
        v_pad = padded_vocab(cfg.vocab_size)
        k_emb, k_blocks, k_head = jax.random.split(key, 3)

        def block_init(k):
            ks = jax.random.split(k, 7)
            return {
                "attn_norm": jnp.ones((cfg.d_model,), self.dtype),
                "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, self.dtype),
                "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, self.dtype),
                "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, self.dtype),
                "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, self.dtype),
                "mlp_norm": jnp.ones((cfg.d_model,), self.dtype),
                "w_gate": dense_init(ks[4], cfg.d_model, cfg.d_ff, self.dtype),
                "w_up": dense_init(ks[5], cfg.d_model, cfg.d_ff, self.dtype),
                "w_down": dense_init(ks[6], cfg.d_ff, cfg.d_model, self.dtype),
            }

        return {
            "embed": dense_init(k_emb, v_pad, cfg.d_model, self.dtype,
                                scale=0.02),
            "blocks": stack_layer_params(k_blocks, cfg.n_layers, block_init),
            "final_norm": jnp.ones((cfg.d_model,), self.dtype),
            "lm_head": dense_init(k_head, cfg.d_model, v_pad, self.dtype),
        }

    def param_axes(self) -> dict:
        return {
            "embed": ("vocab", "fsdp"),
            "blocks": {
                "attn_norm": (None, None),
                "wq": (None, "fsdp", "heads"),
                "wk": (None, "fsdp", None),
                "wv": (None, "fsdp", None),
                "wo": (None, "heads", "fsdp"),
                "mlp_norm": (None, None),
                "w_gate": (None, "fsdp", "ff"),
                "w_up": (None, "fsdp", "ff"),
                "w_down": (None, "ff", "fsdp"),
            },
            "final_norm": (None,),
            "lm_head": ("fsdp", "vocab"),
        }

    def quant_site_map(self) -> dict:
        return {
            ("blocks", "wq"): "attn_in",
            ("blocks", "wk"): "attn_in",
            ("blocks", "wv"): "attn_in",
            ("blocks", "wo"): "attn_out",
            ("blocks", "w_gate"): "mlp_in",
            ("blocks", "w_up"): "mlp_in",
            ("blocks", "w_down"): "mlp_down",
        }

    # -- block -------------------------------------------------------------
    def _attn(self, p, x, positions, *, kv_write=None, cache=None,
              cache_len=None, kv_lens=None, paged=None):
        """Attention sub-block.  Returns (out, (k, v)) — k/v as produced
        (for prefill cache capture).

        ``paged`` switches decode to the paged KV store: a
        ``(page_table, page_ids, offsets)`` triple, with ``cache``
        holding this layer's physical page-store leaves instead of
        dense per-slot caches (see DESIGN.md §10).
        """
        cfg = self.cfg
        hd = cfg.head_dim_
        b, t, _ = x.shape
        q = qlinear(x, p["wq"]).reshape(b, t, cfg.n_heads, hd)
        k = qlinear(x, p["wk"]).reshape(b, t, cfg.n_kv_heads, hd)
        v = qlinear(x, p["wv"]).reshape(b, t, cfg.n_kv_heads, hd)
        q = apply_rope(q, positions, cfg.rope_theta,
                       mrope_sections=cfg.mrope_sections or None)
        k = apply_rope(k, positions, cfg.rope_theta,
                       mrope_sections=cfg.mrope_sections or None)
        q = shard_hint(q, "batch", "seq", "heads", None)
        k = shard_hint(k, "batch", "seq", "kv_heads", None)
        v = shard_hint(v, "batch", "seq", "kv_heads", None)
        if paged is not None:
            # page_ids/offsets are (B, T): the span t > 1 (speculative
            # verify) may cross a page boundary, so each position writes
            # through its own physical page.
            table, page_ids, offsets = paged
            window = cfg.sliding_window or None
            if cfg.kv_cache_bits == 8:
                k_st, ks_st, v_st, vs_st = cache
                kq, ks = quantize_kv(k)
                vq, vs = quantize_kv(v)
                kq, ks = kq.transpose(0, 2, 1, 3), ks.transpose(0, 2, 1, 3)
                vq, vs = vq.transpose(0, 2, 1, 3), vs.transpose(0, 2, 1, 3)
                for i in range(t):
                    k_st = update_pages_at(k_st, kq[:, :, i:i + 1],
                                           page_ids[:, i], offsets[:, i])
                    ks_st = update_pages_at(ks_st, ks[:, :, i:i + 1],
                                            page_ids[:, i], offsets[:, i])
                    v_st = update_pages_at(v_st, vq[:, :, i:i + 1],
                                           page_ids[:, i], offsets[:, i])
                    vs_st = update_pages_at(vs_st, vs[:, :, i:i + 1],
                                            page_ids[:, i], offsets[:, i])
                if t == 1:
                    o = paged_decode_attention_q8(q, k_st, ks_st, v_st,
                                                  vs_st, table, cache_len,
                                                  window=window)
                else:
                    o = paged_verify_attention_q8(q, k_st, ks_st, v_st,
                                                  vs_st, table,
                                                  cache_len - t,
                                                  window=window)
                kv = (k_st, ks_st, v_st, vs_st)
            else:
                k_st, v_st = cache
                kt = k.transpose(0, 2, 1, 3)
                vt = v.transpose(0, 2, 1, 3)
                for i in range(t):
                    k_st = update_pages_at(k_st, kt[:, :, i:i + 1],
                                           page_ids[:, i], offsets[:, i])
                    v_st = update_pages_at(v_st, vt[:, :, i:i + 1],
                                           page_ids[:, i], offsets[:, i])
                if t == 1:
                    o = paged_decode_attention(q, k_st, v_st, table,
                                               cache_len, window=window)
                else:
                    o = paged_verify_attention(q, k_st, v_st, table,
                                               cache_len - t, window=window)
                kv = (k_st, v_st)
            o = o.reshape(b, t, cfg.n_heads * hd)
            with row_parallel():
                out = qlinear(o, p["wo"])
            return out, kv, o
        if cache is None:
            window = cfg.sliding_window or None
            o = chunked_attention(q, k, v, causal=True, window=window,
                                  kv_lens=kv_lens)
        elif cfg.kv_cache_bits == 8:
            k_cache, k_sc, v_cache, v_sc = cache
            pos = cache_len - t        # span start; t=1 is plain decode
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            k_cache = update_cache_at(k_cache, kq.transpose(0, 2, 1, 3), pos)
            v_cache = update_cache_at(v_cache, vq.transpose(0, 2, 1, 3), pos)
            k_sc = update_cache_at(k_sc, ks.transpose(0, 2, 1, 3), pos)
            v_sc = update_cache_at(v_sc, vs.transpose(0, 2, 1, 3), pos)
            window = cfg.sliding_window or None
            if t == 1:
                o = decode_attention_q8(q, k_cache, k_sc, v_cache, v_sc,
                                        cache_len, window=window)
            else:
                o = verify_attention_q8(q, k_cache, k_sc, v_cache, v_sc,
                                        cache_len - t, window=window)
            k, v = (k_cache, k_sc), (v_cache, v_sc)
        else:
            k_cache, v_cache = cache  # (B, KH, S, hd)
            pos = cache_len - t           # (B,) span start
            k_cache = update_cache_at(k_cache, k.transpose(0, 2, 1, 3), pos)
            v_cache = update_cache_at(v_cache, v.transpose(0, 2, 1, 3), pos)
            window = cfg.sliding_window or None
            if t == 1:
                o = decode_attention(q, k_cache, v_cache, cache_len,
                                     window=window)
            else:
                o = verify_attention(q, k_cache, v_cache, cache_len - t,
                                     window=window)
            k, v = k_cache, v_cache
        o = o.reshape(b, t, cfg.n_heads * hd)
        with row_parallel():
            out = qlinear(o, p["wo"])
        return out, (k, v), o

    def _block(self, p, x, positions, collect, *, cache=None, cache_len=None,
               kv_lens=None, paged=None):
        h = rms_norm(x, p["attn_norm"], self.cfg.norm_eps)
        stats = {}
        if collect:
            stats["attn_in"] = site_stat(h)
        attn_out, kv, o_pre = self._attn(p, h, positions, cache=cache,
                                         cache_len=cache_len, kv_lens=kv_lens,
                                         paged=paged)
        if collect:
            stats["attn_out"] = site_stat(o_pre)
        x = x + attn_out
        h = rms_norm(x, p["mlp_norm"], self.cfg.norm_eps)
        if collect:
            stats["mlp_in"] = site_stat(h)
        g = qlinear(h, p["w_gate"])
        u = qlinear(h, p["w_up"])
        hidden = jax.nn.silu(g) * u
        hidden = shard_hint(hidden, "batch", "seq", "ff")
        if collect:
            stats["mlp_down"] = site_stat(hidden)
        with row_parallel():
            x = x + qlinear(hidden, p["w_down"])
        x = shard_hint(x, "batch", "seq", "embed")
        return x, kv, stats

    # -- entry points --------------------------------------------------------
    def forward(self, params, batch, collect_stats: bool = False):
        """Full causal forward (training / evaluation).

        Returns (logits, aux) with aux = {"stats": ..., "moe_aux": scalar}
        — the uniform contract across all model families."""
        tokens = batch["tokens"]
        b, t = tokens.shape
        positions = self._positions(batch, b, t)
        x = embed_tokens(params["embed"], tokens).astype(self.dtype)
        x = shard_hint(x, "batch", "seq", "embed")

        def body(x, p):
            x, _, stats = self._block(p, x, positions, collect_stats)
            return x, (stats if collect_stats else None)

        if self.cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, stats = layer_scan(body, x, params["blocks"])
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        logits = logits_from_hidden(x, params["lm_head"], self.cfg.vocab_size)
        aux = {"stats": stats if collect_stats else {},
               "moe_aux": jnp.zeros((), jnp.float32)}
        return logits, aux

    def prefill(self, params, tokens, cache, prompt_len=None):
        """Run the prompt and write the KV cache in-place (functional).

        cache: dict(k=(L,B,KH,S,hd), v=..., len=()) with S >= T.
        ``prompt_len`` (B,) int32 marks each row's true prompt length for
        bucket-padded batched prefill: keys at positions >= prompt_len[b]
        are masked (length-aware causal mask), the returned logits are
        each row's *last valid* position, and cache["len"] is per-batch
        so decode continues from the right slot position.  ``None`` keeps
        the dense full-length behavior (every row is exactly T long).
        Returns (logits_last, cache)."""
        b, t = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(t), (b, t))
        positions = self._maybe_mrope(positions)
        if prompt_len is None:
            plen = jnp.full((b,), t, jnp.int32)
            kv_lens = None
        else:
            plen = jnp.broadcast_to(prompt_len, (b,)).astype(jnp.int32)
            kv_lens = plen
        x = embed_tokens(params["embed"], tokens).astype(self.dtype)
        x = shard_hint(x, "batch", "seq", "embed")

        if self.cfg.kv_cache_bits == 8:
            def body8(x, xs):
                p, kc, ksc, vc, vsc = xs
                x, (k, v), _ = self._block(p, x, positions, False,
                                           kv_lens=kv_lens)
                kq, ks = quantize_kv(k)
                vq, vs = quantize_kv(v)
                kc = jax.lax.dynamic_update_slice(
                    kc, kq.transpose(0, 2, 1, 3), (0, 0, 0, 0))
                ksc = jax.lax.dynamic_update_slice(
                    ksc, ks.transpose(0, 2, 1, 3), (0, 0, 0, 0))
                vc = jax.lax.dynamic_update_slice(
                    vc, vq.transpose(0, 2, 1, 3), (0, 0, 0, 0))
                vsc = jax.lax.dynamic_update_slice(
                    vsc, vs.transpose(0, 2, 1, 3), (0, 0, 0, 0))
                return x, (kc, ksc, vc, vsc)

            x, (kc, ksc, vc, vsc) = layer_scan(
                body8, x, (params["blocks"], cache["k"], cache["k_scale"],
                           cache["v"], cache["v_scale"]))
            x = x[:, -1:] if prompt_len is None else last_valid_hidden(x, plen)
            x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
            logits = logits_from_hidden(x, params["lm_head"],
                                        self.cfg.vocab_size)
            return logits, {"k": kc, "k_scale": ksc, "v": vc,
                            "v_scale": vsc, "len": plen}

        def body(x, xs):
            p, kc, vc = xs
            x, (k, v), _ = self._block(p, x, positions, False,
                                       kv_lens=kv_lens)
            kc = jax.lax.dynamic_update_slice(
                kc, k.transpose(0, 2, 1, 3), (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                vc, v.transpose(0, 2, 1, 3), (0, 0, 0, 0))
            return x, (kc, vc)

        x, (kc, vc) = layer_scan(body, x, (params["blocks"], cache["k"],
                                             cache["v"]))
        x = x[:, -1:] if prompt_len is None else last_valid_hidden(x, plen)
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        logits = logits_from_hidden(x, params["lm_head"], self.cfg.vocab_size)
        return logits, {"k": kc, "v": vc, "len": plen}

    def decode_step(self, params, cache, token, pos=None):
        """One decode step.  token: (B, T) int32 with T >= 1 — T = 1 is
        the plain decode hot loop; T > 1 is the speculative K-token
        verify forward (DESIGN.md §12): the T fresh K/V entries are
        written as one span starting at each slot's ``len`` and scored
        with shifted-causal verify attention, so ``logits[:, i]`` is the
        target's next-token distribution after consuming ``token[:, :i+1]``.
        Returns (logits (B, T, V), cache).  cache["len"] is per-batch
        (B,) so slots may hold different-length sequences (continuous
        batching); it advances by T."""
        b, t = token.shape
        base = cache["len"].astype(jnp.int32)           # (B,)
        new_len = base + t
        positions = base[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
        positions = self._maybe_mrope(positions)
        x = embed_tokens(params["embed"], token).astype(self.dtype)
        x = shard_hint(x, "batch", "seq", "embed")

        if self.cfg.kv_cache_bits == 8:
            def body8(x, xs):
                p, kc, ksc, vc, vsc = xs
                x, ((kc, ksc), (vc, vsc)), _ = self._block(
                    p, x, positions, False, cache=(kc, ksc, vc, vsc),
                    cache_len=new_len)
                return x, (kc, ksc, vc, vsc)

            x, (kc, ksc, vc, vsc) = layer_scan(
                body8, x, (params["blocks"], cache["k"], cache["k_scale"],
                           cache["v"], cache["v_scale"]))
            x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
            logits = logits_from_hidden(x, params["lm_head"],
                                        self.cfg.vocab_size)
            return logits, {"k": kc, "k_scale": ksc, "v": vc,
                            "v_scale": vsc, "len": new_len}

        def body(x, xs):
            p, kc, vc = xs
            x, (kc, vc), _ = self._block(p, x, positions, False,
                                         cache=(kc, vc), cache_len=new_len)
            return x, (kc, vc)

        x, (kc, vc) = layer_scan(body, x, (params["blocks"], cache["k"],
                                             cache["v"]))
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        logits = logits_from_hidden(x, params["lm_head"], self.cfg.vocab_size)
        return logits, {"k": kc, "v": vc, "len": new_len}

    def decode_step_paged(self, params, store, token, page_table, lens):
        """One decode step against the paged KV store.

        store: page-store tree from :meth:`init_paged_cache` (leaves
        (L, P, KH, ps, d) — no ``len``/table leaves, those are
        host-managed); token: (B, T) int32 (T = 1 plain decode, T > 1
        the speculative verify span, as in :meth:`decode_step`);
        page_table: (B, NP) int32 physical ids; lens: (B,) int32 valid
        entries *before* this step (fresh K/V position ``i`` is written
        at offset ``(lens[b]+i) % ps`` of page
        ``page_table[b, (lens[b]+i)//ps]`` — the span may cross a page
        boundary, so ids/offsets are resolved per position).
        Returns (logits, store).  The page table is shared across layers
        — one table per slot addresses every layer's pages.
        """
        t = token.shape[1]
        lens = jnp.broadcast_to(lens, (token.shape[0],)).astype(jnp.int32)
        new_len = lens + t
        pos2d = lens[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
        positions = self._maybe_mrope(pos2d)
        ps = store["k"].shape[3]
        page_ids = jnp.take_along_axis(page_table, pos2d // ps, axis=1)
        offsets = pos2d % ps
        paged = (page_table, page_ids, offsets)
        x = embed_tokens(params["embed"], token).astype(self.dtype)
        x = shard_hint(x, "batch", "seq", "embed")

        if self.cfg.kv_cache_bits == 8:
            def body8(x, xs):
                p, kc, ksc, vc, vsc = xs
                x, (kc, ksc, vc, vsc), _ = self._block(
                    p, x, positions, False, cache=(kc, ksc, vc, vsc),
                    cache_len=new_len, paged=paged)
                return x, (kc, ksc, vc, vsc)

            x, (kc, ksc, vc, vsc) = layer_scan(
                body8, x, (params["blocks"], store["k"], store["k_scale"],
                           store["v"], store["v_scale"]))
            x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
            logits = logits_from_hidden(x, params["lm_head"],
                                        self.cfg.vocab_size)
            return logits, {"k": kc, "k_scale": ksc, "v": vc, "v_scale": vsc}

        def body(x, xs):
            p, kc, vc = xs
            x, (kc, vc), _ = self._block(p, x, positions, False,
                                         cache=(kc, vc), cache_len=new_len,
                                         paged=paged)
            return x, (kc, vc)

        x, (kc, vc) = layer_scan(body, x, (params["blocks"], store["k"],
                                             store["v"]))
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        logits = logits_from_hidden(x, params["lm_head"], self.cfg.vocab_size)
        return logits, {"k": kc, "v": vc}

    # -- speculative verify (DESIGN.md §12) --------------------------------
    def verify_step(self, params, cache, tokens):
        """Score a K+1-token speculative burst in one forward pass.

        ``tokens`` (B, K+1) is the last committed token followed by the
        draft proposals; each slot's burst starts at its own
        ``cache["len"]`` (per-slot kv_lens — slots at different
        acceptance depths share the batch).  Writes the burst's K/V span
        into the cache and returns (logits (B, K+1, V), cache) with
        ``len`` advanced by K+1; the engine rolls rejected suffixes back
        via :func:`~repro.serve.cache_ops.truncate_slot`.  This is
        :meth:`decode_step`'s T > 1 form, named for the call site."""
        return self.decode_step(params, cache, tokens)

    def verify_step_paged(self, params, store, tokens, page_table, lens):
        """Paged form of :meth:`verify_step`: the burst span writes
        through per-position physical pages (already allocated and
        exclusively owned by the engine — copy-on-write happens
        host-side first) and rejected-suffix pages are trimmed
        refcount-safely by the engine."""
        return self.decode_step_paged(params, store, tokens, page_table,
                                      lens)

    def supports_spec(self) -> bool:
        """Speculative verification relies on this class's span-write
        decode path; subclasses that override it (hymba's ring buffer,
        recurrent xlstm, VLM's patched prefill) decline and serve
        non-speculatively."""
        return (type(self).prefill is DenseLM.prefill
                and type(self).decode_step is DenseLM.decode_step)

    # -- cache -------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        hd = cfg.head_dim_
        shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_len, hd)
        if cfg.kv_cache_bits == 8:
            sshape = shape[:-1] + (1,)
            return {"k": jnp.zeros(shape, jnp.int8),
                    "k_scale": jnp.zeros(sshape, jnp.float32),
                    "v": jnp.zeros(shape, jnp.int8),
                    "v_scale": jnp.zeros(sshape, jnp.float32),
                    "len": jnp.zeros((batch,), jnp.int32)}
        return {"k": jnp.zeros(shape, self.dtype),
                "v": jnp.zeros(shape, self.dtype),
                "len": jnp.zeros((batch,), jnp.int32)}

    def init_paged_cache(self, n_pages: int, page_size: int) -> dict:
        """Physical page store: ``n_pages`` fixed-size KV pages shared by
        all slots through per-slot page tables (serve/pages.py owns the
        allocator; the table and per-slot lengths stay host-side, so the
        tree carries no ``len`` leaf)."""
        cfg = self.cfg
        hd = cfg.head_dim_
        shape = (cfg.n_layers, n_pages, cfg.n_kv_heads, page_size, hd)
        if cfg.kv_cache_bits == 8:
            sshape = shape[:-1] + (1,)
            return {"k": jnp.zeros(shape, jnp.int8),
                    "k_scale": jnp.zeros(sshape, jnp.float32),
                    "v": jnp.zeros(shape, jnp.int8),
                    "v_scale": jnp.zeros(sshape, jnp.float32)}
        return {"k": jnp.zeros(shape, self.dtype),
                "v": jnp.zeros(shape, self.dtype)}

    def supports_paged(self) -> bool:
        """Paged serving relies on this class's exact prefill/decode
        cache layout; subclasses that override either (hymba's ring
        buffer, xlstm's recurrent state, MoE/VLM entry points) fall back
        to the dense cache automatically."""
        return (type(self).prefill is DenseLM.prefill
                and type(self).decode_step is DenseLM.decode_step)

    def cache_axes(self) -> dict:
        ax = (None, "batch", "kv_heads", "kv_seq", None)
        if self.cfg.kv_cache_bits == 8:
            return {"k": ax, "k_scale": ax, "v": ax, "v_scale": ax,
                    "len": None}
        return {"k": ax, "v": ax, "len": None}

    def paged_cache_axes(self) -> dict:
        """Logical axes for :meth:`init_paged_cache` leaves
        (L, P, KH, ps, hd): pages replicated (any slot's table may point
        anywhere), KV heads sharded on the model axis — the same head
        split the dense cache and the attention shard_map use."""
        ax = (None, None, "kv_heads", None, None)
        if self.cfg.kv_cache_bits == 8:
            return {"k": ax, "k_scale": ax, "v": ax, "v_scale": ax}
        return {"k": ax, "v": ax}

    # -- helpers -----------------------------------------------------------
    def _maybe_mrope(self, positions):
        if self.cfg.mrope_sections:
            return jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return positions

    def _positions(self, batch, b, t):
        if "positions" in batch:
            return batch["positions"]
        return self._maybe_mrope(jnp.broadcast_to(jnp.arange(t), (b, t)))
