"""Hymba: hybrid blocks with parallel attention + mamba heads.

Per block (arXiv:2411.13676, adapted): both branches read the same normed
input; outputs are averaged.  The attention branch uses sliding-window
(cfg.sliding_window) masking, making the arch sub-quadratic, and the
decode KV cache is a **ring buffer of window size** (rope is applied
before caching, so slot order is irrelevant to the attention sum).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.stats import site_stat
from repro.dist.sharding import shard_hint
from repro.kernels.ops import decode_attention
from .common import (layer_scan,
                     apply_rope, chunked_attention,
                     dense_init, embed_tokens, logits_from_hidden,
                     padded_vocab, qlinear, rms_norm, stack_layer_params,
                     update_cache_at)
from .dense import DenseLM
from . import ssm


class HymbaLM(DenseLM):
    @property
    def _d_inner(self) -> int:
        return self.cfg.ssm_expand * self.cfg.d_model

    def init(self, key) -> dict:
        cfg = self.cfg
        hd = cfg.head_dim_
        v_pad = padded_vocab(cfg.vocab_size)
        k_emb, k_blocks, k_head = jax.random.split(key, 3)

        def block_init(k):
            ks = jax.random.split(k, 8)
            return {
                "attn_norm": jnp.ones((cfg.d_model,), self.dtype),
                "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, self.dtype),
                "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, self.dtype),
                "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, self.dtype),
                "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, self.dtype),
                "mamba": ssm.mamba_init(ks[4], cfg.d_model, self._d_inner,
                                        cfg.ssm_state, cfg.dt_rank,
                                        cfg.ssm_conv, self.dtype),
                "mlp_norm": jnp.ones((cfg.d_model,), self.dtype),
                "w_gate": dense_init(ks[5], cfg.d_model, cfg.d_ff, self.dtype),
                "w_up": dense_init(ks[6], cfg.d_model, cfg.d_ff, self.dtype),
                "w_down": dense_init(ks[7], cfg.d_ff, cfg.d_model, self.dtype),
            }

        return {
            "embed": dense_init(k_emb, v_pad, cfg.d_model, self.dtype, scale=0.02),
            "blocks": stack_layer_params(k_blocks, cfg.n_layers, block_init),
            "final_norm": jnp.ones((cfg.d_model,), self.dtype),
            "lm_head": dense_init(k_head, cfg.d_model, v_pad, self.dtype),
        }

    def param_axes(self) -> dict:
        ax = super().param_axes()
        ax["blocks"]["mamba"] = ssm.mamba_axes()
        return ax

    def quant_site_map(self) -> dict:
        m = super().quant_site_map()
        m.update({
            ("blocks", "mamba", "in_proj"): "attn_in",   # same normed input
            ("blocks", "mamba", "x_proj"): "mamba_x",
            ("blocks", "mamba", "out_proj"): "mamba_out",
        })
        return m

    def _block(self, p, x, positions, collect, *, cache=None, cache_len=None):
        cfg = self.cfg
        h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
        stats = {}
        if collect:
            stats["attn_in"] = site_stat(h)

        collected = {}
        cb = (lambda name, val: collected.__setitem__(name, site_stat(val))) \
            if collect else None

        if cache is None:
            attn_out, kv, o_pre = self._attn(p, h, positions)
            mamba_out = ssm.mamba_scan(p["mamba"], h, collect_cb=cb)
            new_mamba = None
            if collect:
                # x_proj input: conv+silu output; recompute cheaply for stats
                u, _, _, _, _, _ = ssm._mamba_gates(p["mamba"], h)
                stats["mamba_x"] = site_stat(u)
        else:
            kv_cache, mamba_state = cache
            attn_out, kv, o_pre = self._attn_ring(p, h, positions, kv_cache,
                                                  cache_len)
            mamba_out, new_mamba = ssm.mamba_step(p["mamba"], h, mamba_state)
        if collect:
            stats["attn_out"] = site_stat(o_pre)
            stats.update(collected)
        x = x + 0.5 * (attn_out + mamba_out)

        h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        if collect:
            stats["mlp_in"] = site_stat(h)
        g = qlinear(h, p["w_gate"])
        u2 = qlinear(h, p["w_up"])
        hidden = jax.nn.silu(g) * u2
        hidden = shard_hint(hidden, "batch", "seq", "ff")
        if collect:
            stats["mlp_down"] = site_stat(hidden)
        x = x + qlinear(hidden, p["w_down"])
        x = shard_hint(x, "batch", "seq", "embed")
        return x, (kv, new_mamba), stats

    def _attn_ring(self, p, x, positions, kv_cache, cache_len):
        """Decode attention against the ring-buffer window cache."""
        cfg = self.cfg
        hd = cfg.head_dim_
        w = cfg.sliding_window
        b, t, _ = x.shape
        q = qlinear(x, p["wq"]).reshape(b, t, cfg.n_heads, hd)
        k = qlinear(x, p["wk"]).reshape(b, t, cfg.n_kv_heads, hd)
        v = qlinear(x, p["wv"]).reshape(b, t, cfg.n_kv_heads, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        k_cache, v_cache = kv_cache                       # (B, KH, W, hd)
        slot = (cache_len - 1) % w                        # (B,)
        k_cache = update_cache_at(k_cache, k.transpose(0, 2, 1, 3), slot)
        v_cache = update_cache_at(v_cache, v.transpose(0, 2, 1, 3), slot)
        valid = jnp.minimum(cache_len, w)                 # (B,)
        o = decode_attention(q, k_cache, v_cache, valid)
        o = o.reshape(b, t, cfg.n_heads * hd)
        return qlinear(o, p["wo"]), (k_cache, v_cache), o

    # -- entry points (cache structure differs from DenseLM) ---------------
    def forward(self, params, batch, collect_stats: bool = False):
        tokens = batch["tokens"]
        b, t = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(t), (b, t))
        x = embed_tokens(params["embed"], tokens).astype(self.dtype)
        x = shard_hint(x, "batch", "seq", "embed")

        def body(x, p):
            x, _, stats = self._block(p, x, positions, collect_stats)
            return x, (stats if collect_stats else None)

        if self.cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, stats = layer_scan(body, x, params["blocks"])
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        logits = logits_from_hidden(x, params["lm_head"], self.cfg.vocab_size)
        return logits, {"stats": stats if collect_stats else {},
                        "moe_aux": jnp.zeros((), jnp.float32)}

    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        hd = cfg.head_dim_
        w = min(cfg.sliding_window or max_len, max_len)
        kv_shape = (cfg.n_layers, batch, cfg.n_kv_heads, w, hd)
        return {
            "k": jnp.zeros(kv_shape, self.dtype),
            "v": jnp.zeros(kv_shape, self.dtype),
            "mamba": jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape),
                ssm.mamba_state_init(batch, self._d_inner, cfg.ssm_state,
                                     cfg.ssm_conv, self.dtype)),
            "len": jnp.zeros((batch,), jnp.int32),
        }

    def cache_axes(self) -> dict:
        ax = (None, "batch", "kv_heads", "kv_seq", None)
        return {"k": ax, "v": ax,
                "mamba": {"h": (None, "batch", "ff", None),
                          "conv": (None, "batch", None, "ff")},
                "len": None}

    def prefill(self, params, tokens, cache):
        """Prefill = full forward capturing final states.

        The attention branch keeps only the last `window` kv entries; the
        mamba branch's state after the prompt is reconstructed by running
        the scan and taking the final carry (recomputed in one pass)."""
        b, t = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(t), (b, t))
        x = embed_tokens(params["embed"], tokens).astype(self.dtype)
        w = cache["k"].shape[3]

        def body(x, xs):
            p, kc, vc, mst = xs
            h = rms_norm(x, p["attn_norm"], self.cfg.norm_eps)
            attn_out, (k, v), _ = self._attn(p, h, positions)
            # window-tail of rope'd k/v into the ring buffer (ring offset 0)
            k_tail = k.transpose(0, 2, 1, 3)[:, :, -w:]
            v_tail = v.transpose(0, 2, 1, 3)[:, :, -w:]
            kc = _ring_store(kc, k_tail, t, w)
            vc = _ring_store(vc, v_tail, t, w)
            mamba_out, mst = _mamba_scan_final(p["mamba"], h, mst)
            x = x + 0.5 * (attn_out + mamba_out)
            h2 = rms_norm(x, p["mlp_norm"], self.cfg.norm_eps)
            hidden = jax.nn.silu(qlinear(h2, p["w_gate"])) * qlinear(h2, p["w_up"])
            x = x + qlinear(hidden, p["w_down"])
            return x, (kc, vc, mst)

        x, (kc, vc, mst) = layer_scan(
            body, x, (params["blocks"], cache["k"], cache["v"], cache["mamba"]))
        x = rms_norm(x[:, -1:], params["final_norm"], self.cfg.norm_eps)
        logits = logits_from_hidden(x, params["lm_head"], self.cfg.vocab_size)
        return logits, {"k": kc, "v": vc, "mamba": mst,
                        "len": jnp.full((b,), t, jnp.int32)}

    def decode_step(self, params, cache, token, pos=None):
        b = token.shape[0]
        new_len = cache["len"] + 1                        # (B,)
        positions = (new_len - 1)[:, None].astype(jnp.int32)
        x = embed_tokens(params["embed"], token).astype(self.dtype)

        def body(x, xs):
            p, kc, vc, mst = xs
            x, ((kc, vc), mst), _ = self._block(
                p, x, positions, False, cache=((kc, vc), mst),
                cache_len=new_len)
            return x, (kc, vc, mst)

        x, (kc, vc, mst) = layer_scan(
            body, x, (params["blocks"], cache["k"], cache["v"], cache["mamba"]))
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        logits = logits_from_hidden(x, params["lm_head"], self.cfg.vocab_size)
        return logits, {"k": kc, "v": vc, "mamba": mst, "len": new_len}


def _ring_store(cache, tail, t: int, w: int):
    """Store the last min(t, w) entries at ring slots consistent with
    absolute positions (slot = pos % w)."""
    n = tail.shape[2]
    start = t - n
    slots = (start + jnp.arange(n)) % w
    return cache.at[:, :, slots].set(tail.astype(cache.dtype))


def _mamba_scan_final(p, x, state):
    """Like ssm.mamba_scan but seeded with ``state`` and returning the
    final state (for prefill)."""
    from .ssm import _mamba_gates
    u, z, dt, b_, c_, conv_state = _mamba_gates(p, x, conv_state=state["conv"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dt32, u32 = dt.astype(jnp.float32), u.astype(jnp.float32)

    def step(h, xs):
        dt_t, u_t, b_t, c_t = xs
        da_t = jnp.exp(dt_t[..., None] * a)
        dbu_t = dt_t[..., None] * b_t[:, None, :] * u_t[..., None]
        h = da_t * h + dbu_t
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    h_final, ys = jax.lax.scan(
        step, state["h"],
        (dt32.transpose(1, 0, 2), u32.transpose(1, 0, 2),
         b_.astype(jnp.float32).transpose(1, 0, 2),
         c_.astype(jnp.float32).transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2) + u32 * p["d_skip"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = qlinear(y, p["out_proj"])
    return out, {"h": h_final, "conv": conv_state}
