"""Mixture-of-Experts LM (llama4-maverick, qwen2-moe).

Dispatch is gather/scatter (sort-by-expert + capacity buffers), O(N·d),
never the O(N·E·C·d) one-hot einsum.  Two execution paths share the same
math:

* **local** — pure jnp, used on CPU (tests, calibration) and whenever no
  mesh is active.
* **sharded** — ``shard_map`` over the production mesh: tokens sharded on
  (pod, data); experts sharded on the 16-way ``model`` axis (padded to a
  multiple of it, pad experts masked in the router); expert weights
  additionally FSDP-sharded on (pod, data) along d_model and all-gathered
  per layer; token buffers exchanged with ``all_to_all`` over ``model``
  (expert parallelism).  Backward collectives come from JAX's transpose
  rules (all_gather -> psum_scatter, all_to_all -> all_to_all).

The router stays full-precision (small, sensitive); expert and shared-
expert linears are quantizable sites.  Per DESIGN.md §4, routed-expert
sites use the dispatch-weighted block-input statistic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig
from repro.core.stats import site_stat
from repro.dist.sharding import active_mesh, row_parallel, shard_hint
from .common import (layer_scan,
                     apply_rope, chunked_attention,
                     dense_init, embed_tokens, last_valid_hidden,
                     logits_from_hidden,
                     padded_vocab, qlinear, rms_norm, stack_layer_params)
from .dense import DenseLM


def padded_experts(n_experts: int, multiple: int = 16) -> int:
    return ((n_experts + multiple - 1) // multiple) * multiple


def _capacity(n_tokens: int, k: int, n_experts: int, factor: float) -> int:
    return max(1, int(n_tokens * k * factor / n_experts + 0.999))


def _route(x_flat, router_w, n_experts_real, k):
    """Top-k routing.  Returns (probs (N,k), ids (N,k), aux_loss)."""
    logits = (x_flat @ router_w.astype(x_flat.dtype)).astype(jnp.float32)
    e_pad = router_w.shape[-1]
    pad_mask = jnp.where(jnp.arange(e_pad) < n_experts_real, 0.0, -1e30)
    logits = logits + pad_mask
    topv, topi = jax.lax.top_k(logits, k)
    probs = jax.nn.softmax(topv, axis=-1)
    # switch-style load-balance aux loss
    full_probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(full_probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(topi, e_pad, dtype=jnp.float32), axis=1), axis=0) / k
    aux = n_experts_real * jnp.sum(me * ce)
    return probs, topi, aux


def _dispatch(x_flat, topi, probs, e_pad, capacity):
    """Sort-by-expert capacity dispatch.

    Returns (buffers (E, C, d), dest (N*k,), keep (N*k,), src (N*k,),
    gate (N*k,)).
    """
    n, d = x_flat.shape
    k = topi.shape[-1]
    flat_e = topi.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jax.ops.segment_sum(jnp.ones_like(sorted_e), sorted_e,
                                 num_segments=e_pad)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(n * k) - offsets[sorted_e]
    keep = (rank < capacity).astype(x_flat.dtype)
    dest = sorted_e * capacity + jnp.minimum(rank, capacity - 1)
    src = order // k
    gate = probs.reshape(-1)[order].astype(x_flat.dtype)
    buf = jnp.zeros((e_pad * capacity, d), x_flat.dtype)
    buf = buf.at[dest].add(x_flat[src] * keep[:, None])
    return buf.reshape(e_pad, capacity, d), dest, keep, src, gate


def _expert_matmul(x, w):
    """(E, C, d) @ per-expert weight; FP array or QuantizedTensor."""
    from repro.core.quantizer import QuantizedTensor
    if isinstance(w, QuantizedTensor):
        from repro.kernels.ops import quant_matmul_experts
        return quant_matmul_experts(x, w).astype(x.dtype)
    return jnp.einsum("ecd,edf->ecf", x, w.astype(x.dtype))


def _expert_ffn(buf, wg, wu, wd):
    """buf (E, C, d) through per-expert SwiGLU.  Returns (out, hidden)."""
    g = _expert_matmul(buf, wg)
    u = _expert_matmul(buf, wu)
    h = jax.nn.silu(g) * u
    out = _expert_matmul(h, wd)
    return out, h


def _combine(out_buf, dest, keep, src, gate, n, d):
    contrib = out_buf.reshape(-1, d)[dest] * (keep * gate)[:, None]
    y = jnp.zeros((n, d), out_buf.dtype).at[src].add(contrib)
    return y


def moe_ffn_local(x, router_w, wg, wu, wd, cfg: ModelConfig,
                  collect: bool = False):
    """Single-device MoE FFN.  x: (B, T, d)."""
    b, t, d = x.shape
    x_flat = x.reshape(-1, d)
    n = b * t
    e_pad = router_w.shape[-1]
    k = cfg.experts_per_token
    cap = _capacity(n, k, cfg.n_experts, cfg.moe_capacity_factor)
    probs, topi, aux = _route(x_flat, router_w, cfg.n_experts, k)
    buf, dest, keep, src, gate = _dispatch(x_flat, topi, probs, e_pad, cap)
    out_buf, hidden = _expert_ffn(buf, wg, wu, wd)
    y = _combine(out_buf, dest, keep, src, gate, n, d)
    stats = {}
    if collect:
        stats["mlp_down"] = site_stat(hidden)
    return y.reshape(b, t, d), aux, stats


def _gather_expert_weight(w, axis: int, fsdp_axes):
    """FSDP all-gather of one expert weight (FP or QuantizedTensor)."""
    from repro.core.quantizer import QuantizedTensor
    if not fsdp_axes:
        return w
    if isinstance(w, QuantizedTensor):
        codes = jax.lax.all_gather(w.codes, fsdp_axes, axis=axis, tiled=True)
        return QuantizedTensor(codes=codes, scale=w.scale, zero=w.zero,
                               spec=w.spec, n_in=w.n_in, packed=w.packed,
                               act_scale=w.act_scale)
    return jax.lax.all_gather(w, fsdp_axes, axis=axis, tiled=True)


def _moe_body_sharded(x, router_w, wg, wu, wd, *, cfg: ModelConfig,
                      model_axis: str, fsdp_axes, quantized: bool = False):
    """shard_map body.  Shapes are per-device:
    x (b_loc, T, d); router_w (d, E) replicated; wg/wu (E_loc, d_loc, f);
    wd (E_loc, f, d_loc)."""
    b, t, d = x.shape
    x_flat = x.reshape(-1, d)
    n = b * t
    e_pad = router_w.shape[-1]
    m = int(jax.lax.psum(1, model_axis))  # static axis size (constant-folded)
    e_loc = e_pad // m
    k = cfg.experts_per_token
    cap = _capacity(n, k, cfg.n_experts, cfg.moe_capacity_factor)

    probs, topi, aux = _route(x_flat, router_w, cfg.n_experts, k)
    buf, dest, keep, src, gate = _dispatch(x_flat, topi, probs, e_pad, cap)

    # exchange: (E, C, d) -> (E_loc, m*C, d).  View the buffer as
    # (dest_shard, e_loc, C, d); after all_to_all axis 0 indexes the
    # *source* shard, so entry (j, e, c) is source-shard j's buffer for
    # this shard's local expert e.
    buf = buf.reshape(m, e_loc, cap, d)
    buf = jax.lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=0,
                             tiled=True)
    buf = buf.transpose(1, 0, 2, 3).reshape(e_loc, m * cap, d)

    # FSDP all-gather of this layer's local expert shards over (pod, data)
    wg_f = _gather_expert_weight(wg, 1, fsdp_axes)
    wu_f = _gather_expert_weight(wu, 1, fsdp_axes)
    wd_f = _gather_expert_weight(wd, 2, fsdp_axes)

    out_buf, _ = _expert_ffn(buf, wg_f, wu_f, wd_f)

    # reverse exchange: rows go back to their source shard; after the
    # all_to_all axis 0 indexes the expert-owner shard, so global expert
    # id e = owner * e_loc + e_local matches the dispatch's block layout.
    out_buf = out_buf.reshape(e_loc, m, cap, d).transpose(1, 0, 2, 3)
    out_buf = jax.lax.all_to_all(out_buf, model_axis, split_axis=0,
                                 concat_axis=0, tiled=True)
    out_buf = out_buf.reshape(e_pad, cap, d)

    y = _combine(out_buf, dest, keep, src, gate, n, d)
    aux = jax.lax.pmean(aux, (model_axis,) + tuple(fsdp_axes))
    return y.reshape(b, t, d), aux


def _expert_specs(w, in_dim_axes, fsdp):
    """Per-leaf shard_map specs for one expert-weight arg.

    FP array: single P.  QuantizedTensor: a matching pytree of specs —
    codes shard like the weight; group scales/zeros and act_scale are
    small and replicated beyond the expert axis."""
    from repro.core.quantizer import QuantizedTensor
    if not isinstance(w, QuantizedTensor):
        return P("model", fsdp, None) if in_dim_axes == 1 \
            else P("model", None, fsdp)
    codes_spec = (P("model", fsdp, None) if in_dim_axes == 1
                  else P("model", None, fsdp))
    meta_spec = P("model", None, None)
    act_spec = None if w.act_scale is None else P("model", None)
    return QuantizedTensor(codes=codes_spec, scale=meta_spec, zero=meta_spec,
                           spec=w.spec, n_in=w.n_in, packed=w.packed,
                           act_scale=act_spec)


def moe_ffn(x, router_w, wg, wu, wd, cfg: ModelConfig, collect: bool = False):
    """Dispatching MoE FFN: shard_map on an active mesh, local otherwise.

    Tokens enter sharded over (batch x **sequence**): the sequence axis is
    split over ``model`` so each device routes only T/model_axis tokens.
    Without this, every model-shard in a data row routes — and, after the
    all-to-all, every expert shard *computes* — the same replicated
    tokens: a model_axis-fold waste of expert FLOPs and exchange bytes
    that dominated the baseline MoE train cells (EXPERIMENTS.md §Perf
    iteration 2).  Sequence positions are independent in an FFN, so
    correctness is unaffected; capacity is per (device, expert) sub-batch.
    """
    mesh = active_mesh()
    if mesh is None or collect or "model" not in mesh.shape:
        return moe_ffn_local(x, router_w, wg, wu, wd, cfg, collect)
    from repro.core.quantizer import QuantizedTensor
    quantized = isinstance(wg, QuantizedTensor)
    fsdp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    batch_spec = fsdp if fsdp else None
    seq_spec = "model" if x.shape[1] % mesh.shape["model"] == 0 else None
    body = functools.partial(_moe_body_sharded, cfg=cfg, model_axis="model",
                             fsdp_axes=fsdp, quantized=quantized)
    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_spec, seq_spec, None), P(None, None),
                  _expert_specs(wg, 1, fsdp), _expert_specs(wu, 1, fsdp),
                  _expert_specs(wd, 2, fsdp)),
        out_specs=(P(batch_spec, seq_spec, None), P()),
        check_rep=False,
    )(x, router_w, wg, wu, wd)
    return y, aux, {}


class MoELM(DenseLM):
    """Dense attention + MoE FFN blocks, with optional shared expert(s)."""

    def init(self, key) -> dict:
        cfg = self.cfg
        hd = cfg.head_dim_
        v_pad = padded_vocab(cfg.vocab_size)
        e_pad = padded_experts(cfg.n_experts)
        k_emb, k_blocks, k_head = jax.random.split(key, 3)

        def block_init(k):
            ks = jax.random.split(k, 12)
            p = {
                "attn_norm": jnp.ones((cfg.d_model,), self.dtype),
                "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, self.dtype),
                "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, self.dtype),
                "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, self.dtype),
                "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, self.dtype),
                "mlp_norm": jnp.ones((cfg.d_model,), self.dtype),
                "router": dense_init(ks[4], cfg.d_model, e_pad, self.dtype),
                "wg_exp": jax.random.normal(ks[5], (e_pad, cfg.d_model, cfg.d_ff)).astype(self.dtype) * (cfg.d_model ** -0.5),
                "wu_exp": jax.random.normal(ks[6], (e_pad, cfg.d_model, cfg.d_ff)).astype(self.dtype) * (cfg.d_model ** -0.5),
                "wd_exp": jax.random.normal(ks[7], (e_pad, cfg.d_ff, cfg.d_model)).astype(self.dtype) * (cfg.d_ff ** -0.5),
            }
            if cfg.n_shared_experts:
                f_sh = cfg.shared_expert_ff
                p["wg_sh"] = dense_init(ks[8], cfg.d_model, f_sh, self.dtype)
                p["wu_sh"] = dense_init(ks[9], cfg.d_model, f_sh, self.dtype)
                p["wd_sh"] = dense_init(ks[10], f_sh, cfg.d_model, self.dtype)
            return p

        return {
            "embed": dense_init(k_emb, v_pad, cfg.d_model, self.dtype,
                                scale=0.02),
            "blocks": stack_layer_params(k_blocks, cfg.n_layers, block_init),
            "final_norm": jnp.ones((cfg.d_model,), self.dtype),
            "lm_head": dense_init(k_head, cfg.d_model, v_pad, self.dtype),
        }

    def param_axes(self) -> dict:
        ax = {
            "embed": ("vocab", "fsdp"),
            "blocks": {
                "attn_norm": (None, None),
                "wq": (None, "fsdp", "heads"),
                "wk": (None, "fsdp", None),
                "wv": (None, "fsdp", None),
                "wo": (None, "heads", "fsdp"),
                "mlp_norm": (None, None),
                "router": (None, None, None),
                "wg_exp": (None, "experts", "fsdp", None),
                "wu_exp": (None, "experts", "fsdp", None),
                "wd_exp": (None, "experts", None, "fsdp"),
            },
            "final_norm": (None,),
            "lm_head": ("fsdp", "vocab"),
        }
        if self.cfg.n_shared_experts:
            ax["blocks"].update({
                "wg_sh": (None, "fsdp", "ff"),
                "wu_sh": (None, "fsdp", "ff"),
                "wd_sh": (None, "ff", "fsdp"),
            })
        return ax

    def quant_site_map(self) -> dict:
        m = {
            ("blocks", "wq"): "attn_in",
            ("blocks", "wk"): "attn_in",
            ("blocks", "wv"): "attn_in",
            ("blocks", "wo"): "attn_out",
            ("blocks", "wg_exp"): "mlp_in",
            ("blocks", "wu_exp"): "mlp_in",
            ("blocks", "wd_exp"): "mlp_down",
        }
        if self.cfg.n_shared_experts:
            m.update({
                ("blocks", "wg_sh"): "mlp_in",
                ("blocks", "wu_sh"): "mlp_in",
                ("blocks", "wd_sh"): "shared_down",
            })
        return m

    # override the FFN half of the block
    def _block(self, p, x, positions, collect, *, cache=None, cache_len=None,
               kv_lens=None):
        h = rms_norm(x, p["attn_norm"], self.cfg.norm_eps)
        stats = {}
        if collect:
            stats["attn_in"] = site_stat(h)
        attn_out, kv, o_pre = self._attn(p, h, positions, cache=cache,
                                         cache_len=cache_len, kv_lens=kv_lens)
        if collect:
            stats["attn_out"] = site_stat(o_pre)
        x = x + attn_out
        h = rms_norm(x, p["mlp_norm"], self.cfg.norm_eps)
        if collect:
            stats["mlp_in"] = site_stat(h)
        if cache is not None and h.shape[1] > 1:
            # speculative verify span: route each position separately so
            # the capacity cutoff (a function of the routed token count)
            # matches sequential T=1 decode exactly — pooled routing
            # would let burst tokens compete for expert capacity and
            # drop different tokens than the non-speculative loop
            outs, auxes = [], []
            for i in range(h.shape[1]):
                y_i, aux_i, _ = moe_ffn(h[:, i:i + 1], p["router"],
                                        p["wg_exp"], p["wu_exp"],
                                        p["wd_exp"], self.cfg, False)
                outs.append(y_i)
                auxes.append(aux_i)
            y = jnp.concatenate(outs, axis=1)
            aux = jnp.mean(jnp.stack(auxes))
            moe_stats = {}
        else:
            y, aux, moe_stats = moe_ffn(h, p["router"], p["wg_exp"],
                                        p["wu_exp"], p["wd_exp"], self.cfg,
                                        collect)
        stats.update(moe_stats)
        if self.cfg.n_shared_experts:
            g = qlinear(h, p["wg_sh"])
            u = qlinear(h, p["wu_sh"])
            hidden = jax.nn.silu(g) * u
            hidden = shard_hint(hidden, "batch", "seq", "ff")
            if collect:
                stats["shared_down"] = site_stat(hidden)
            with row_parallel():
                y = y + qlinear(hidden, p["wd_sh"])
        x = x + y
        x = shard_hint(x, "batch", "seq", "embed")
        return x, kv, stats, aux

    # scan wrappers must thread the aux loss through
    def forward(self, params, batch, collect_stats: bool = False):
        tokens = batch["tokens"]
        b, t = tokens.shape
        positions = self._positions(batch, b, t)
        x = embed_tokens(params["embed"], tokens).astype(self.dtype)
        x = shard_hint(x, "batch", "seq", "embed")

        def body(x, p):
            x, _, stats, aux = self._block(p, x, positions, collect_stats)
            return x, (stats if collect_stats else None, aux)

        if self.cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, (stats, aux) = layer_scan(body, x, params["blocks"])
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        logits = logits_from_hidden(x, params["lm_head"], self.cfg.vocab_size)
        out = {"stats": stats if collect_stats else {},
               "moe_aux": jnp.mean(aux)}
        return logits, out

    def prefill(self, params, tokens, cache, prompt_len=None):
        b, t = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(t), (b, t))
        if prompt_len is None:
            plen = jnp.full((b,), t, jnp.int32)
            kv_lens = None
        else:
            plen = jnp.broadcast_to(prompt_len, (b,)).astype(jnp.int32)
            kv_lens = plen
        x = embed_tokens(params["embed"], tokens).astype(self.dtype)
        x = shard_hint(x, "batch", "seq", "embed")

        def body(x, xs):
            p, kc, vc = xs
            x, (k, v), _, _ = self._block(p, x, positions, False,
                                          kv_lens=kv_lens)
            kc = jax.lax.dynamic_update_slice(
                kc, k.transpose(0, 2, 1, 3), (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                vc, v.transpose(0, 2, 1, 3), (0, 0, 0, 0))
            return x, (kc, vc)

        x, (kc, vc) = layer_scan(body, x, (params["blocks"], cache["k"],
                                             cache["v"]))
        x = x[:, -1:] if prompt_len is None else last_valid_hidden(x, plen)
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        logits = logits_from_hidden(x, params["lm_head"], self.cfg.vocab_size)
        return logits, {"k": kc, "v": vc, "len": plen}

    def decode_step(self, params, cache, token, pos=None):
        """One decode step; token (B, T) with T > 1 the speculative
        verify span (same contract as :meth:`DenseLM.decode_step` — the
        span write and verify attention live in the inherited
        ``_attn``)."""
        b, t = token.shape
        base = cache["len"].astype(jnp.int32)
        new_len = base + t
        positions = base[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
        x = embed_tokens(params["embed"], token).astype(self.dtype)
        x = shard_hint(x, "batch", "seq", "embed")

        def body(x, xs):
            p, kc, vc = xs
            x, (kc, vc), _, _ = self._block(p, x, positions, False,
                                            cache=(kc, vc), cache_len=new_len)
            return x, (kc, vc)

        x, (kc, vc) = layer_scan(body, x, (params["blocks"], cache["k"],
                                             cache["v"]))
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        logits = logits_from_hidden(x, params["lm_head"], self.cfg.vocab_size)
        return logits, {"k": kc, "v": vc, "len": new_len}

    def supports_spec(self) -> bool:
        """MoE overrides the dense decode pair but keeps the same cache
        layout and span-write attention, so speculative verification
        works; further subclasses that override it again decline."""
        return (type(self).prefill is MoELM.prefill
                and type(self).decode_step is MoELM.decode_step)
