"""Model registry: build any assigned architecture from its config."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from .dense import DenseLM
from .hymba import HymbaLM
from .moe import MoELM
from .vlm import VLM
from .whisper import WhisperLM
from .xlstm import XLSTMLM

FAMILIES = {
    "dense": DenseLM,
    "moe": MoELM,
    "hybrid": HymbaLM,
    "ssm": XLSTMLM,
    "audio": WhisperLM,
    "vlm": VLM,
}


def build_model(cfg: ModelConfig):
    try:
        cls = FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown family {cfg.family!r} for {cfg.name}")
    return cls(cfg)
