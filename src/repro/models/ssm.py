"""Sequence-mixing primitives for the sub-quadratic families.

* :func:`mamba_*`   — selective SSM branch of hymba (scan over time for
  train/prefill, O(1)-state single step for decode).
* :func:`mlstm_*`   — xLSTM matrix-LSTM in *chunked* parallel form: exact
  recurrence, O(T·W) compute, O(dk·dv) carried state.  Gate products are
  accumulated in log-space; the normalizer is lower-bounded at 1 per the
  xLSTM paper, which keeps the unstabilized-chunk simplification
  numerically safe (documented in DESIGN.md).
* :func:`slstm_*`   — xLSTM scalar-LSTM with exponential gating,
  stabilizer state m, and head-wise recurrent memory mixing (strictly
  sequential scan).

All weights quantizable by FAQ are plain (n_in, n_out) matrices routed
through ``qlinear``; recurrent/gate parameters stay FP (tiny).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .common import dense_init, qlinear


# ---------------------------------------------------------------------------
# Mamba (selective SSM) — used by hymba's parallel branch
# ---------------------------------------------------------------------------

def mamba_init(key, d_model: int, d_inner: int, d_state: int, dt_rank: int,
               d_conv: int, dtype) -> dict:
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner)) * 0.1).astype(dtype),
        "x_proj": dense_init(ks[2], d_inner, dt_rank + 2 * d_state, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_inner, dtype),
        "dt_bias": jnp.zeros((d_inner,), dtype),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_inner, d_state))).astype(dtype),
        "d_skip": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(ks[4], d_inner, d_model, dtype),
    }


def mamba_axes() -> dict:
    return {"in_proj": (None, "fsdp", "ff"), "conv_w": (None, None, "ff"),
            "x_proj": (None, "ff", None), "dt_proj": (None, None, "ff"),
            "dt_bias": (None, None), "a_log": (None, "ff", None),
            "d_skip": (None, None), "out_proj": (None, "ff", "fsdp")}


def _mamba_gates(p, x, conv_state=None):
    """Shared front: projections + causal depthwise conv.

    x: (B, T, d_model).  Returns (u, z, dt, B_, C_, new_conv_state) where
    u is the conv+silu'd SSM input (B, T, d_in)."""
    d_inner = p["dt_bias"].shape[0]
    d_state = p["a_log"].shape[1]
    xz = qlinear(x, p["in_proj"])
    u, z = jnp.split(xz, 2, axis=-1)
    k = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
        new_conv_state = pad[:, -(k - 1):, :] if k > 1 else None
    else:
        pad = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)
        new_conv_state = pad[:, -(k - 1):, :]
    u = sum(pad[:, i:i + u.shape[1], :] * p["conv_w"][i].astype(u.dtype)
            for i in range(k))
    u = jax.nn.silu(u)
    proj = qlinear(u, p["x_proj"])
    dt_rank = proj.shape[-1] - 2 * d_state
    dt_low, b_, c_ = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(qlinear(dt_low, p["dt_proj"])
                         + p["dt_bias"].astype(x.dtype))
    return u, z, dt, b_, c_, new_conv_state


def mamba_scan(p, x, collect_cb=None):
    """Full-sequence selective scan.  x: (B, T, d_model) -> (B, T, d_model).

    The discretized (dA, dB·u) terms are computed *inside* the time step so
    the O(B·T·d_in·S) tensor is never materialized (memory stays at one
    timestep's (B, d_in, S))."""
    u, z, dt, b_, c_, _ = _mamba_gates(p, x)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))            # (d_in, S)
    dt32, u32 = dt.astype(jnp.float32), u.astype(jnp.float32)

    def step(h, xs):
        dt_t, u_t, b_t, c_t = xs                             # (B,d_in),(B,d_in),(B,S),(B,S)
        da_t = jnp.exp(dt_t[..., None] * a)                  # (B,d_in,S)
        dbu_t = dt_t[..., None] * b_t[:, None, :] * u_t[..., None]
        h = da_t * h + dbu_t                                 # (B,d_in,S)
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    b, t, d_in = u.shape
    h0 = jnp.zeros((b, d_in, a.shape[1]), jnp.float32)
    _, ys = jax.lax.scan(step, h0,
                         (dt32.transpose(1, 0, 2), u32.transpose(1, 0, 2),
                          b_.astype(jnp.float32).transpose(1, 0, 2),
                          c_.astype(jnp.float32).transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2) + u32 * p["d_skip"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    if collect_cb is not None:
        collect_cb("mamba_out", y)
    return qlinear(y, p["out_proj"])


def mamba_step(p, x, state):
    """Single decode step.  x: (B, 1, d_model); state dict(h, conv)."""
    u, z, dt, b_, c_, conv_state = _mamba_gates(p, x, conv_state=state["conv"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dt32, u32 = dt[:, 0].astype(jnp.float32), u[:, 0].astype(jnp.float32)
    da = jnp.exp(dt32[..., None] * a)
    dbu = dt32[..., None] * b_[:, 0].astype(jnp.float32)[:, None, :] * u32[..., None]
    h = da * state["h"] + dbu
    y = jnp.einsum("bds,bs->bd", h, c_[:, 0].astype(jnp.float32))
    y = y + u32 * p["d_skip"].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    out = qlinear(y[:, None, :], p["out_proj"])
    return out, {"h": h, "conv": conv_state}


def mamba_state_init(batch: int, d_inner: int, d_state: int, d_conv: int,
                     dtype) -> dict:
    return {"h": jnp.zeros((batch, d_inner, d_state), jnp.float32),
            "conv": jnp.zeros((batch, d_conv - 1, d_inner), dtype)}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory) — chunked parallel form
# ---------------------------------------------------------------------------

def mlstm_init(key, d_model: int, d_inner: int, n_heads: int, dtype) -> dict:
    ks = jax.random.split(key, 7)
    return {
        "up_proj": dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "wq": dense_init(ks[1], d_inner, d_inner, dtype),
        "wk": dense_init(ks[2], d_inner, d_inner, dtype),
        "wv": dense_init(ks[3], d_inner, d_inner, dtype),
        "w_gates": dense_init(ks[4], d_inner, 2 * n_heads, dtype, scale=0.01),
        "gate_bias": jnp.concatenate([jnp.full((n_heads,), 3.0),
                                      jnp.zeros((n_heads,))]).astype(dtype),
        "out_norm": jnp.ones((d_inner,), dtype),
        "down_proj": dense_init(ks[5], d_inner, d_model, dtype),
    }


def mlstm_axes() -> dict:
    return {"up_proj": (None, "fsdp", "ff"), "wq": (None, "fsdp", "ff"),
            "wk": (None, "fsdp", "ff"), "wv": (None, "fsdp", "ff"),
            "w_gates": (None, None, None), "gate_bias": (None, None),
            "out_norm": (None, None), "down_proj": (None, "ff", "fsdp")}


def _mlstm_qkvg(p, x, n_heads: int):
    d_inner = p["wq"].shape[0]
    xz = qlinear(x, p["up_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    q = qlinear(xi, p["wq"])
    k = qlinear(xi, p["wk"])
    v = qlinear(xi, p["wv"])
    gates = (xi @ p["w_gates"].astype(xi.dtype)
             + p["gate_bias"].astype(xi.dtype)).astype(jnp.float32)
    fgate, igate = jnp.split(gates, 2, axis=-1)            # (B,T,H)
    logf = jax.nn.log_sigmoid(fgate)
    logi = jnp.clip(igate, -10.0, 10.0)
    b, t, _ = x.shape
    hd = d_inner // n_heads
    shp = (b, t, n_heads, hd)
    return (q.reshape(shp), k.reshape(shp), v.reshape(shp),
            logf, logi, z, xi)


def mlstm_chunked(p, x, n_heads: int, chunk: int = 64, collect_cb=None,
                  state: Optional[dict] = None, return_state: bool = False):
    from .common import cost_mode
    if cost_mode():
        chunk = x.shape[1]
    """Exact chunked mLSTM.  x: (B, T, d_model) -> (B, T, d_model).

    Optionally seeds from / returns the (C, n) recurrent state so prefill
    can reuse the chunk-parallel path."""
    q, k, v, logf, logi, z, xi = _mlstm_qkvg(p, x, n_heads)
    b, t, h, hd = q.shape
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, padw) for a in (q, k, v))
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)), constant_values=-1e9)
    tp = t + pad
    nc = tp // chunk
    # (B, nc, W, H, ...) -> scan over nc
    qc = q.reshape(b, nc, chunk, h, hd).astype(jnp.float32) * hd ** -0.5
    kc = k.reshape(b, nc, chunk, h, hd).astype(jnp.float32)
    vc = v.reshape(b, nc, chunk, h, hd).astype(jnp.float32)
    lfc = logf.reshape(b, nc, chunk, h)
    lic = logi.reshape(b, nc, chunk, h)

    def step(carry, xs):
        C, n = carry                                    # (B,H,hd,hd), (B,H,hd)
        qw, kw, vw, lf, li = xs                         # (B,W,H,*)
        clf = jnp.cumsum(lf, axis=1)                    # (B,W,H) decay to t
        # intra-chunk: D[t,s] = exp(clf_t - clf_s + li_s), s <= t
        dmat = clf[:, :, None, :] - clf[:, None, :, :] + li[:, None, :, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
        dexp = jnp.exp(jnp.clip(dmat, -60.0, 30.0))     # (B,T,S,H)
        scores = jnp.einsum("bthd,bshd->btsh", qw, kw) * dexp
        intra = jnp.einsum("btsh,bshd->bthd", scores, vw)
        # normalizer n_t = sum_s D_ts k_s (+ carried, decayed)
        intra_n = jnp.einsum("btsh,bshd->bthd", dexp, kw)
        # inter-chunk
        decay_t = jnp.exp(clf)                          # (B,W,H)
        inter = jnp.einsum("bthd,bhde->bthe", qw, C) * decay_t[..., None]
        inter_n = jnp.einsum("bthd,bhd->bth", qw, n) * decay_t
        num = intra + inter
        den = jnp.abs(jnp.einsum("bthd,bthd->bth", qw, intra_n)
                      + inter_n)
        hout = num / jnp.maximum(den, 1.0)[..., None]
        # carry update
        tot = clf[:, -1]                                # (B,H)
        rdec = jnp.exp(jnp.clip(tot[:, None] - clf + li, -60.0, 30.0))
        C = C * jnp.exp(tot)[..., None, None] + jnp.einsum(
            "bshd,bshe,bsh->bhde", kw, vw, rdec)
        n = n * jnp.exp(tot)[..., None] + jnp.einsum(
            "bshd,bsh->bhd", kw, rdec)
        return (C, n), hout

    if state is None:
        c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
    else:
        c0, n0 = state["C"], state["n"]
    (c_f, n_f), hs = jax.lax.scan(
        step, (c0, n0),
        (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
         vc.transpose(1, 0, 2, 3, 4), lfc.transpose(1, 0, 2, 3),
         lic.transpose(1, 0, 2, 3)))
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(b, tp, h * hd)[:, :t]
    y = _mlstm_out(p, hs, z, x.dtype, collect_cb)
    if return_state:
        return y, {"C": c_f, "n": n_f}
    return y


def _mlstm_out(p, hs, z, dtype, collect_cb=None):
    from .common import rms_norm
    y = rms_norm(hs.astype(jnp.float32), p["out_norm"])
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(dtype)
    if collect_cb is not None:
        collect_cb("mlstm_out", y)
    return qlinear(y, p["down_proj"])


def mlstm_step(p, x, state, n_heads: int):
    """Single decode step with carried (C, n) state.  x: (B, 1, d_model)."""
    q, k, v, logf, logi, z, _ = _mlstm_qkvg(p, x, n_heads)
    b, _, h, hd = q.shape
    qw = q[:, 0].astype(jnp.float32) * hd ** -0.5
    kw = k[:, 0].astype(jnp.float32)
    vw = v[:, 0].astype(jnp.float32)
    f = jnp.exp(logf[:, 0])[..., None, None]            # (B,H,1,1)
    i = jnp.exp(jnp.clip(logi[:, 0], -60.0, 30.0))[..., None, None]
    C = state["C"] * f + i * jnp.einsum("bhd,bhe->bhde", kw, vw)
    n = state["n"] * f[..., 0] + i[..., 0] * kw
    num = jnp.einsum("bhd,bhde->bhe", qw, C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qw, n))
    hout = (num / jnp.maximum(den, 1.0)[..., None]).reshape(b, 1, h * hd)
    y = _mlstm_out(p, hout, z, x.dtype)
    return y, {"C": C, "n": n}


def mlstm_state_init(batch: int, n_heads: int, head_dim: int) -> dict:
    return {"C": jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
            "n": jnp.zeros((batch, n_heads, head_dim), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM — exponential-gated scalar LSTM with head-wise memory mixing
# ---------------------------------------------------------------------------

def slstm_init(key, d_model: int, n_heads: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    hd = d_model // n_heads
    return {
        "w_in": dense_init(ks[0], d_model, 4 * d_model, dtype),
        "r": (jax.random.normal(ks[1], (4, n_heads, hd, hd)) * hd ** -0.5
              ).astype(dtype),
        "bias": jnp.zeros((4 * d_model,), dtype),
        "out_norm": jnp.ones((d_model,), dtype),
        "out_proj": dense_init(ks[2], d_model, d_model, dtype),
    }


def slstm_axes() -> dict:
    return {"w_in": (None, "fsdp", None), "r": (None, None, None, None, None),
            "bias": (None, None), "out_norm": (None, None),
            "out_proj": (None, "fsdp", None)}


def _slstm_cell(p, gx, state, n_heads):
    """gx: (B, 4, H, hd) pre-activation input contribution."""
    h, c, n, m = state
    r = p["r"].astype(jnp.float32)
    gr = jnp.einsum("bhd,ghde->bghe", h, r)              # (B,4,H,hd)
    zt, it, ft, ot = [ (gx[:, g] + gr[:, g]) for g in range(4) ]
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_act = jnp.exp(it - m_new)
    f_act = jnp.exp(logf + m - m_new)
    c_new = f_act * c + i_act * jnp.tanh(zt)
    n_new = f_act * n + i_act
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1.0)
    return (h_new, c_new, n_new, m_new)


def slstm_scan(p, x, n_heads: int, collect_cb=None):
    """x: (B, T, d_model) -> (B, T, d_model), sequential over T."""
    b, t, d = x.shape
    hd = d // n_heads
    gx = (qlinear(x, p["w_in"]) + p["bias"].astype(x.dtype)).astype(jnp.float32)
    gx = gx.reshape(b, t, 4, n_heads, hd)

    def step(state, gx_t):
        new = _slstm_cell(p, gx_t, state, n_heads)
        return new, new[0]

    z0 = jnp.zeros((b, n_heads, hd), jnp.float32)
    state0 = (z0, z0, z0, jnp.full_like(z0, -1e9))
    _, hs = jax.lax.scan(step, state0, gx.transpose(1, 0, 2, 3, 4))
    hs = hs.transpose(1, 0, 2, 3).reshape(b, t, d)
    from .common import rms_norm
    y = rms_norm(hs, p["out_norm"]).astype(x.dtype)
    if collect_cb is not None:
        collect_cb("slstm_out", y)
    return qlinear(y, p["out_proj"])


def slstm_step(p, x, state, n_heads: int):
    b, _, d = x.shape
    hd = d // n_heads
    gx = (qlinear(x, p["w_in"]) + p["bias"].astype(x.dtype)).astype(jnp.float32)
    gx = gx.reshape(b, 4, n_heads, hd)
    new = _slstm_cell(p, gx, tuple(state), n_heads)
    hs = new[0].reshape(b, 1, d)
    from .common import rms_norm
    y = rms_norm(hs, p["out_norm"]).astype(x.dtype)
    return qlinear(y, p["out_proj"]), list(new)


def slstm_state_init(batch: int, n_heads: int, head_dim: int) -> list:
    z = jnp.zeros((batch, n_heads, head_dim), jnp.float32)
    return [z, z, z, jnp.full_like(z, -1e9)]
