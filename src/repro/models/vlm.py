"""Qwen2-VL backbone: DenseLM + M-RoPE + stub vision frontend.

Per the assignment the vision tower is a STUB: batches carry precomputed
patch embeddings ``patches (B, P, d_model)`` which are prepended to the
token embeddings.  M-RoPE is implemented in common.apply_rope (sections
over head_dim); with the stub's text-style position ids it reduces to
standard RoPE, which is exactly Qwen2-VL's behaviour for text tokens.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_hint
from .common import (embed_tokens, layer_scan,
                     logits_from_hidden, rms_norm)
from .dense import DenseLM


class VLM(DenseLM):
    def forward(self, params, batch, collect_stats: bool = False):
        tokens = batch["tokens"]
        b, t = tokens.shape
        x = embed_tokens(params["embed"], tokens).astype(self.dtype)
        n_patch = 0
        if "patches" in batch:
            patches = batch["patches"].astype(self.dtype)
            n_patch = patches.shape[1]
            x = jnp.concatenate([patches, x], axis=1)
        total = n_patch + t
        positions = self._maybe_mrope(
            jnp.broadcast_to(jnp.arange(total), (b, total)))
        x = shard_hint(x, "batch", "seq", "embed")

        def body(x, p):
            x, _, stats = self._block(p, x, positions, collect_stats)
            return x, (stats if collect_stats else None)

        if self.cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, stats = layer_scan(body, x, params["blocks"])
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        # logits over token positions only (patch positions carry no labels)
        x = x[:, n_patch:]
        logits = logits_from_hidden(x, params["lm_head"], self.cfg.vocab_size)
        return logits, {"stats": stats if collect_stats else {},
                        "moe_aux": jnp.zeros((), jnp.float32)}

    def prefill(self, params, tokens, cache, patches=None):
        b, t = tokens.shape
        x = embed_tokens(params["embed"], tokens).astype(self.dtype)
        n_patch = 0
        if patches is not None:
            n_patch = patches.shape[1]
            x = jnp.concatenate([patches.astype(self.dtype), x], axis=1)
        total = n_patch + t
        positions = self._maybe_mrope(
            jnp.broadcast_to(jnp.arange(total), (b, total)))
        x = shard_hint(x, "batch", "seq", "embed")

        def body(x, xs):
            p, kc, vc = xs
            x, (k, v), _ = self._block(p, x, positions, False)
            kc = jax.lax.dynamic_update_slice(
                kc, k.transpose(0, 2, 1, 3), (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                vc, v.transpose(0, 2, 1, 3), (0, 0, 0, 0))
            return x, (kc, vc)

        x, (kc, vc) = layer_scan(body, x, (params["blocks"], cache["k"],
                                             cache["v"]))
        x = rms_norm(x[:, -1:], params["final_norm"], self.cfg.norm_eps)
        logits = logits_from_hidden(x, params["lm_head"], self.cfg.vocab_size)
        return logits, {"k": kc, "v": vc,
                        "len": jnp.full((b,), total, jnp.int32)}
