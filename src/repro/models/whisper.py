"""Whisper-small: encoder-decoder transformer over stub frame embeddings.

Per the assignment the conv/mel frontend is a STUB: ``input_specs`` (and
all entry points here) take precomputed frame embeddings
``frames (B, T_enc, d_model)``.  Faithful to Whisper where it matters for
system shape: LayerNorm (with bias), GELU MLP, learned positional
embeddings (no RoPE), bidirectional encoder self-attention, decoder with
causal self-attention + cross-attention.  FAQ previews run per-stack
(encoder window over encoder blocks, decoder over decoder blocks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.stats import site_stat
from repro.dist.sharding import shard_hint
from repro.kernels.ops import decode_attention
from .common import (layer_scan,
                     chunked_attention, dense_init,
                     embed_tokens, layer_norm, logits_from_hidden,
                     padded_vocab, qlinear, stack_layer_params,
                     update_cache_at)

MAX_DEC_POS = 36864  # learned positional table (covers 32k prefill + decode)


class WhisperLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)

    # -- params ------------------------------------------------------------
    def _attn_params(self, k, with_cross=False):
        cfg = self.cfg
        hd = cfg.head_dim_
        ks = jax.random.split(k, 8)
        p = {
            "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, self.dtype),
            "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, self.dtype),
            "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, self.dtype),
            "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, self.dtype),
        }
        return p

    def _block_init(self, k, cross: bool):
        cfg = self.cfg
        ks = jax.random.split(k, 4)
        d = cfg.d_model
        p = {
            "ln1_w": jnp.ones((d,), self.dtype), "ln1_b": jnp.zeros((d,), self.dtype),
            "attn": self._attn_params(ks[0]),
            "ln2_w": jnp.ones((d,), self.dtype), "ln2_b": jnp.zeros((d,), self.dtype),
            "w1": dense_init(ks[1], d, cfg.d_ff, self.dtype),
            "b1": jnp.zeros((cfg.d_ff,), self.dtype),
            "w2": dense_init(ks[2], cfg.d_ff, d, self.dtype),
            "b2": jnp.zeros((d,), self.dtype),
        }
        if cross:
            p["lnx_w"] = jnp.ones((d,), self.dtype)
            p["lnx_b"] = jnp.zeros((d,), self.dtype)
            p["cross"] = self._attn_params(ks[3])
        return p

    def init(self, key) -> dict:
        cfg = self.cfg
        v_pad = padded_vocab(cfg.vocab_size)
        ks = jax.random.split(key, 6)
        return {
            "enc_pos": (jax.random.normal(ks[0], (cfg.encoder_len, cfg.d_model))
                        * 0.02).astype(self.dtype),
            "enc_blocks": stack_layer_params(
                ks[1], cfg.n_encoder_layers, lambda k: self._block_init(k, False)),
            "enc_norm_w": jnp.ones((cfg.d_model,), self.dtype),
            "enc_norm_b": jnp.zeros((cfg.d_model,), self.dtype),
            "embed": dense_init(ks[2], v_pad, cfg.d_model, self.dtype, scale=0.02),
            "dec_pos": (jax.random.normal(ks[3], (MAX_DEC_POS, cfg.d_model))
                        * 0.02).astype(self.dtype),
            "dec_blocks": stack_layer_params(
                ks[4], cfg.n_layers, lambda k: self._block_init(k, True)),
            "dec_norm_w": jnp.ones((cfg.d_model,), self.dtype),
            "dec_norm_b": jnp.zeros((cfg.d_model,), self.dtype),
            "lm_head": dense_init(ks[5], cfg.d_model, v_pad, self.dtype),
        }

    def param_axes(self) -> dict:
        def attn_ax():
            return {"wq": (None, "fsdp", "heads"), "wk": (None, "fsdp", None),
                    "wv": (None, "fsdp", None), "wo": (None, "heads", "fsdp")}

        def block_ax(cross):
            ax = {"ln1_w": (None, None), "ln1_b": (None, None),
                  "attn": attn_ax(),
                  "ln2_w": (None, None), "ln2_b": (None, None),
                  "w1": (None, "fsdp", "ff"), "b1": (None, None),
                  "w2": (None, "ff", "fsdp"), "b2": (None, None)}
            if cross:
                ax["lnx_w"] = (None, None)
                ax["lnx_b"] = (None, None)
                ax["cross"] = attn_ax()
            return ax

        return {
            "enc_pos": (None, None), "enc_blocks": block_ax(False),
            "enc_norm_w": (None,), "enc_norm_b": (None,),
            "embed": ("vocab", "fsdp"), "dec_pos": (None, None),
            "dec_blocks": block_ax(True),
            "dec_norm_w": (None,), "dec_norm_b": (None,),
            "lm_head": ("fsdp", "vocab"),
        }

    def quant_site_map(self) -> dict:
        m = {}
        for w in ("wq", "wk", "wv"):
            m[("enc_blocks", "attn", w)] = "enc_attn_in"
            m[("dec_blocks", "attn", w)] = "dec_attn_in"
        m[("enc_blocks", "attn", "wo")] = "enc_attn_out"
        m[("dec_blocks", "attn", "wo")] = "dec_attn_out"
        m[("enc_blocks", "w1")] = "enc_mlp_in"
        m[("enc_blocks", "w2")] = "enc_mlp_down"
        m[("dec_blocks", "w1")] = "dec_mlp_in"
        m[("dec_blocks", "w2")] = "dec_mlp_down"
        m[("dec_blocks", "cross", "wq")] = "cross_q_in"
        m[("dec_blocks", "cross", "wk")] = "cross_kv_in"
        m[("dec_blocks", "cross", "wv")] = "cross_kv_in"
        m[("dec_blocks", "cross", "wo")] = "cross_out"
        return m

    # -- attention helpers ---------------------------------------------------
    def _mha(self, p, xq, xkv, causal, collect, stats, prefix,
             cache=None, cache_len=None, append=False):
        cfg = self.cfg
        hd = cfg.head_dim_
        b, tq, _ = xq.shape
        q = qlinear(xq, p["wq"]).reshape(b, tq, cfg.n_heads, hd)
        if cache is not None and not append:
            # cross-attention at decode: k/v precomputed in cache
            k_c, v_c = cache
            enc_len = jnp.full((b,), k_c.shape[2], jnp.int32)
            o = decode_attention(q, k_c, v_c, enc_len)
            new_cache = cache
        else:
            src = xkv if xkv is not None else xq
            tk = src.shape[1]
            k = qlinear(src, p["wk"]).reshape(b, tk, cfg.n_kv_heads, hd)
            v = qlinear(src, p["wv"]).reshape(b, tk, cfg.n_kv_heads, hd)
            k = shard_hint(k, "batch", "seq", "kv_heads", None)
            v = shard_hint(v, "batch", "seq", "kv_heads", None)
            if cache is not None:
                k_c, v_c = cache
                pos = cache_len - 1                      # (B,)
                k_c = update_cache_at(k_c, k.transpose(0, 2, 1, 3), pos)
                v_c = update_cache_at(v_c, v.transpose(0, 2, 1, 3), pos)
                o = decode_attention(q, k_c, v_c, cache_len)
                new_cache = (k_c, v_c)
            else:
                o = chunked_attention(q, k, v, causal=causal)
                new_cache = (k, v)
        o = o.reshape(b, tq, cfg.n_heads * hd)
        if collect:
            stats[prefix + "_out"] = site_stat(o)
        return qlinear(o, p["wo"]), new_cache

    def _mlp(self, p, x, collect, stats, prefix):
        h = qlinear(x, p["w1"]) + p["b1"].astype(x.dtype)
        h = jax.nn.gelu(h)
        h = shard_hint(h, "batch", "seq", "ff")
        if collect:
            stats[prefix + "_down"] = site_stat(h)
        return qlinear(h, p["w2"]) + p["b2"].astype(x.dtype)

    # -- encoder -------------------------------------------------------------
    def encode(self, params, frames, collect=False):
        cfg = self.cfg
        x = frames.astype(self.dtype) + params["enc_pos"][None, :frames.shape[1]]
        x = shard_hint(x, "batch", "seq", "embed")

        def body(x, p):
            stats = {}
            h = layer_norm(x, p["ln1_w"], p["ln1_b"])
            if collect:
                stats["enc_attn_in"] = site_stat(h)
            a, _ = self._mha(p["attn"], h, None, False, collect, stats,
                             "enc_attn")
            x = x + a
            h = layer_norm(x, p["ln2_w"], p["ln2_b"])
            if collect:
                stats["enc_mlp_in"] = site_stat(h)
            x = x + self._mlp(p, h, collect, stats, "enc_mlp")
            return x, (stats if collect else None)

        if self.cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, stats = layer_scan(body, x, params["enc_blocks"])
        x = layer_norm(x, params["enc_norm_w"], params["enc_norm_b"])
        return x, stats

    # -- decoder -------------------------------------------------------------
    def _dec_block(self, p, x, memory, collect, stats_out,
                   self_cache=None, cross_cache=None, cache_len=None):
        stats = {}
        h = layer_norm(x, p["ln1_w"], p["ln1_b"])
        if collect:
            stats["dec_attn_in"] = site_stat(h)
        a, new_self = self._mha(p["attn"], h, None, True, collect, stats,
                                "dec_attn", cache=self_cache,
                                cache_len=cache_len, append=self_cache is not None)
        x = x + a
        h = layer_norm(x, p["lnx_w"], p["lnx_b"])
        if collect:
            stats["cross_q_in"] = site_stat(h)
            stats["cross_kv_in"] = site_stat(memory)
        a, new_cross = self._mha(p["cross"], h, memory, False, collect, stats,
                                 "cross", cache=cross_cache)
        x = x + a
        h = layer_norm(x, p["ln2_w"], p["ln2_b"])
        if collect:
            stats["dec_mlp_in"] = site_stat(h)
        x = x + self._mlp(p, h, collect, stats, "dec_mlp")
        stats_out.update(stats)
        return x, new_self, new_cross

    def forward(self, params, batch, collect_stats: bool = False):
        """Teacher-forced decoder over encoder memory.  batch:
        {"tokens": (B, T), "frames": (B, T_enc, d)}."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, t = tokens.shape
        memory, enc_stats = self.encode(params, batch["frames"], collect_stats)
        x = embed_tokens(params["embed"], tokens).astype(self.dtype)
        x = x + params["dec_pos"][None, :t]
        x = shard_hint(x, "batch", "seq", "embed")

        def body(x, p):
            stats = {}
            x, _, _ = self._dec_block(p, x, memory, collect_stats, stats)
            return x, (stats if collect_stats else None)

        if self.cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, dec_stats = layer_scan(body, x, params["dec_blocks"])
        x = layer_norm(x, params["dec_norm_w"], params["dec_norm_b"])
        logits = logits_from_hidden(x, params["lm_head"], cfg.vocab_size)
        stats = {}
        if collect_stats:
            stats.update(enc_stats)
            stats.update(dec_stats)
        return logits, {"stats": stats, "moe_aux": jnp.zeros((), jnp.float32)}

    # -- serving -------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        hd = cfg.head_dim_
        self_shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_len, hd)
        cross_shape = (cfg.n_layers, batch, cfg.n_kv_heads, cfg.encoder_len, hd)
        return {"k": jnp.zeros(self_shape, self.dtype),
                "v": jnp.zeros(self_shape, self.dtype),
                "xk": jnp.zeros(cross_shape, self.dtype),
                "xv": jnp.zeros(cross_shape, self.dtype),
                "len": jnp.zeros((batch,), jnp.int32)}

    def cache_axes(self) -> dict:
        ax = (None, "batch", "kv_heads", "kv_seq", None)
        return {"k": ax, "v": ax, "xk": ax, "xv": ax, "len": None}

    def prefill(self, params, tokens, cache, frames=None):
        cfg = self.cfg
        b, t = tokens.shape
        memory, _ = self.encode(params, frames)
        x = embed_tokens(params["embed"], tokens).astype(self.dtype)
        x = x + params["dec_pos"][None, :t]

        def body(x, xs):
            p, kc, vc, xkc, xvc = xs
            stats = {}
            x, (k, v), (xk, xv) = self._dec_block(p, x, memory, False, stats)
            kc = jax.lax.dynamic_update_slice(
                kc, k.transpose(0, 2, 1, 3), (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                vc, v.transpose(0, 2, 1, 3), (0, 0, 0, 0))
            xkc = xk.transpose(0, 2, 1, 3).astype(xkc.dtype)
            xvc = xv.transpose(0, 2, 1, 3).astype(xvc.dtype)
            return x, (kc, vc, xkc, xvc)

        x, (kc, vc, xkc, xvc) = layer_scan(
            body, x, (params["dec_blocks"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        x = layer_norm(x[:, -1:], params["dec_norm_w"], params["dec_norm_b"])
        logits = logits_from_hidden(x, params["lm_head"], cfg.vocab_size)
        return logits, {"k": kc, "v": vc, "xk": xkc, "xv": xvc,
                        "len": jnp.full((b,), t, jnp.int32)}

    def decode_step(self, params, cache, token, pos=None):
        cfg = self.cfg
        b = token.shape[0]
        new_len = cache["len"] + 1                       # (B,)
        x = embed_tokens(params["embed"], token).astype(self.dtype)
        x = x + jnp.take(params["dec_pos"], new_len - 1, axis=0)[:, None]

        def body(x, xs):
            p, kc, vc, xkc, xvc = xs
            stats = {}
            x, (kc, vc), _ = self._dec_block(
                p, x, None, False, stats, self_cache=(kc, vc),
                cross_cache=(xkc, xvc), cache_len=new_len)
            return x, (kc, vc)

        x, (kc, vc) = layer_scan(
            body, x, (params["dec_blocks"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        x = layer_norm(x, params["dec_norm_w"], params["dec_norm_b"])
        logits = logits_from_hidden(x, params["lm_head"], cfg.vocab_size)
        return logits, {"k": kc, "v": vc, "xk": cache["xk"],
                        "xv": cache["xv"], "len": new_len}
