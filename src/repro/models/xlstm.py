"""xLSTM LM: mLSTM blocks with a sLSTM block every ``slstm_every`` layers.

Per-layer params hold **both** block types (superset; the unused one per
layer is small at this scale) so the layer scan stays homogeneous; a
per-layer flag selects the branch with ``lax.cond``.  Recurrent state
replaces the KV cache; it is O(1) in sequence length, which is exactly
why this arch runs the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.stats import site_stat
from repro.dist.sharding import shard_hint
from .common import (layer_scan,
                     dense_init, embed_tokens, logits_from_hidden,
                     padded_vocab, rms_norm, stack_layer_params)
from .dense import DenseLM
from . import ssm


class XLSTMLM(DenseLM):
    @property
    def _d_inner(self) -> int:
        return self.cfg.ssm_expand * self.cfg.d_model

    def _slstm_flags(self):
        k = self.cfg.slstm_every
        return jnp.array([(i % k == k - 1) if k else False
                          for i in range(self.cfg.n_layers)])

    def init(self, key) -> dict:
        cfg = self.cfg
        v_pad = padded_vocab(cfg.vocab_size)
        k_emb, k_blocks, k_head = jax.random.split(key, 3)

        def block_init(k):
            ks = jax.random.split(k, 2)
            return {
                "norm": jnp.ones((cfg.d_model,), self.dtype),
                "mlstm": ssm.mlstm_init(ks[0], cfg.d_model, self._d_inner,
                                        cfg.n_heads, self.dtype),
                "slstm": ssm.slstm_init(ks[1], cfg.d_model, cfg.n_heads,
                                        self.dtype),
            }

        return {
            "embed": dense_init(k_emb, v_pad, cfg.d_model, self.dtype, scale=0.02),
            "blocks": stack_layer_params(k_blocks, cfg.n_layers, block_init),
            "final_norm": jnp.ones((cfg.d_model,), self.dtype),
            "lm_head": dense_init(k_head, cfg.d_model, v_pad, self.dtype),
        }

    def param_axes(self) -> dict:
        return {
            "embed": ("vocab", "fsdp"),
            "blocks": {"norm": (None, None),
                       "mlstm": ssm.mlstm_axes(),
                       "slstm": ssm.slstm_axes()},
            "final_norm": (None,),
            "lm_head": ("fsdp", "vocab"),
        }

    def quant_site_map(self) -> dict:
        return {
            ("blocks", "mlstm", "up_proj"): "xin",
            ("blocks", "mlstm", "wq"): "m_qkv",
            ("blocks", "mlstm", "wk"): "m_qkv",
            ("blocks", "mlstm", "wv"): "m_qkv",
            ("blocks", "mlstm", "down_proj"): "m_out",
            ("blocks", "slstm", "w_in"): "xin",
            ("blocks", "slstm", "out_proj"): "s_out",
        }

    # -- forward -------------------------------------------------------------
    def forward(self, params, batch, collect_stats: bool = False):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, t = tokens.shape
        x = embed_tokens(params["embed"], tokens).astype(self.dtype)
        x = shard_hint(x, "batch", "seq", "embed")
        flags = self._slstm_flags()

        def body(x, xs):
            p, is_s = xs
            h = rms_norm(x, p["norm"], cfg.norm_eps)
            stats = {}
            if collect_stats:
                stats["xin"] = site_stat(h)
                # mLSTM qkv input (xi) + branch outputs for down-proj sites
                _, _, _, _, _, _, xi = ssm._mlstm_qkvg(p["mlstm"], h, cfg.n_heads)
                stats["m_qkv"] = site_stat(xi)
                holder = {}
                cb = lambda name, val: holder.__setitem__(name, site_stat(val))
                y_m = ssm.mlstm_chunked(p["mlstm"], h, cfg.n_heads, collect_cb=cb)
                y_s = ssm.slstm_scan(p["slstm"], h, cfg.n_heads, collect_cb=cb)
                stats["m_out"] = holder["mlstm_out"]
                stats["s_out"] = holder["slstm_out"]
                y = jnp.where(is_s, y_s, y_m)
            else:
                y = jax.lax.cond(
                    is_s,
                    lambda: ssm.slstm_scan(p["slstm"], h, cfg.n_heads),
                    lambda: ssm.mlstm_chunked(p["mlstm"], h, cfg.n_heads))
            x = x + y
            x = shard_hint(x, "batch", "seq", "embed")
            return x, (stats if collect_stats else None)

        if self.cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, stats = layer_scan(body, x, (params["blocks"], flags))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = logits_from_hidden(x, params["lm_head"], cfg.vocab_size)
        return logits, {"stats": stats if collect_stats else {},
                        "moe_aux": jnp.zeros((), jnp.float32)}

    # -- recurrent "cache" ---------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        L = cfg.n_layers
        hd_m = self._d_inner // cfg.n_heads
        hd_s = cfg.d_model // cfg.n_heads
        bcast = lambda x: jnp.broadcast_to(x, (L,) + x.shape)
        m_state = jax.tree_util.tree_map(
            bcast, ssm.mlstm_state_init(batch, cfg.n_heads, hd_m))
        s_state = [bcast(s) for s in
                   ssm.slstm_state_init(batch, cfg.n_heads, hd_s)]
        return {"mlstm": m_state, "slstm": s_state,
                "len": jnp.zeros((batch,), jnp.int32)}

    def cache_axes(self) -> dict:
        return {"mlstm": {"C": (None, "batch", "heads", None, None),
                          "n": (None, "batch", "heads", None)},
                "slstm": [(None, "batch", "heads", None)] * 4,
                "len": None}

    def prefill(self, params, tokens, cache):
        cfg = self.cfg
        b, t = tokens.shape
        x = embed_tokens(params["embed"], tokens).astype(self.dtype)
        flags = self._slstm_flags()

        def body(x, xs):
            p, is_s, mst, sst = xs
            h = rms_norm(x, p["norm"], cfg.norm_eps)

            def m_branch():
                y, new = ssm.mlstm_chunked(p["mlstm"], h, cfg.n_heads,
                                           state=mst, return_state=True)
                return y, new, sst

            def s_branch():
                y, new = _slstm_scan_final(p["slstm"], h, cfg.n_heads, sst)
                return y, mst, new

            y, mst2, sst2 = jax.lax.cond(is_s, s_branch, m_branch)
            return x + y, (mst2, sst2)

        x, (mst, sst) = layer_scan(
            body, x, (params["blocks"], flags, cache["mlstm"], cache["slstm"]))
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = logits_from_hidden(x, params["lm_head"], cfg.vocab_size)
        return logits, {"mlstm": mst, "slstm": sst,
                        "len": jnp.full((b,), t, jnp.int32)}

    def decode_step(self, params, cache, token, pos=None):
        cfg = self.cfg
        x = embed_tokens(params["embed"], token).astype(self.dtype)
        flags = self._slstm_flags()

        def body(x, xs):
            p, is_s, mst, sst = xs
            h = rms_norm(x, p["norm"], cfg.norm_eps)

            def m_branch():
                y, new = ssm.mlstm_step(p["mlstm"], h, mst, cfg.n_heads)
                return y, new, sst

            def s_branch():
                y, new = ssm.slstm_step(p["slstm"], h, sst, cfg.n_heads)
                return y, mst, list(new)

            y, mst2, sst2 = jax.lax.cond(is_s, s_branch, m_branch)
            return x + y, (mst2, sst2)

        x, (mst, sst) = layer_scan(
            body, x, (params["blocks"], flags, cache["mlstm"], cache["slstm"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = logits_from_hidden(x, params["lm_head"], cfg.vocab_size)
        return logits, {"mlstm": mst, "slstm": sst, "len": cache["len"] + 1}


def _slstm_scan_final(p, x, n_heads, state):
    from .common import qlinear
    b, t, d = x.shape
    hd = d // n_heads
    gx = (qlinear(x, p["w_in"]) + p["bias"].astype(x.dtype)
          ).astype(jnp.float32).reshape(b, t, 4, n_heads, hd)

    def step(st, gx_t):
        new = ssm._slstm_cell(p, gx_t, st, n_heads)
        return new, new[0]

    final, hs = jax.lax.scan(step, tuple(state), gx.transpose(1, 0, 2, 3, 4))
    hs = hs.transpose(1, 0, 2, 3).reshape(b, t, d)
    from .common import qlinear
    y = rms_norm(hs, p["out_norm"]).astype(x.dtype)
    return qlinear(y, p["out_proj"]), list(final)
