"""Observability layer: metrics registry, span tracer, profiler hooks.

One subsystem (DESIGN.md §17) behind the serving stack's three
measurement questions:

* **how much / how often** — :class:`MetricsRegistry` with
  :class:`Counter` / :class:`Gauge` / :class:`Histogram`, labeled by
  tenant / cache kind / phase; snapshot/delta replaces the old
  hand-merged metrics dicts.
* **when / in what order** — :class:`Tracer`, a ring-buffered span
  collector timestamped exclusively through the engine's injectable
  ``clock=`` seam, exporting Chrome/Perfetto ``trace_event`` JSON.
* **what is the device doing** — :mod:`.profile`, optional
  ``jax.profiler`` wrappers around the jitted entry points.
"""
from .metrics import (DEFAULT_MS_EDGES, Counter, Gauge, Histogram,
                      MetricGroup, MetricsRegistry, dist_ms,
                      never_nan_percentile)
from .profile import annotation, profile_session, profiler_available
from .trace import (PID_ENGINE, PID_REQUESTS, Tracer, check_span_nesting,
                    validate_trace)

__all__ = [
    "DEFAULT_MS_EDGES", "Counter", "Gauge", "Histogram", "MetricGroup",
    "MetricsRegistry", "dist_ms", "never_nan_percentile",
    "annotation", "profile_session", "profiler_available",
    "PID_ENGINE", "PID_REQUESTS", "Tracer", "check_span_nesting",
    "validate_trace",
]
