"""CLI: ``python -m repro.obs <trace.json> [...]`` — validate exported
traces against the trace_event schema (the CI obs-smoke job runs this
over the traffic bench's ``--trace-out`` file)."""
from __future__ import annotations

import json
import sys

from .trace import check_span_nesting, validate_trace


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.obs <trace.json> [...]",
              file=sys.stderr)
        return 2
    rc = 0
    for path in argv:
        with open(path) as f:
            obj = json.load(f)
        problems = validate_trace(obj)
        problems += check_span_nesting(obj.get("traceEvents", []))
        events = obj.get("traceEvents", [])
        other = obj.get("otherData", {})
        print(f"{path}: {len(events)} events "
              f"(recorded={other.get('recorded')}, "
              f"dropped={other.get('dropped')}, "
              f"capacity={other.get('capacity')})")
        for p in problems:
            print(f"  {p}")
            rc = 1
        if not problems:
            print("  OK: schema valid, spans balanced")
    return rc


if __name__ == "__main__":
    sys.exit(main())
