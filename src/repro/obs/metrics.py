"""Unified metrics registry: Counter / Gauge / Histogram (DESIGN.md §17).

Before this layer the serving stack kept five hand-merged dicts —
``ServeEngine._m``, ``SpecRunner.m``, ``PagePool``'s attribute
counters, ``FaultInjector.counts``, and the bench-local percentile
code — each with its own snapshot/delta convention.  The registry
replaces them with one model:

* a **metric** is a named :class:`Counter`, :class:`Gauge`, or
  :class:`Histogram`, optionally **labeled** (``tenant=``,
  ``cache_kind=``, ``phase=``); the (name, labels) pair is the
  identity, so ``registry.counter("serve.shed_by_tenant", tenant="a")``
  always returns the same object;
* a **group** (:class:`MetricGroup`) is a dict-shaped view over
  counters sharing a name prefix — ``group["tokens_generated"] += 1``
  keeps the ergonomics of the old plain dicts while every increment
  lands in the registry (``dict(group)`` still materializes the old
  shape, so ``metrics()`` surfaces are unchanged);
* :meth:`MetricsRegistry.snapshot` flattens everything to a JSON-safe
  dict and :meth:`MetricsRegistry.delta` subtracts a prior snapshot —
  counters and histograms difference, gauges report current — which is
  what ``Scheduler.run`` digests into ``RunResult.summary``.

The shared never-NaN percentile helpers live here too
(:func:`never_nan_percentile`, :func:`dist_ms`): ``loadgen.summarize``
and ``benchmarks/traffic_bench.py`` previously hand-rolled the same
p50/p95/p99 math; an empty or shed-everything sample reports zeros,
never a NaN that poisons JSON dashboards downstream.
"""
from __future__ import annotations

import bisect
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

# Fixed bucket edges for millisecond-latency histograms: two-ish steps
# per decade across the range a serving step or TTFT can land in.
# Fixed (not adaptive) edges keep snapshots subtractable and traces
# comparable across runs.
DEFAULT_MS_EDGES = (1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                    500.0, 1000.0, 2500.0, 5000.0, 10000.0)


def never_nan_percentile(xs, q) -> float:
    """Exact percentile hardened for overload reports: an empty sample
    (a run that shed or expired everything) reports 0.0, not a crash or
    a NaN.  Non-finite samples are dropped before the percentile."""
    arr = np.asarray(list(xs) if not hasattr(xs, "size") else xs,
                     np.float64)
    if arr.size == 0:
        return 0.0
    arr = arr[np.isfinite(arr)]
    if arr.size == 0:
        return 0.0
    return float(np.percentile(arr, q))


def dist_ms(xs) -> dict:
    """p50/p95/p99/mean/n of a sample of *seconds*, reported in ms —
    the distribution shape every latency report in the repo uses.
    Empty samples report all-zero (never NaN)."""
    if not xs:
        return dict(p50=0.0, p95=0.0, p99=0.0, mean=0.0, n=0)
    ms = [1e3 * x for x in xs]
    return dict(p50=never_nan_percentile(ms, 50),
                p95=never_nan_percentile(ms, 95),
                p99=never_nan_percentile(ms, 99),
                mean=float(np.mean(ms)), n=len(ms))


class Counter:
    """Monotonic-by-convention scalar.  Arithmetic type follows the
    values fed in (int counters stay int; ``serve_time_s`` stays
    float), so ``dict(group)`` reproduces the old plain-dict shapes."""

    kind = "counter"

    def __init__(self, value=0):
        self.value = value

    def inc(self, n=1):
        self.value = self.value + n

    def snapshot(self):
        return self.value


class Gauge:
    """Point-in-time scalar (queue-delay estimate, in-flight tokens).
    ``delta`` semantics: a gauge reports its *current* value, never a
    difference."""

    kind = "gauge"

    def __init__(self, value=0):
        self.value = value

    def set(self, v):
        self.value = v

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed-bucket histogram: ``edges`` are upper bounds, plus one
    overflow bucket.  Percentiles interpolate within the landing bucket
    (assuming uniform mass), clamped to the top edge for overflow —
    never NaN, 0.0 when empty."""

    kind = "histogram"

    def __init__(self, edges: Iterable[float] = DEFAULT_MS_EDGES):
        self.edges: Tuple[float, ...] = tuple(float(e) for e in edges)
        if list(self.edges) != sorted(set(self.edges)):
            raise ValueError("histogram edges must be strictly increasing")
        self.counts: List[int] = [0] * (len(self.edges) + 1)
        self.sum = 0.0
        self.count = 0

    @classmethod
    def from_samples(cls, xs, edges: Iterable[float] = DEFAULT_MS_EDGES
                     ) -> "Histogram":
        h = cls(edges)
        for x in xs:
            h.observe(x)
        return h

    def observe(self, x):
        x = float(x)
        self.counts[bisect.bisect_left(self.edges, x)] += 1
        self.sum += x
        self.count += 1

    def percentile(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        target = (q / 100.0) * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if cum + c >= target and c > 0:
                lo = self.edges[i - 1] if i > 0 else 0.0
                hi = self.edges[i] if i < len(self.edges) else self.edges[-1]
                frac = (target - cum) / c
                return lo + frac * (hi - lo)
            cum += c
        return self.edges[-1]

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return dict(count=self.count, sum=self.sum,
                    counts=list(self.counts), edges=list(self.edges))


def _qualname(name: str, labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"


class MetricsRegistry:
    """Get-or-create metric store keyed on (name, sorted labels)."""

    def __init__(self):
        self._metrics: Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                            object] = {}

    def _get(self, cls, name: str, labels: dict, **kwargs):
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = cls(**kwargs)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, edges=DEFAULT_MS_EDGES,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, edges=edges)

    def adopt(self, metric, name: str, **labels):
        """Register an *existing* metric object under this registry
        (rebinding a component built standalone — e.g. a FaultInjector
        constructed before its engine — without losing its counts)."""
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        self._metrics[key] = metric
        return metric

    def group(self, prefix: str, **labels) -> "MetricGroup":
        return MetricGroup(self, prefix, labels)

    def snapshot(self) -> dict:
        """Flat JSON-safe ``{qualified_name: value}`` — scalars for
        counters/gauges, bucket dicts for histograms."""
        return {_qualname(name, labels): m.snapshot()
                for (name, labels), m in sorted(self._metrics.items())}

    def delta(self, before: dict) -> dict:
        """Difference vs a prior :meth:`snapshot`: counters and
        histograms subtract (a metric born since reports its full
        value), gauges report current."""
        out = {}
        for (name, labels), m in sorted(self._metrics.items()):
            q = _qualname(name, labels)
            prev = before.get(q)
            if m.kind == "counter" and prev is not None:
                out[q] = m.value - prev
            elif m.kind == "histogram" and isinstance(prev, dict):
                cur = m.snapshot()
                out[q] = dict(
                    count=cur["count"] - prev.get("count", 0),
                    sum=cur["sum"] - prev.get("sum", 0.0),
                    counts=[a - b for a, b in
                            zip(cur["counts"],
                                prev.get("counts", [0] * len(cur["counts"])))],
                    edges=cur["edges"])
            else:
                out[q] = m.snapshot()
        return out


class MetricGroup:
    """Dict-shaped view over same-prefix counters: ``group["shed"] += 1``
    increments the registry counter ``<prefix>.shed`` (with the group's
    labels).  Provides the mapping protocol the old plain dicts were
    used through — ``dict(group)``, ``in``, ``.items()`` — so existing
    ``metrics()`` consumers see identical shapes."""

    def __init__(self, registry: MetricsRegistry, prefix: str,
                 labels: Optional[dict] = None):
        self._registry = registry
        self._prefix = prefix
        self._labels = dict(labels or {})
        self._names: List[str] = []       # insertion order, dict-like

    def init(self, **values) -> "MetricGroup":
        """Declare the group's counters with initial values (the old
        ``dict(tokens_generated=0, ...)`` literal, one-for-one)."""
        for k, v in values.items():
            self[k] = v
        return self

    def _ctr(self, name: str) -> Counter:
        c = self._registry.counter(f"{self._prefix}.{name}", **self._labels)
        if name not in self._names:
            self._names.append(name)
        return c

    def __getitem__(self, name: str):
        return self._ctr(name).value

    def __setitem__(self, name: str, value):
        self._ctr(name).value = value

    def __contains__(self, name) -> bool:
        return name in self._names

    def __iter__(self):
        return iter(self._names)

    def __len__(self) -> int:
        return len(self._names)

    def keys(self):
        return list(self._names)

    def items(self):
        return [(k, self[k]) for k in self._names]

    def rebind(self, registry: MetricsRegistry) -> "MetricGroup":
        """Move this group's metric objects into another registry (a
        component built standalone joining its engine's registry);
        counts carry over, future snapshots include them."""
        if registry is self._registry:
            return self
        for name in self._names:
            registry.adopt(self._ctr(name), f"{self._prefix}.{name}",
                           **self._labels)
        self._registry = registry
        return self
