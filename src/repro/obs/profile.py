"""Optional ``jax.profiler`` hooks for the serving entry points.

The span tracer (:mod:`.trace`) answers *host-side* timeline questions;
when the question is "what is the device doing inside that span", the
XLA profiler is the right tool.  This module is the thin, always-safe
seam between the two:

* :func:`profile_session` — wrap a serve/bench run in
  ``jax.profiler.trace(logdir)`` (TensorBoard/Perfetto-readable device
  profile).  ``logdir=None`` or an unavailable profiler degrade to a
  no-op, so call sites never branch.
* :func:`annotation` — a named ``TraceAnnotation`` around one jitted
  entry-point call, so prefill/decode/spec dispatches show up as named
  regions inside the device profile.  ``TraceCounter`` applies it when
  its engine was built with ``profile=True``.

Nothing here is on by default: profiling is opt-in per run
(``launch/serve.py --profile-dir``), and the no-op paths add a single
attribute check to the hot loop.
"""
from __future__ import annotations

from contextlib import contextmanager, nullcontext

try:                                     # pragma: no cover - import guard
    from jax import profiler as _profiler
except Exception:                        # pragma: no cover
    _profiler = None


def profiler_available() -> bool:
    return _profiler is not None


@contextmanager
def profile_session(logdir=None):
    """Device-profile the enclosed block into ``logdir`` (no-op when
    ``logdir`` is falsy or jax.profiler is unavailable)."""
    if not logdir or _profiler is None:
        yield None
        return
    with _profiler.trace(str(logdir)):
        yield str(logdir)


def annotation(name: str):
    """Named profiler region for one dispatch (no-op context manager
    when the profiler is unavailable)."""
    if _profiler is None:
        return nullcontext()
    return _profiler.TraceAnnotation(name)
