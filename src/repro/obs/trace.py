"""Ring-buffered span tracer with a Chrome/Perfetto trace_event exporter.

The serving stack's timeline questions — "why did this request's TTFT
blow past p99", "what did the engine do during the overload storm" —
need per-request and per-step *events*, not counters.  :class:`Tracer`
collects them into a bounded ring (a deque with ``maxlen``; an
overload storm evicts the oldest events instead of growing without
bound, and ``dropped`` counts the evictions) and exports the
`trace_event <https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU>`_
JSON that ``chrome://tracing`` / `Perfetto <https://ui.perfetto.dev>`_
render.

Determinism rule (DESIGN.md §17): the tracer reads time **only**
through its ``clock`` attribute, which the engine re-points at its own
injectable ``clock=`` seam on attach — under a fake clock two
identical runs export byte-identical JSON (sorted keys, compact
separators, timestamps anchored to the earliest event).  Nothing here
ever touches device values, so tracing adds zero host transfers to
the serve path.

Event vocabulary:

* ``X`` (complete) spans — emitted *at close* with ``ts`` + ``dur``,
  so a ring-evicted span never leaves an unbalanced ``B``/``E`` pair;
* ``i`` (instant) — lifecycle edges (arrival, shed, preempt, resume,
  retire) and compile/retrace marks;
* ``C`` (counter) — numeric tracks (pages in use, queue depth);
* ``M`` (metadata) — process/thread names, generated fresh at export
  time from the name table (never ring-evicted).

Track layout: ``pid 1`` is the engine (step loop, tid 0); ``pid 2``
is the request swimlane — one tid per rid, so every request renders as
its own row of queue/prefill/decode spans.
"""
from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager
from typing import Optional

PID_ENGINE = 1
PID_REQUESTS = 2

_PROCESS_NAMES = {PID_ENGINE: "engine", PID_REQUESTS: "requests"}


class Tracer:
    """Bounded trace-event collector over an injectable clock."""

    def __init__(self, clock=None, capacity: int = 8192):
        self.clock = clock if clock is not None else time.time
        self.capacity = int(capacity)
        self._events = deque(maxlen=self.capacity)
        self.dropped = 0
        self._threads = {}            # (pid, tid) -> display name

    # -- recording -----------------------------------------------------------
    def _emit(self, ev: dict):
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(ev)

    @contextmanager
    def span(self, name: str, *, pid: int = PID_ENGINE, tid: int = 0,
             cat: str = "serve", args: Optional[dict] = None):
        """Complete-span context manager; yields the args dict so the
        body can attach results (accepted depth, group size, ...)."""
        t0 = self.clock()
        a = dict(args) if args else {}
        try:
            yield a
        finally:
            self.complete(name, t0, self.clock(), pid=pid, tid=tid,
                          cat=cat, args=a)

    def complete(self, name: str, t_start: float, t_end: float, *,
                 pid: int = PID_ENGINE, tid: int = 0, cat: str = "serve",
                 args: Optional[dict] = None):
        """One ``X`` event from two explicit clock stamps (for spans
        whose start was recorded on a request object)."""
        ev = dict(ph="X", name=name, cat=cat, pid=pid, tid=tid,
                  ts=float(t_start),
                  dur=max(float(t_end) - float(t_start), 0.0))
        if args:
            ev["args"] = args
        self._emit(ev)

    def instant(self, name: str, *, pid: int = PID_ENGINE, tid: int = 0,
                cat: str = "serve", args: Optional[dict] = None):
        ev = dict(ph="i", s="t", name=name, cat=cat, pid=pid, tid=tid,
                  ts=float(self.clock()))
        if args:
            ev["args"] = args
        self._emit(ev)

    def counter(self, name: str, values: dict, *, pid: int = PID_ENGINE,
                tid: int = 0):
        self._emit(dict(ph="C", name=name, cat="counter", pid=pid,
                        tid=tid, ts=float(self.clock()),
                        args=dict(values)))

    def thread_name(self, pid: int, tid: int, name: str):
        self._threads[(pid, tid)] = name

    def events(self) -> list:
        """Recorded events with timestamps anchored to the *earliest*
        surviving event and converted to microseconds.  Anchoring at
        read time (not at record time) keeps every ts non-negative even
        though span starts can predate the first recorded event — a
        queue span's start is the request's arrival stamp, which the
        open-loop feed may place before the engine's first step event."""
        evs = list(self._events)
        if not evs:
            return []
        t0 = min(ev["ts"] for ev in evs)
        out = []
        for ev in evs:
            e = dict(ev, ts=round((ev["ts"] - t0) * 1e6, 3))
            if "dur" in e:
                e["dur"] = round(e["dur"] * 1e6, 3)
            out.append(e)
        return out

    # -- export --------------------------------------------------------------
    def to_json(self) -> dict:
        """The full trace object.  Metadata events are generated here —
        never stored in the ring — so process/thread names survive any
        amount of eviction."""
        meta = [dict(ph="M", name="process_name", pid=pid, tid=0, ts=0,
                     args=dict(name=label))
                for pid, label in sorted(_PROCESS_NAMES.items())]
        meta += [dict(ph="M", name="thread_name", pid=pid, tid=tid, ts=0,
                      args=dict(name=label))
                 for (pid, tid), label in sorted(self._threads.items())]
        return {"traceEvents": meta + self.events(),
                "displayTimeUnit": "ms",
                "otherData": {"capacity": self.capacity,
                              "dropped": self.dropped,
                              "recorded": len(self._events)}}

    def export(self, path) -> str:
        """Write the trace as deterministic JSON (sorted keys, compact
        separators): identical event streams produce byte-identical
        files, which the fake-clock determinism test asserts."""
        with open(path, "w") as f:
            f.write(json.dumps(self.to_json(), sort_keys=True,
                               separators=(",", ":")))
            f.write("\n")
        return str(path)


# ---------------------------------------------------------------------------
# Validation (tests + the CI obs-smoke job)
# ---------------------------------------------------------------------------

_REQUIRED = {"ph", "name", "pid", "tid", "ts"}
_PHASES = {"X", "i", "C", "M"}


def validate_trace(obj) -> list:
    """Schema-check a trace object (or a path to one) against the
    trace_event contract this module emits; returns a list of problem
    strings (empty == valid)."""
    if isinstance(obj, (str, bytes)):
        with open(obj) as f:
            obj = json.load(f)
    problems = []
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        missing = _REQUIRED - set(ev)
        if missing:
            problems.append(f"{where}: missing {sorted(missing)}")
            continue
        if ev["ph"] not in _PHASES:
            problems.append(f"{where}: unknown ph {ev['ph']!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            problems.append(f"{where}: bad ts {ev['ts']!r}")
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), (int, float)) \
                    or ev["dur"] < 0:
                problems.append(f"{where}: X span needs dur >= 0")
        if ev["ph"] == "i" and ev.get("s") not in ("t", "p", "g"):
            problems.append(f"{where}: instant needs scope s in t/p/g")
        if ev["ph"] == "M" and "name" not in ev.get("args", {}):
            problems.append(f"{where}: metadata needs args.name")
        if ev["ph"] == "C" and not isinstance(ev.get("args"), dict):
            problems.append(f"{where}: counter needs an args dict")
    return problems


def check_span_nesting(events) -> list:
    """Per-(pid, tid) properly-nested check over ``X`` spans: two spans
    on one track must either nest or be disjoint (a partial overlap
    means a span closed across another's boundary — unbalanced
    instrumentation).  Returns violation strings."""
    tracks = {}
    for ev in events:
        if ev.get("ph") == "X":
            tracks.setdefault((ev["pid"], ev["tid"]), []).append(ev)
    problems = []
    # Export rounds ts and dur to 0.001 us *independently*, so a span
    # end reconstructed as ts + dur and the adjacent span's start —
    # three roundings of two raw stamps — can disagree by up to
    # ~0.002 us even when the raw stamps are identical.  Anything
    # under that quantum is "touching", not crossing.
    eps = 2e-3
    for key, spans in sorted(tracks.items()):
        spans.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack = []                      # open spans' (end, name)
        for ev in spans:
            t0, t1 = ev["ts"], ev["ts"] + ev["dur"]
            while stack and stack[-1][0] <= t0 + eps:
                stack.pop()
            if stack and t1 > stack[-1][0] + eps:
                problems.append(
                    f"track {key}: span {ev['name']!r} "
                    f"[{t0}, {t1}] crosses enclosing "
                    f"{stack[-1][1]!r} ending at {stack[-1][0]}")
            stack.append((t1, ev["name"]))
    return problems
