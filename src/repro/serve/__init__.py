"""Serving: bucketed continuous-batching engine over FAQ-quantized weights."""
from .buckets import bucket_for, default_buckets
from .cache_ops import merge_slots, write_slot
from .engine import Request, ServeEngine, TraceCounter
from .sampler import sample_tokens
from .scheduler import Scheduler
