"""Serving: bucketed continuous-batching engine over FAQ-quantized weights."""
from .buckets import bucket_for, default_buckets
from .cache_ops import (copy_page, merge_slots, scatter_prefill_pages,
                        write_slot)
from .engine import Request, ServeEngine, TraceCounter
from .pages import PagePool, block_hashes
from .sampler import sample_tokens
from .scheduler import Scheduler
