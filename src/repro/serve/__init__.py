"""Serving: bucketed continuous-batching engine over FAQ-quantized weights."""
from .buckets import bucket_for, default_buckets
from .cache_ops import (copy_page, merge_slots, scatter_prefill_pages,
                        truncate_slot, write_slot)
from .draft import ModelDraft, SelfDraft, registry_draft, self_int8_draft
from .engine import Request, ServeEngine, TraceCounter
from .faults import FaultConfig, FaultInjector, burstify
from . import instrument
from .loadgen import ArrivalFeed, TrafficConfig, make_trace, summarize
from .overload import SLOAdmission, SLOConfig, request_tokens
from .pages import PagePool, PagePressure, PoolExhausted, block_hashes
from .slots import SlotTable, effective_prompt
from .sampler import (draw_from_probs, policy_probs, sample_tokens,
                      spec_accept)
from .scheduler import RunResult, Scheduler
from .spec import SpecConfig, SpecRunner
