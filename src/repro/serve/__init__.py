"""Serving: continuous-batching engine over FAQ-quantized weights."""
from .engine import Request, ServeEngine
