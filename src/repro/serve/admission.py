"""Admission strategies over the shared slot table.

One pipeline, three strategies, tried in order per free-slot pass:

* :class:`PrefixHitAdmission` (paged only) — the head request's leading
  prompt blocks are already in the prefix index: map the shared pages,
  skip their prefill entirely, stream the uncached tail through the
  decode step via the slot's ``fill`` list.
* :class:`BucketedAdmission` — group FIFO-ordered waiting requests that
  share the head request's length bucket and prefill them in one
  slot-aligned batch.  With chunked prefill enabled, a long prompt is
  admitted as its first ``prefill_chunk`` tokens (one bucket-sized
  batched prefill) and the remainder teacher-forces through subsequent
  decode steps exactly like a prefix-hit tail — so a long admission
  never stalls the decode batch for more than one chunk.  On the paged
  path, queued requests whose first block duplicates a group member's
  are deferred one pass so they hit the index instead of prefilling the
  same prefix twice.
* :class:`SingleAdmission` — exact-length batch-1 fallback for models
  whose ``prefill`` takes no ``prompt_len`` (ring-buffer hymba,
  recurrent xlstm); chunking requires ``prompt_len`` and is disabled.

Strategies mutate only the :class:`.slots.SlotTable` and the stepper
(via its admission entry points); emission, accounting, and finish
checks stay in the engine.
"""
from __future__ import annotations

import numpy as np

from .buckets import bucket_for
from .pages import block_hashes


class _Strategy:
    def __init__(self, engine):
        self.engine = engine

    def admit(self, run, free) -> bool:
        """Try to admit from ``run.queue`` head into ``free`` slots.
        Returns True if this strategy made progress (so the pipeline
        re-checks free slots before the next pass)."""
        raise NotImplementedError


class PrefixHitAdmission(_Strategy):
    def admit(self, run, free) -> bool:
        eng = self.engine
        st, stp = run.st, eng._stepper
        head = run.queue[0]
        hashes = run.hashes_of(head)
        if not stp.pool.lookup_blocks(hashes):
            return False
        # prefix hit: map the shared pages, skip their prefill, stream
        # the tail through decode
        run.queue.pop(0)
        s = free[0]
        matched = stp.pool.match(hashes)
        npr = len(head.prompt)
        # always leave >= 1 token to process so the first sampled token
        # has logits; a fully-cached prompt re-feeds its last token (the
        # write into the shared final page is what triggers
        # copy-on-write)
        cached = min(len(matched) * stp.page_size, npr - 1)
        for j, phys in enumerate(matched):
            stp.table[s, j] = phys
        eng._admit_bind(run, head, s)
        st.hashes[s] = hashes
        st.slot_len[s] = cached
        st.fill[s] = np.asarray(head.prompt, np.int32)[cached:]
        eng._m["prefix_hits"] += 1
        eng._m["prefix_hit_tokens"] += cached
        return True


class BucketedAdmission(_Strategy):
    def admit(self, run, free) -> bool:
        eng = self.engine
        st, stp = run.st, eng._stepper
        queue = run.queue
        paged = stp.kind == "paged"
        chunk = eng.prefill_chunk

        def admit_len(r) -> int:
            n = len(r.prompt)
            return min(n, chunk) if chunk else n

        head = queue[0]
        b = bucket_for(eng.buckets, admit_len(head))
        group, seen_block0 = [], set()
        i = 0
        while i < len(queue) and len(group) < len(free):
            r = queue[i]
            if eng._handle_immediate(r, run.results):
                queue.pop(i)
                continue
            hs = run.hashes_of(r) if paged else None
            if paged and r is not head and hs and (
                    stp.pool.lookup_blocks(hs) or hs[0] in seen_block0):
                i += 1
                continue
            if bucket_for(eng.buckets, admit_len(r)) == b:
                group.append((queue.pop(i), hs))
                if paged and hs:
                    seen_block0.add(hs[0])
                continue
            i += 1
        if not group:
            return True      # drained immediates; pipeline re-checks
        tokens = np.zeros((st.n, b), np.int32)
        plen = np.ones(st.n, np.int32)
        admit_mask = np.zeros(st.n, bool)
        targets = free[:len(group)]
        placed = []
        for (req, hs), s in zip(group, targets):
            p = np.asarray(req.prompt, np.int32)
            al = admit_len(req)
            tokens[s, :al] = p[:al]
            plen[s] = al
            admit_mask[s] = True
            eng._admit_bind(run, req, s)
            st.hashes[s] = hs
            st.slot_len[s] = al
            if al < len(p):
                # chunked admission: the rest of the prompt
                # teacher-forces through decode; no token emits until
                # the fill drains (the sampled first token below is a
                # mid-prompt continuation, discarded)
                st.fill[s] = p[al:]
                eng._m["chunked_admissions"] += 1
            placed.append((req, s))
        stp.admit_group(st, tokens, plen, admit_mask, placed)
        eng._m["prefill_batches"] += 1
        toks = np.asarray(st.slot_last)
        for req, s in placed:
            if st.fill[s] is not None:
                continue
            eng._post_admit(run, req, s, int(toks[s]))
        return True


class SingleAdmission(_Strategy):
    def admit(self, run, free) -> bool:
        eng = self.engine
        st = run.st
        req = None
        while run.queue:
            cand = run.queue.pop(0)
            if not eng._handle_immediate(cand, run.results):
                req = cand
                break
        if req is None:
            return True
        s = free[0]
        eng._admit_bind(run, req, s)
        st.slot_len[s] = len(req.prompt)
        eng._stepper.admit_single(st, req, s)
        eng._m["prefill_batches"] += 1
        eng._post_admit(run, req, s, int(np.asarray(st.slot_last)[s]))
        return True


class AdmissionPipeline:
    """Orders the strategies for the engine's cache kind and drains the
    queue into free slots until neither slots nor admissible requests
    remain."""

    def __init__(self, engine):
        self.engine = engine
        stp = engine._stepper
        if stp.kind == "paged":
            self.strategies = [PrefixHitAdmission(engine),
                               BucketedAdmission(engine)]
        elif engine._supports_plen:
            self.strategies = [BucketedAdmission(engine)]
        else:
            self.strategies = [SingleAdmission(engine)]

    def fill_slots(self, run):
        eng = self.engine
        while True:
            free = run.st.free()
            if not free or not run.queue:
                return
            while run.queue and eng._handle_immediate(run.queue[0],
                                                      run.results):
                run.queue.pop(0)
            if not run.queue:
                continue
            for strat in self.strategies:
                if strat.admit(run, free):
                    break
            else:
                return


class ServeRun:
    """Per-``serve()`` scope: the FIFO queue, the results dict, the
    slot table, and the prompt-hash memo (hashes are deterministic per
    request — computed once, not once per fill pass)."""

    def __init__(self, engine, requests):
        from .slots import SlotTable
        self.queue = list(requests)
        self.results: dict = {}
        self.st = SlotTable(engine.n_slots)
        self._engine = engine
        self._hash_cache: dict = {}

    def hashes_of(self, req) -> list:
        ent = self._hash_cache.get(id(req))
        if ent is None or ent[0] is not req:
            ent = (req, block_hashes(req.prompt,
                                     self._engine._stepper.page_size))
            self._hash_cache[id(req)] = ent
        return ent[1]
