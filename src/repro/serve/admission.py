"""Admission strategies over the shared slot table.

One pipeline, three strategies, tried in order per free-slot pass:

* :class:`PrefixHitAdmission` (paged only) — the head request's leading
  prompt blocks are already in the prefix index: map the shared pages,
  skip their prefill entirely, stream the uncached tail through the
  decode step via the slot's ``fill`` list.
* :class:`BucketedAdmission` — group FIFO-ordered waiting requests that
  share the head request's length bucket and prefill them in one
  slot-aligned batch.  With chunked prefill enabled, a long prompt is
  admitted as its first ``prefill_chunk`` tokens (one bucket-sized
  batched prefill) and the remainder teacher-forces through subsequent
  decode steps exactly like a prefix-hit tail — so a long admission
  never stalls the decode batch for more than one chunk.  On the paged
  path, queued requests whose first block duplicates a group member's
  are deferred one pass so they hit the index instead of prefilling the
  same prefix twice.
* :class:`SingleAdmission` — exact-length batch-1 fallback for models
  whose ``prefill`` takes no ``prompt_len`` (ring-buffer hymba,
  recurrent xlstm); chunking requires ``prompt_len`` and is disabled.

Strategies mutate only the :class:`.slots.SlotTable` and the stepper
(via its admission entry points); emission, accounting, and finish
checks stay in the engine.
"""
from __future__ import annotations

import numpy as np

from . import instrument
from .buckets import bucket_for
from .pages import PagePressure, block_hashes
from .slots import effective_prompt


class _Strategy:
    def __init__(self, engine):
        self.engine = engine

    def admit(self, run, free) -> bool:
        """Try to admit from ``run.queue`` head into ``free`` slots.
        Returns True if this strategy made progress (so the pipeline
        re-checks free slots before the next pass)."""
        raise NotImplementedError


class PrefixHitAdmission(_Strategy):
    def admit(self, run, free) -> bool:
        eng = self.engine
        st, stp = run.st, eng._stepper
        head = run.queue[0]
        if not eng._eligible(head):
            return False
        eff = effective_prompt(head)
        hashes = run.hashes_of(head)
        if not stp.pool.lookup_blocks(hashes):
            return False
        # prefix hit: map the shared pages, skip their prefill, stream
        # the tail through decode.  A resumed preempted request lands
        # here by design — its blocks were registered at preemption, so
        # only the partial tail block recomputes.
        run.queue.pop(0)
        s = free[0]
        matched = stp.pool.match(hashes)
        npr = len(eff)
        # always leave >= 1 token to process so the first sampled token
        # has logits; a fully-cached prompt re-feeds its last token (the
        # write into the shared final page is what triggers
        # copy-on-write)
        cached = min(len(matched) * stp.page_size, npr - 1)
        for j, phys in enumerate(matched):
            stp.table[s, j] = phys
        eng._admit_bind(run, head, s, eff)
        st.hashes[s] = hashes
        st.slot_len[s] = cached
        st.fill[s] = eff[cached:]
        eng._m["prefix_hits"] += 1
        eng._m["prefix_hit_tokens"] += cached
        instrument.page_event(eng, "prefix_hit", slot=s, cached=cached)
        return True


class BucketedAdmission(_Strategy):
    def admit(self, run, free) -> bool:
        eng = self.engine
        st, stp = run.st, eng._stepper
        queue = run.queue
        paged = stp.kind == "paged"
        chunk = eng.prefill_chunk

        def admit_len(n: int) -> int:
            return min(n, chunk) if chunk else n

        # head = first *eligible* request (quota-blocked tenants are
        # skipped, not shed — they stay queued until in-flight work
        # releases their tokens); immediates (expired / shed / zero
        # budget) drain as encountered
        progress, head = False, None
        i = 0
        while i < len(queue):
            r = queue[i]
            if eng._handle_immediate(r, run.results):
                queue.pop(i)
                progress = True
                continue
            if eng._eligible(r):
                head = r
                break
            i += 1
        if head is None:
            return progress
        b = bucket_for(eng.buckets, admit_len(len(effective_prompt(head))))
        group, seen_block0 = [], set()
        # paged capacity pre-check: never bind more prompt pages than
        # the pool can produce right now (free + evictable), so the
        # reservation below can only fail under an injected fault
        pages_left = stp.pool.available() if paged else 0
        i = 0
        while i < len(queue) and len(group) < len(free):
            r = queue[i]
            if eng._handle_immediate(r, run.results):
                queue.pop(i)
                progress = True
                continue
            if not eng._eligible(r):
                i += 1
                continue
            eff = effective_prompt(r)
            al = admit_len(len(eff))
            hs = run.hashes_of(r) if paged else None
            if paged and r is not head and hs and (
                    stp.pool.lookup_blocks(hs) or hs[0] in seen_block0):
                i += 1
                continue
            if bucket_for(eng.buckets, al) != b or (
                    paged and stp.pool.pages_for(al) > pages_left):
                i += 1
                continue
            if paged:
                pages_left -= stp.pool.pages_for(al)
                if hs:
                    seen_block0.add(hs[0])
            group.append((queue.pop(i), hs, eff))
        if not group:
            return progress
        reserved = None
        if paged:
            try:
                reserved = stp.reserve_admit(
                    [stp.pool.pages_for(admit_len(len(eff)))
                     for (_, _, eff) in group])
            except PagePressure:
                # injected allocation fault mid-reservation: nothing was
                # bound — re-queue the group and let the engine relieve
                for (r, _, _) in reversed(group):
                    queue.insert(0, r)
                raise
        tokens = np.zeros((st.n, b), np.int32)
        plen = np.ones(st.n, np.int32)
        admit_mask = np.zeros(st.n, bool)
        targets = free[:len(group)]
        placed = []
        for (req, hs, eff), s in zip(group, targets):
            al = admit_len(len(eff))
            tokens[s, :al] = eff[:al]
            plen[s] = al
            admit_mask[s] = True
            eng._admit_bind(run, req, s, eff)
            st.hashes[s] = hs
            st.slot_len[s] = al
            if al < len(eff):
                # chunked admission: the rest of the prompt
                # teacher-forces through decode; no token emits until
                # the fill drains (the sampled first token below is a
                # mid-prompt continuation, discarded)
                st.fill[s] = eff[al:]
                eng._m["chunked_admissions"] += 1
                if eng.tracer is not None:
                    eng.tracer.instant("chunked_admit", cat="step",
                                       args=dict(slot=s, chunk=al,
                                                 total=len(eff)))
            placed.append((req, s))
        stp.admit_group(st, tokens, plen, admit_mask, placed, reserved)
        eng._m["prefill_batches"] += 1
        toks = np.asarray(st.slot_last)
        for req, s in placed:
            if st.fill[s] is not None:
                continue
            eng._post_admit(run, req, s, int(toks[s]))
        return True


class SingleAdmission(_Strategy):
    def admit(self, run, free) -> bool:
        eng = self.engine
        st = run.st
        progress, req = False, None
        i = 0
        while i < len(run.queue):
            cand = run.queue[i]
            if eng._handle_immediate(cand, run.results):
                run.queue.pop(i)
                progress = True
                continue
            if eng._eligible(cand):
                req = run.queue.pop(i)
                break
            i += 1
        if req is None:
            return progress
        s = free[0]
        eff = effective_prompt(req)
        eng._admit_bind(run, req, s, eff)
        st.slot_len[s] = len(eff)
        eng._stepper.admit_single(st, req, s, eff)
        eng._m["prefill_batches"] += 1
        eng._post_admit(run, req, s, int(np.asarray(st.slot_last)[s]))
        return True


class AdmissionPipeline:
    """Orders the strategies for the engine's cache kind and drains the
    queue into free slots until neither slots nor admissible requests
    remain."""

    def __init__(self, engine):
        self.engine = engine
        stp = engine._stepper
        if stp.kind == "paged":
            self.strategies = [PrefixHitAdmission(engine),
                               BucketedAdmission(engine)]
        elif engine._supports_plen:
            self.strategies = [BucketedAdmission(engine)]
        else:
            self.strategies = [SingleAdmission(engine)]

    def fill_slots(self, run):
        eng = self.engine
        while True:
            free = run.st.free()
            if not free or not run.queue:
                return
            while run.queue and eng._handle_immediate(run.queue[0],
                                                      run.results):
                run.queue.pop(0)
            if not run.queue:
                continue
            for strat in self.strategies:
                if strat.admit(run, free):
                    break
            else:
                return


class ServeRun:
    """Per-``serve()`` scope: the FIFO queue, the results dict, the
    slot table, and the prompt-hash memo (hashes are deterministic per
    request — computed once, not once per fill pass)."""

    def __init__(self, engine, requests):
        from .slots import SlotTable
        self.queue = list(requests)
        self.results: dict = {}
        self.st = SlotTable(engine.n_slots)
        self._engine = engine
        self._hash_cache: dict = {}

    def hashes_of(self, req) -> list:
        """Block hashes of the request's *effective* prompt.  The memo
        key includes the effective length: a preempted request comes
        back with its emitted tokens folded into the prompt, so its
        chain grows between admissions and a stale entry would miss the
        pages registered at preemption."""
        eff = effective_prompt(req)
        ent = self._hash_cache.get(id(req))
        if ent is None or ent[0] is not req or ent[1] != len(eff):
            ent = (req, len(eff),
                   block_hashes(eff, self._engine._stepper.page_size))
            self._hash_cache[id(req)] = ent
        return ent[2]
