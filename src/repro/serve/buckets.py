"""Prompt-length bucketing for batched prefill admission.

Prefill is jit-compiled per input shape; per-prompt-length tracing means
every new length pays a full XLA compile.  Padding prompts up to a small
fixed grid of length buckets bounds total prefill compiles by the bucket
count, independent of traffic.
"""
from __future__ import annotations

from typing import Sequence, Tuple


def default_buckets(max_len: int, min_bucket: int = 16) -> Tuple[int, ...]:
    """Doubling grid ``[min_bucket, 2*min_bucket, ..., max_len]``.

    The largest bucket is always exactly ``max_len`` so every admissible
    prompt has a bucket.
    """
    if max_len <= min_bucket:
        return (max_len,)
    out = []
    b = min_bucket
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


def bucket_for(buckets: Sequence[int], prompt_len: int) -> int:
    """Smallest bucket >= prompt_len.  Raises if the prompt doesn't fit."""
    for b in buckets:
        if prompt_len <= b:
            return b
    raise ValueError(
        f"prompt length {prompt_len} exceeds largest bucket {max(buckets)}")
