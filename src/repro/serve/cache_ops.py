"""Jitted cache-admission ops for the serve engine.

Cache trees across every model family share one batch convention: the
``len`` leaf is ``(B,)`` and every other leaf is ``(L, B, ...)`` — batch
on axis 1 (see ``init_cache`` in models/*.py).  Both ops below rely only
on that convention, so they work for dense, MoE, hymba, xlstm, and
whisper caches alike.

They replace the old engine's ``_splice_cache``: a host-side
``tree_map`` that located the batch axis by shape comparison and issued
one scatter per leaf from Python.  Here the whole tree update is a
single jitted XLA program with the slot index traced, so admission costs
one dispatch and never recompiles.

Sharded serving (DESIGN.md §13): under a mesh the engine traces these
ops with both sides of every copy laid out identically — dense caches
and page stores are sharded on the KV-head axis, scratch prefill caches
carry the same head split, and slot/page indices are replicated — so
every update below is a device-local dynamic-slice on each shard and
introduces no collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _batch_axis(leaf) -> int:
    return 0 if leaf.ndim == 1 else 1


def write_slot(batched_cache, single_cache, slot):
    """Write a batch-1 cache into slot ``slot`` of the batched cache.

    ``slot`` is a traced int32 scalar — one compile serves every slot.
    Each leaf is one ``dynamic_update_index_in_dim`` on its batch axis.
    """
    def w(b, s):
        ax = _batch_axis(b)
        row = jax.lax.index_in_dim(s.astype(b.dtype), 0, ax, keepdims=False)
        return jax.lax.dynamic_update_index_in_dim(b, row, slot, ax)

    return jax.tree_util.tree_map(w, batched_cache, single_cache)


def scatter_prefill_pages(store, scratch, slots, phys_ids):
    """Scatter the prefilled slot rows of one admission group from the
    dense scratch cache into their freshly allocated physical pages.

    ``store`` is the paged tree (leaves (L, P, KH, ps, d));
    ``scratch`` the prefill scratch (leaves (L, B, KH, S, d) with S a
    multiple of ps, plus a ``len`` leaf the page store doesn't carry).
    ``slots`` (G,) and ``phys_ids`` (G, S//ps) are traced — the whole
    group lands in one call (one store update instead of one full-store
    copy per member); retraces are bounded by the bucket grid times the
    group-size grid (both small).  Entries of ``phys_ids`` past a
    prompt's last page point at the trash page, which absorbs the
    padded tail.
    """
    def w(st, sc):
        ps = st.shape[3]
        for g in range(slots.shape[0]):
            row = jax.lax.dynamic_index_in_dim(sc, slots[g], axis=1,
                                               keepdims=False)
            for i in range(sc.shape[3] // ps):
                blk = row[:, None, :, i * ps:(i + 1) * ps]  # (L,1,KH,ps,d)
                st = jax.lax.dynamic_update_slice(
                    st, blk.astype(st.dtype), (0, phys_ids[g, i], 0, 0, 0))
        return st

    return {key: w(store[key], scratch[key]) for key in store}


def copy_page(store, src, dst):
    """Copy-on-write helper: duplicate physical page ``src`` into
    ``dst`` across every leaf of the page store (src/dst traced)."""
    def c(st):
        page = jax.lax.dynamic_slice_in_dim(st, src, 1, axis=1)
        return jax.lax.dynamic_update_slice(st, page, (0, dst, 0, 0, 0))

    return jax.tree_util.tree_map(c, store)


def truncate_slot(cache, new_lens):
    """Roll per-slot cache lengths back to ``new_lens`` (B,) int32.

    The speculative verify forward optimistically writes K+1 fresh KV
    entries per slot and advances ``len`` by K+1; after the accept step
    the engine truncates each slot to its accepted depth.  Entries past
    ``len`` are invisible to the length-masked attention, so the stale
    rejected-suffix KV needs no scrubbing — the next burst overwrites it
    in place.  Host-side per-slot lengths stay authoritative; this op
    just republishes them into the jitted cache tree.
    """
    return dict(cache, len=jnp.asarray(new_lens, jnp.int32))


def merge_slots(cache, new_cache, admit_mask):
    """Per-slot select between two same-shape caches.

    ``admit_mask`` (B,) bool: rows where it is True come from
    ``new_cache`` (the freshly prefilled scratch), others keep ``cache``
    (the live slots).  Used by bucketed batched admission, where the
    prefill batch is slot-aligned.
    """
    def m(old, new):
        ax = _batch_axis(old)
        shape = [1] * old.ndim
        shape[ax] = old.shape[ax]
        return jnp.where(admit_mask.reshape(shape), new.astype(old.dtype),
                         old)

    return jax.tree_util.tree_map(m, cache, new_cache)
