"""Draft sources for speculative decoding (DESIGN.md §12).

A *draft* proposes K cheap tokens per engine step; the target model
verifies them in one batched forward.  Two pluggable sources:

* :class:`SelfDraft` — the FAQ int8 quantization of the *target's own*
  weights.  The paper's central property (FAQ-calibrated quantized
  models track the full-precision model's future activations) is
  exactly what a draft needs for high acceptance, and the draft shares
  the target's architecture, cache layout, and KV pages: the draft
  writes its speculative K/V straight into the target cache and the
  verify pass overwrites those positions with target K/V, so the
  self-draft costs **zero extra KV memory**.  On this CPU reproduction
  the int8 reconstruction is materialized dense (``mode="fake"``) so
  draft steps run as plain fp matmuls — cheaper than the target's
  packed-int4 dequant path; a TPU deployment would keep the int8 codes
  in HBM (half the weight traffic of fp16) and run them through the
  same dequant-GEMM kernel as the serving weights.

* :class:`ModelDraft` — any smaller registry model as an independent
  draft with its own small dense KV cache.  Acceptance depends entirely
  on how well the draft tracks the target; correctness never does — the
  verify/accept rule guarantees the emitted stream is an exact sample
  from the target policy even for a random draft.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional


class _Placeable:
    """Sharded-serving hook shared by all draft sources: a tensor-parallel
    engine re-places the draft's weights on its mesh with the *same*
    logical-axis annotations as the resolved draft model, so draft burst
    steps run under the identical TP layout (and collective pattern) as
    the target's decode step."""

    def place(self, place_fn, dmodel):
        axes = (dmodel.param_axes()
                if hasattr(dmodel, "param_axes") else None)
        self.params = place_fn(self.params, axes)


@dataclasses.dataclass
class SelfDraft(_Placeable):
    """Self-draft: the target model running int8-FAQ'd target weights.

    ``model`` stays ``None`` — the runner resolves it to the engine's
    target model, and the draft shares the target's dense cache or
    paged KV store (speculative writes are overwritten by verify).
    """
    params: Any
    bits: int = 8
    shares_cache = True
    model = None


@dataclasses.dataclass
class ModelDraft(_Placeable):
    """Independent draft model with its own dense KV cache."""
    model: Any
    params: Any
    shares_cache = False


def _materialize(qt):
    """Dense original-domain reconstruction of one QuantizedTensor leaf.

    Param-tree leaves carry stacked leading axes (layers, experts); the
    2-D dequant vmaps over them.  ``act_scale`` is folded back in
    (``(x/s) @ deq(codes)  ==  x @ (deq(codes) / s[:, None])``), so the
    result is the exact weight the serving dequant-matmul realizes.
    """
    import jax

    from repro.core.quantizer import QuantizedTensor, dequantize_groupwise

    def deq2(codes, scale, zero, act):
        sub = QuantizedTensor(codes=codes, scale=scale, zero=zero,
                              spec=qt.spec, n_in=qt.n_in, packed=qt.packed,
                              act_scale=None)
        w = dequantize_groupwise(sub)
        if act is not None:
            w = w / act[:, None]
        return w

    lead = qt.codes.ndim - 2
    if qt.act_scale is None:
        fn = lambda c, s, z: deq2(c, s, z, None)
        for _ in range(lead):
            fn = jax.vmap(fn)
        return fn(qt.codes, qt.scale, qt.zero)
    fn = deq2
    for _ in range(lead):
        fn = jax.vmap(fn)
    return fn(qt.codes, qt.scale, qt.zero, qt.act_scale)


def self_int8_draft(model, params, stats=None, *, bits: int = 8,
                    group_size: int = 64) -> SelfDraft:
    """Build the FAQ int8 self-draft from the target's weights.

    ``params`` may be the fp weights *or* the packed serving tree —
    QuantizedTensor leaves are first materialized to the exact weights
    the serving dequant-matmul realizes, so the draft is the int8
    quantization of **the model being served** (derived purely from the
    codes that already exist at serve time): its greedy argmaxes track
    the target's almost everywhere, which is what acceptance rate pays
    for.  ``stats`` are the same calibration statistics used to
    quantize the serving weights (FAQ's future-activation preview);
    without them the draft falls back to plain RTN int8.  The
    reconstruction is materialized dense (``mode="fake"``) — numerically
    it *is* the int8 model; see the module docstring for the storage
    story.
    """
    import jax

    from repro.core import QuantSpec, quantize_model
    from repro.core.quantizer import QuantizedTensor

    is_qt = lambda x: isinstance(x, QuantizedTensor)
    params = jax.tree_util.tree_map(
        lambda x: _materialize(x) if is_qt(x) else x, params, is_leaf=is_qt)
    method = "faq" if stats is not None else "rtn"
    qp, _ = quantize_model(params, model.quant_site_map(), stats,
                           method=method,
                           spec=QuantSpec(bits=bits, group_size=group_size),
                           mode="fake")
    return SelfDraft(params=qp, bits=bits)


def registry_draft(arch: str, *, tiny: bool = True, seed: int = 0,
                   params: Optional[Any] = None) -> ModelDraft:
    """Build an independent draft from a registry architecture name.

    With ``params=None`` the draft is randomly initialized — useful as
    plumbing (greedy output is still exactly the target's; acceptance
    is just poor), real deployments pass trained/distilled weights.
    """
    import jax

    from repro.configs import ARCHS
    from repro.models.registry import build_model

    cfg = ARCHS[arch].tiny() if tiny else ARCHS[arch]
    model = build_model(cfg)
    if not getattr(model, "supports_spec", lambda: False)():
        raise ValueError(
            f"draft arch {arch!r} ({cfg.family}) lacks the span-write "
            "decode path speculative drafting needs")
    if params is None:
        params = model.init(jax.random.PRNGKey(seed))
    return ModelDraft(model=model, params=params)
