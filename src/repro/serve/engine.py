"""Bucketed continuous-batching engine over FAQ-quantized weights.

Slot-based continuous batching: bucketed batched prefill (admission
compiles at most once per length bucket), a jitted on-device batched
sampler fused with the decode step (one int32 transferred per slot per
step), and inactive-slot masking inside the jitted decode wrapper so a
draining batch can never advance a dead slot's cache length past
``max_len``.

The engine itself is a thin orchestrator over three composable parts
(DESIGN.md §14): the :class:`.slots.SlotTable` (host-side slot state),
an :class:`.admission.AdmissionPipeline` (bucketed / paged prefix-hit /
single-request admission strategies), and a :mod:`.stepper` (the jitted
prefill/decode/spec cores per cache kind).  Dense and paged serving run
the *same* ``serve()`` loop — the cache kind only changes which stepper
is plugged in.

**Chunked prefill** (``prefill_chunk``, default ``"auto"``): a prompt
longer than the chunk is admitted as its first chunk through one
bucket-sized batched prefill; the remainder teacher-forces through the
batched decode step, one token per step, interleaved with every other
slot's decoding — a long admission can never stall the decode batch for
more than one chunk.  ``"auto"`` picks the second-largest bucket;
``0``/``None`` restores monolithic prefill.  Greedy outputs are
token-for-token identical either way (teacher-forced decode writes the
same KV as prefill at the same positions).

The weights are the *packed* QuantizedTensor representation — every
matmul runs through the dequant-matmul kernel path, i.e. the paper's
deployment format is the first-class serving path, not a simulation.
Models whose ``prefill`` does not accept ``prompt_len`` (hymba's ring
buffer, recurrent xlstm) fall back to per-request exact-length prefill
through :func:`.cache_ops.write_slot` — only the compile-per-length
cost remains.  ``paged=True`` swaps in the page-pool stepper with
shared-prefix reuse (:mod:`.pages`, DESIGN.md §10); ``spec=SpecConfig``
turns decode steps into speculative draft+verify cycles (:mod:`.spec`,
DESIGN.md §12) with greedy output unchanged.

``clock=`` injects the deadline clock (default ``time.time``) — one
seam for EDF-expiry tests and the open-loop traffic harness
(:mod:`.loadgen`) instead of per-test monkeypatching.  ``serve()`` also
accepts a ``feed`` (an :class:`.loadgen.ArrivalFeed` or anything with
``poll``/``pending``/``next_time``): requests are then admitted as
their arrival times pass instead of all up front.
"""
from __future__ import annotations

import inspect
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import (SERVE_DECODE_RULES, SERVE_PREFILL_RULES,
                                 axis_rules, shard_hint, tree_hint,
                                 tree_shardings)
from repro.obs import MetricsRegistry
from . import instrument
from .admission import AdmissionPipeline, ServeRun
from .buckets import bucket_for, default_buckets
from .cache_ops import truncate_slot
from .overload import (SLOAdmission, never_admissible, pick_victim,
                       preempt_slot, relieve_pressure, shed_request)
from .pages import PagePressure
from .sampler import policy_in_use, sample_tokens
from .slots import Request, SlotTable, TraceCounter, empty_tokens
from .stepper import DenseStepper, PagedStepper

__all__ = ["Request", "ServeEngine", "TraceCounter"]


def _empty() -> np.ndarray:
    return empty_tokens()


class ServeEngine:
    def __init__(self, model, params, *, n_slots: int = 4,
                 max_len: int = 512, buckets=None, rng_seed: int = 0,
                 paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None, spec=None, mesh=None,
                 prefill_chunk="auto", clock=None, slo=None, faults=None,
                 tracer=None, registry=None, profile: bool = False):
        self.model = model
        self.mesh = mesh
        self.clock = clock if clock is not None else time.time  # repro: noqa[RPR006] the seam's own wall-clock default
        # observability (DESIGN.md §17): one registry for every
        # component's counters; an optional span tracer whose clock is
        # re-pointed at the engine's seam (fake-clock determinism).
        # Must exist before the stepper/spec/overload components so
        # their groups land in it.
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer
        if tracer is not None:
            tracer.clock = self.clock
        self._profile = bool(profile)
        # overload seams (DESIGN.md §16): slo is an SLOConfig or
        # SLOAdmission (shed gate + tenant quotas), faults a
        # FaultInjector consulted by the pool and the serve loop.  Both
        # must bind before the stepper so the page pool sees them.
        self.faults = faults
        self.slo = (slo if slo is None or isinstance(slo, SLOAdmission)
                    else SLOAdmission(slo))
        if self.faults is not None:
            self.faults.counts.rebind(self.registry)
        if self.slo is not None:
            self.slo.bind_registry(self.registry)
        # serve-time sharding (DESIGN.md §13): with a mesh, weights are
        # laid out tensor-parallel once at admission-to-engine time —
        # QuantizedTensor codes *and* scales split on the same logical
        # axes — and every jitted entry point traces under its regime's
        # rule table (prefill vs decode).  mesh=None is the single-device
        # fast path: every placement/hint helper below degrades to
        # identity and the engine behaves exactly as before.
        self._cache_axes = (model.cache_axes()
                            if hasattr(model, "cache_axes") else None)
        self.params = self._place(params, model.param_axes()
                                  if hasattr(model, "param_axes") else None)
        self.n_slots = n_slots
        self.max_len = max_len
        self.cfg = model.cfg
        if buckets is None:
            self.buckets = default_buckets(max_len)
        else:
            # the largest bucket is always exactly max_len so every
            # admissible prompt has a bucket (same invariant as
            # default_buckets)
            self.buckets = tuple(sorted({min(int(b), max_len)
                                         for b in buckets} | {max_len}))
        self._supports_plen = (
            "prompt_len" in inspect.signature(model.prefill).parameters)
        probe = getattr(model, "supports_paged", None)
        self.paged = bool(paged and self._supports_plen
                          and probe is not None and probe())
        self._key = jax.random.PRNGKey(rng_seed)
        self._rng_step = 0

        # chunked prefill: "auto" = second-largest bucket (disabled when
        # the grid has one bucket — nothing to chunk to); 0/None =
        # monolithic; an explicit chunk rounds *up* to the bucket grid so
        # chunking never adds a compile beyond the existing buckets.
        # Requires prompt_len prefill (the fallback path admits exact
        # lengths and cannot teacher-force through the batched step).
        if not self._supports_plen or not prefill_chunk:
            self.prefill_chunk = None
        elif prefill_chunk == "auto":
            self.prefill_chunk = (self.buckets[-2]
                                  if len(self.buckets) > 1 else None)
        else:
            self.prefill_chunk = bucket_for(self.buckets,
                                            int(prefill_chunk))

        # the stepper owns the jitted entry points and device cache
        # state; TraceCounter-wrapped so metrics() reports "*_traces"
        self._stepper = (PagedStepper(self, page_size, n_pages)
                         if self.paged else DenseStepper(self))
        self._sample = self._jit(sample_tokens, SERVE_DECODE_RULES)

        # speculative decoding (DESIGN.md §12): spec is a SpecConfig with
        # a draft source; models without the span-write decode path fall
        # back to plain decode
        self._spec = None
        probe_spec = getattr(model, "supports_spec", None)
        if spec is not None and probe_spec is not None and probe_spec():
            from .spec import SpecRunner
            self._spec = SpecRunner(self, spec)
            self._truncate = self._jit(truncate_slot, SERVE_DECODE_RULES)

        self._admission = AdmissionPipeline(self)
        self._m = self.registry.group("serve").init(
            tokens_generated=0, decode_steps=0, prefill_batches=0,
            admitted=0, completed=0, expired=0, truncated=0,
            prefix_hits=0, prefix_hit_tokens=0, fill_steps=0,
            chunked_admissions=0, serve_time_s=0.0,
            shed=0, shed_retried=0, preempted=0, resumed=0,
            pressure_events=0)
        self._stall_spins = 0
        self._hold_fill = False      # one-iteration admission hold after
                                     # a pressure-relieving preemption
        self._req_stats: dict = {}   # rid -> dict(tokens=..., steps=...)

    # -- stepper state (back-compat attribute surface) -----------------------
    @property
    def _prefill1(self):
        return self._stepper._prefill1

    @property
    def _prefill_admit(self):
        return self._stepper._prefill_admit

    @property
    def _admit_one(self):
        return self._stepper._admit_one

    @property
    def _decode(self):
        return self._stepper._decode

    def _paged_stepper(self) -> PagedStepper:
        if not self.paged:
            raise AttributeError("dense engine has no paged state")
        return self._stepper

    @property
    def pool(self):
        return self._paged_stepper().pool

    @property
    def _store(self):
        return self._paged_stepper().store

    @property
    def page_size(self):
        return self._paged_stepper().page_size

    @property
    def pages_per_slot(self):
        return self._paged_stepper().pages_per_slot

    @property
    def n_pages(self):
        return self._paged_stepper().n_pages

    @property
    def _prefill_paged(self):
        return self._paged_stepper()._prefill_paged

    @property
    def _decode_paged(self):
        return self._paged_stepper()._decode_paged

    # -- mesh plumbing -------------------------------------------------------
    def _jit(self, fn, rules):
        """jit ``fn``; with a mesh, every call (so also the trace) runs
        under ``axis_rules(mesh, rules)``.  The raw jitted callable stays
        reachable as ``.jitted`` (lowering/compile introspection)."""
        jf = jax.jit(fn)  # repro: noqa[RPR001] this IS the seam every other serve jit routes through
        if self.mesh is None:
            return jf

        def wrapped(*args):
            with axis_rules(self.mesh, rules):
                return jf(*args)

        wrapped.jitted = jf
        return wrapped

    def _place(self, tree, axes_tree):
        """Place a param/cache tree onto the mesh per its logical-axis
        annotations (identity without a mesh or annotations)."""
        if self.mesh is None or axes_tree is None or tree is None:
            return tree
        return jax.device_put(
            tree, tree_shardings(self.mesh, tree, axes_tree,
                                 rules=SERVE_DECODE_RULES))

    def _hint_cache(self, cache):
        """Pin a dense cache tree to its canonical layout inside a jitted
        body — keeps the steady-state decode layout stable step to step."""
        if self.mesh is None or self._cache_axes is None:
            return cache
        return tree_hint(cache, self._cache_axes)

    @staticmethod
    def _gathered(step_logits):
        """Replicate one step's (B, V) logits before sampling.  The
        projection leaves them vocab-sharded (logits_from_hidden's hint);
        this second constraint is the decode step's single all-gather —
        argmax/sampling then runs replicated with no further collectives.
        Identity without an active mesh."""
        return shard_hint(step_logits, "batch", None)

    # -- helpers -------------------------------------------------------------
    def _next_key(self):
        self._rng_step += 1
        return jax.random.fold_in(self._key, self._rng_step)

    @staticmethod
    def _policy_args(temps, top_k, top_p):
        """Device policy args for the jitted bodies, with top-k/top-p
        dropped to ``None`` when no slot in the batch uses them — the
        full-vocab sort/argsort behind those masks would otherwise run
        every decode step (None vs array is a different jit signature,
        so each variant compiles once).  The in-use predicates are
        shared with the speculative cycle (:func:`.sampler.policy_in_use`)."""
        use_tk, use_tp = policy_in_use(top_k, top_p)
        tk = jnp.asarray(top_k, jnp.int32) if use_tk else None
        tp = jnp.asarray(top_p, jnp.float32) if use_tp else None
        return jnp.asarray(temps, jnp.float32), tk, tp

    def _check_prompt(self, req: Request) -> int:
        n = int(np.asarray(req.prompt).shape[0])
        if n < 1:
            raise ValueError(f"req {req.rid}: empty prompt")
        limit = self.buckets[-1] if self._supports_plen else self.max_len
        if n > limit:
            raise ValueError(
                f"req {req.rid}: prompt length {n} exceeds {limit}")
        return n

    # -- single-request path -------------------------------------------------
    def generate(self, request: Request) -> np.ndarray:
        """Single-request generate (tests / quickstart): exact-length
        batch-1 prefill + batch-1 decode through the same jitted sampler
        ops as the batched path."""
        self._check_prompt(request)
        if request.max_new_tokens <= 0:
            return _empty()
        t0 = self.clock()
        cache = self._place(self.model.init_cache(1, self.max_len),
                            self._cache_axes)
        tok = jnp.asarray(np.asarray(request.prompt, np.int32))[None]
        logits, cache = self._prefill1(self.params, tok, cache)
        temps, top_k, top_p = self._policy_args(
            [request.temperature], [request.top_k], [request.top_p])
        active = jnp.ones((1,), bool)
        nxt = self._sample(logits[:, 0], temps, top_k, self._next_key(),
                           top_p)
        out = [int(nxt[0])]
        n_steps = min(request.max_new_tokens - 1,
                      self.max_len - len(request.prompt))
        for _ in range(n_steps):
            nxt, cache = self._decode(self.params, cache, nxt, active,
                                      temps, top_k, top_p,
                                      self._next_key())
            self._m["decode_steps"] += 1
            out.append(int(nxt[0]))
        self._m["tokens_generated"] += len(out)
        self._m["serve_time_s"] += self.clock() - t0
        return np.asarray(out, np.int32)

    # -- per-request accounting ----------------------------------------------
    def _settle(self, req: Request, results: dict, out, counter: str):
        """Record a request's terminal outcome without a slot."""
        req.outcome = counter
        results[req.rid] = out
        self._m[counter] += 1
        instrument.settled(self, req, counter)
        if req.on_finish:
            req.on_finish(req.rid, out)

    def _handle_immediate(self, req: Request, results: dict) -> bool:
        """True if the request completes without ever taking a slot.
        A deadline exactly at the admission instant still admits (the
        cutoff is strict ``>``).  A resumed preempted request that
        expires while re-queued keeps the tokens it already produced
        (truncated, not expired).  The SLO shed gate runs last: fresh
        requests whose deadline the queue-delay estimate says cannot be
        met are rejected before they waste a slot."""
        if req.deadline is not None and self.clock() > req.deadline:
            out = (np.asarray(req.out_tokens, np.int32)
                   if req.resume and req.out_tokens else _empty())
            self._settle(req, results,
                         out, "truncated" if len(out) else "expired")
            return True
        if req.max_new_tokens <= 0:
            self._settle(req, results, _empty(), "completed")
            return True
        if self.slo is not None and not req.resume \
                and self.slo.should_shed(req, self.clock()):
            shed_request(self, req, results)
            return True
        return False

    def _eligible(self, req: Request) -> bool:
        """Admissible right now (tenant under its in-flight quota)."""
        return self.slo is None or self.slo.quota_ok(req)

    def _emit(self, req: Request, tok: int):
        if req.t_first is None:
            instrument.first_token(self, req)
        req.out_tokens.append(tok)
        self._m["tokens_generated"] += 1
        self._req_stats.setdefault(
            req.rid, dict(tokens=0, steps=0))["tokens"] += 1
        if req.on_token:
            req.on_token(req.rid, tok)

    def _count_step(self, rid: int):
        """One engine step (prefill, decode step, or spec cycle) in
        which request ``rid`` occupied a live slot — the denominator of
        its ``tokens_per_step``."""
        self._req_stats.setdefault(
            rid, dict(tokens=0, steps=0))["steps"] += 1

    def request_summary(self) -> dict:
        """Per-request ``tokens_per_step`` (tokens emitted per engine
        step while resident; > 1 only with speculative bursts)."""
        return {rid: s["tokens"] / max(s["steps"], 1)
                for rid, s in self._req_stats.items()}

    def _admit_bind(self, run: ServeRun, req: Request, s: int, eff=None):
        """Bind + engine-level admission accounting (shared by every
        admission strategy).  ``eff`` is the effective prompt — prompt
        plus already-emitted tokens for a resumed preemptee.  Admission
        is where the SLO layer observes queue delay (arrival to bind,
        the same quantity the traffic percentiles report) and charges
        the tenant's in-flight quota."""
        if self.slo is not None:
            self.slo.acquire(req)
            if req.arrival is not None:
                self.slo.observe(self.clock() - req.arrival)
        if req.resume:
            self._m["resumed"] += 1
        run.st.bind(req, s)
        instrument.bound(self, req, s)
        req.resume = False
        self._m["admitted"] += 1
        self._req_stats.setdefault(req.rid, dict(tokens=0, steps=0))
        if self._spec is not None:
            self._spec.admit_slot(s, req.prompt if eff is None else eff)
        if req.on_admit:
            req.on_admit(req.rid)

    def _post_admit(self, run: ServeRun, req: Request, s: int, tok: int):
        """First-token emission for a fully-prefilled admission (chunked
        admissions emit nothing until their fill drains)."""
        self._count_step(req.rid)
        self._emit(req, tok)
        self._finish_checks(run, req, s, None)

    def _finish(self, run: ServeRun, s: int, counter: str = "completed"):
        st = run.st
        req = st.req[s]
        out = np.asarray(req.out_tokens, np.int32)
        run.results[req.rid] = out
        req.outcome = counter
        self._m[counter] += 1
        instrument.retired(self, req, counter)
        if self.slo is not None:
            self.slo.release(req)
        st.clear(s)
        self._stepper.retire(st, s)
        if req.on_finish:
            req.on_finish(req.rid, out)

    def _finish_checks(self, run: ServeRun, req: Request, s: int, now):
        if len(req.out_tokens) >= req.max_new_tokens:
            self._finish(run, s)
        elif now is not None and req.deadline is not None \
                and now > req.deadline:
            self._finish(run, s, counter="truncated")
        elif run.st.slot_len[s] >= self.max_len:
            self._finish(run, s, counter="truncated")

    # -- unified continuous-batching loop ------------------------------------
    def serve(self, requests: List[Request] = (), *, feed=None) -> dict:
        """Run requests to completion with slot-based batching.

        Returns {rid: np.ndarray of generated tokens}.  Requests with
        ``max_new_tokens=0`` complete immediately with an empty sequence;
        requests whose ``deadline`` already passed at admission expire
        with an empty sequence; a running request whose deadline passes
        mid-decode is truncated at the tokens produced so far.

        One loop serves both cache kinds: the dense block and the paged
        pool differ only in the stepper plugged into the engine.  With
        ``feed`` (open-loop traffic), arrivals whose time has passed are
        polled into the queue every iteration and the loop idles —
        without busy-spinning the decode step — until the feed drains.

        Page exhaustion never escapes this loop: a step (or an
        injected-fault admission reservation) raising
        :class:`.pages.PagePressure` is relieved by preempting the
        latest-deadline slot and retrying — throughput degrades, the
        loop does not die (DESIGN.md §16).
        """
        self._req_stats = {}         # per-serve scope (no unbounded growth)
        t0 = self.clock()
        for r in requests:
            self._check_prompt(r)
            instrument.enqueued(self, r)
        run = ServeRun(self, requests)
        st = run.st
        self._stepper.begin()

        while True:
            if self.faults is not None:
                self._fault_tick(run)
            if feed is not None:
                for r in feed.poll(self.clock()):
                    self._check_prompt(r)
                    instrument.enqueued(self, r)
                    run.queue.append(r)
            try:
                # a pressure-relieving preemption holds admission for one
                # iteration: the retried step gets first claim on the
                # freed pages (otherwise the loop would re-admit the
                # victim right back into the same shortage — a livelock,
                # not backpressure)
                hold_fill, self._hold_fill = self._hold_fill, False
                if run.queue and st.free() and not hold_fill:
                    with instrument.step_span(self, "admit"):
                        self._admission.fill_slots(run)
                if not st.any_active():
                    waiting = feed is not None and feed.pending()
                    if run.queue and self._stall_shed(run, waiting):
                        continue
                    if waiting:
                        self._idle_wait(feed)
                        continue
                    if run.queue:
                        continue    # immediates drained; re-admit
                    break
                k_eff = self._spec_k(st.slot_len, st.active, st.req,
                                     filling=st.filling())
                if k_eff >= 1:
                    self._spec_step(run, k_eff)
                else:
                    self._plain_step(run)
            except PagePressure as pp:
                instrument.page_event(self, "page_pressure", slot=pp.slot)
                self._hold_fill = relieve_pressure(self, run, pp)
        self._m["serve_time_s"] += self.clock() - t0
        return run.results

    def _fault_tick(self, run: ServeRun):
        """Consume this iteration's injected faults: scheduled stalls
        burn through the injector's ``advance``; a scheduled forced
        preemption evicts the normal victim (exercising preempt/resume
        even without page pressure, dense included)."""
        self.faults.on_loop()
        if self.faults.take_preempt():
            victim = pick_victim(run.st)
            if victim is not None:
                self.faults.count_preempt()
                preempt_slot(self, run, victim)

    def _stall_shed(self, run: ServeRun, waiting: bool) -> bool:
        """No slot active but the queue is non-empty: with every quota
        free and the pool at its emptiest, a head that still cannot
        bind never will — shed it terminally.  A bounded spin backstop
        catches anything else (pathological fault schedules) unless
        arrivals are still pending (``waiting`` — idling is then the
        correct behavior, not a stall)."""
        head = run.queue[0]
        stuck = never_admissible(self, head)
        self._stall_spins = 0 if stuck or waiting else self._stall_spins + 1
        if stuck is None and self._stall_spins < 4096:
            return False
        self._stall_spins = 0
        shed_request(self, run.queue.pop(0), run.results, terminal=True)
        return True

    def _idle_wait(self, feed):
        """No active slots but arrivals still pending: sleep (real time,
        capped small so fake clocks can't wedge the loop) until the next
        scheduled arrival."""
        nxt = feed.next_time()
        if nxt is None:
            time.sleep(2e-4)
            return
        time.sleep(min(max(nxt - self.clock(), 0.0), 5e-3))

    def _plain_step(self, run: ServeRun):
        """One masked decode step + shared post-step bookkeeping
        (teacher-forced fill consumption, emission, finish checks)."""
        st = run.st
        with instrument.step_span(self, "decode_step"):
            self._stepper.plain_step(st)
            with instrument.step_span(self, "sampler_sync"):
                toks = np.asarray(st.slot_last)  # repro: noqa[RPR002] the designed per-step budget: one int32 per slot for emission
        self._m["decode_steps"] += 1
        now = self.clock()
        for s in range(self.n_slots):
            req = st.req[s]
            if req is None or not st.active[s]:
                continue
            self._count_step(req.rid)
            st.slot_len[s] += 1
            assert st.slot_len[s] <= self.max_len, \
                f"slot {s}: cache len {st.slot_len[s]} > max_len"
            if st.fill[s] is not None:
                self._m["fill_steps"] += 1
                st.fill[s] = st.fill[s][1:]
                if len(st.fill[s]):
                    if req.deadline is not None and now > req.deadline:
                        self._finish(run, s, counter="truncated")
                    continue        # still prefilling this slot
                # fill done: this step consumed the last prompt token,
                # so the sampled token is the first output
                st.fill[s] = None
                self._stepper.fill_done(st, s)
                instrument.fill_done(self, req)
            self._emit(req, int(toks[s]))
            self._finish_checks(run, req, s, now)

    def _spec_step(self, run: ServeRun, k_eff: int):
        """One speculative draft+verify burst + shared emission loop;
        rejected suffixes roll back through the stepper hooks."""
        st = run.st
        with instrument.step_span(self, "spec_cycle", k=k_eff) as sa:
            out, n_acc = self._stepper.spec_cycle(st, k_eff)
            sa["accepted"] = int(n_acc.sum())
            with instrument.step_span(self, "sampler_sync"):
                last_np = np.asarray(st.slot_last).copy()  # repro: noqa[RPR002] burst emission rewrites slot_last on host; k+1 int32 per slot
        self._m["decode_steps"] += 1
        now = self.clock()
        for s in range(self.n_slots):
            req = st.req[s]
            if req is None or not st.active[s]:
                continue
            self._count_step(req.rid)
            consumed = 0
            for i in range(int(n_acc[s]) + 1):
                consumed = i + 1
                st.slot_len[s] += 1
                assert st.slot_len[s] <= self.max_len, \
                    f"slot {s}: cache len {st.slot_len[s]} > max_len"
                last_np[s] = int(out[s, i])
                self._emit(req, int(out[s, i]))
                if len(req.out_tokens) >= req.max_new_tokens:
                    self._finish(run, s)
                    break
                elif req.deadline is not None and now > req.deadline:
                    self._finish(run, s, counter="truncated")
                    break
                elif st.slot_len[s] >= self.max_len:
                    self._finish(run, s, counter="truncated")
                    break
            # draft proposals that reached the output (position n_acc is
            # the correction/bonus, not a proposal)
            self._spec.m["emitted_draft_tokens"] += \
                min(consumed, int(n_acc[s]))
            if st.active[s]:
                self._stepper.post_spec_slot(st, s)
        st.slot_last = jnp.asarray(last_np)
        self._stepper.spec_rollback(st)

    def _spec_k(self, slot_len, active, slot_req, filling=()) -> int:
        """Draft depth for this iteration: the configured k shrunk to
        (a) the tightest active slot's remaining cache room (a cycle
        writes k+1 fresh positions per slot) and (b) the *largest*
        remaining token budget across active slots — when every slot is
        near its ``max_new_tokens`` a full-depth burst would be paid
        for and thrown away, so the depth tracks what can still be
        emitted (slots below the max just drop their surplus, which is
        cheap).  0 means "run a plain decode step" — near-capacity
        slots and prompt-filling slots (chunked or prefix-hit) keep the
        exact truncation semantics of non-speculative serving."""
        if self._spec is None or any(filling):
            return 0
        room = min(self.max_len - int(slot_len[s])
                   for s in range(self.n_slots) if active[s])
        budget = max(slot_req[s].max_new_tokens - len(slot_req[s].out_tokens)
                     for s in range(self.n_slots) if active[s])
        return max(0, min(self._spec.cfg.k, room - 1, budget - 1))

    # -- observability -------------------------------------------------------
    def metrics(self) -> dict:
        """Counter snapshot: throughput, prefill/decode call and trace
        counts, the retrace count (compiles beyond the first per jitted
        entry point — bounded by len(buckets)-1 for the bucketed
        prefill) plus its per-entry breakdown (``retrace_by_entry``).
        Assembled by :func:`.instrument.collect_metrics` from the
        registry-backed groups; the key surface is frozen
        (tests/test_obs.py)."""
        return instrument.collect_metrics(self)

    def export_trace(self, path) -> str:
        """Write this engine's span trace as Chrome/Perfetto
        trace_event JSON (requires ``tracer=`` at construction)."""
        return instrument.export_trace(self, path)

    def page_bytes(self) -> int:
        """Device bytes of one physical KV page (every leaf, all
        layers)."""
        if not self.paged:
            return 0
        return sum(leaf.nbytes // leaf.shape[1]
                   for leaf in self._store.values())
