"""Batched serving engine over FAQ-quantized weights.

Slot-based continuous batching: a fixed decode batch of ``n_slots``; new
requests prefill into free slots (prefill is per-request, decode is
batched).  The weights are the *packed* QuantizedTensor representation —
every matmul runs through the dequant-matmul kernel path (``qlinear``
dispatch), i.e. the paper's deployment format is the first-class serving
path, not a simulation.

This engine intentionally keeps orchestration in Python (jitted prefill /
decode_step inner loops) — the same structure used by production JAX
servers; on TPU the jitted steps dominate and Python overhead hides under
the device queue.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (T,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 => greedy
    out_tokens: Optional[list] = None


class ServeEngine:
    def __init__(self, model, params, *, n_slots: int = 4,
                 max_len: int = 512, rng_seed: int = 0):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cfg = model.cfg
        self._rng = np.random.Generator(np.random.PCG64(rng_seed))

        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        # slot-state: per-slot cache is a full-batch cache of batch=1 each
        self._caches: List = [None] * n_slots
        self._active: List[Optional[Request]] = [None] * n_slots
        self._tokens_done = 0

    # -- single-request path -------------------------------------------------
    def _sample(self, logits: jax.Array, temperature: float) -> int:
        v = self.cfg.vocab_size
        logits = np.asarray(logits[0, 0, :v], np.float64)
        if temperature <= 0:
            return int(np.argmax(logits))
        logits = logits / temperature
        p = np.exp(logits - logits.max())
        p /= p.sum()
        return int(self._rng.choice(v, p=p))

    def generate(self, request: Request) -> np.ndarray:
        """Single-request generate (used by tests and the quickstart)."""
        cache = self.model.init_cache(1, self.max_len)
        tok = jnp.asarray(request.prompt, jnp.int32)[None]
        logits, cache = self._prefill(self.params, tok, cache)
        out = []
        nxt = self._sample(logits, request.temperature)
        out.append(nxt)
        for _ in range(request.max_new_tokens - 1):
            logits, cache = self._decode(
                self.params, cache, jnp.asarray([[nxt]], jnp.int32))
            nxt = self._sample(logits, request.temperature)
            out.append(nxt)
        self._tokens_done += len(out)
        return np.asarray(out, np.int32)

    # -- batched continuous path ----------------------------------------------
    def serve(self, requests: List[Request]) -> dict:
        """Run all requests to completion with slot-based batching.

        Returns {rid: np.ndarray of generated tokens}."""
        queue = list(requests)
        results = {}
        # batched cache: one cache with batch = n_slots
        cache = self.model.init_cache(self.n_slots, self.max_len)
        # per-slot state kept host-side
        slot_req: List[Optional[Request]] = [None] * self.n_slots
        slot_last = np.zeros((self.n_slots, 1), np.int32)
        slot_left = np.zeros(self.n_slots, np.int32)

        def fill_slots():
            for s in range(self.n_slots):
                if slot_req[s] is None and queue:
                    req = queue.pop(0)
                    req.out_tokens = []
                    # per-request prefill into a batch-1 cache, then splice
                    c1 = self.model.init_cache(1, self.max_len)
                    tok = jnp.asarray(req.prompt, jnp.int32)[None]
                    logits, c1 = self._prefill(self.params, tok, c1)
                    _splice_cache(cache, c1, s)
                    nxt = self._sample(logits, req.temperature)
                    req.out_tokens.append(nxt)
                    slot_req[s] = req
                    slot_last[s, 0] = nxt
                    slot_left[s] = req.max_new_tokens - 1

        fill_slots()
        while any(r is not None for r in slot_req):
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(slot_last))
            logits_np = np.asarray(logits[:, 0, :self.cfg.vocab_size])
            for s in range(self.n_slots):
                req = slot_req[s]
                if req is None:
                    continue
                row = logits_np[s]
                if req.temperature <= 0:
                    nxt = int(np.argmax(row))
                else:
                    p = np.exp((row - row.max()) / req.temperature)
                    p /= p.sum()
                    nxt = int(self._rng.choice(self.cfg.vocab_size, p=p))
                req.out_tokens.append(nxt)
                slot_last[s, 0] = nxt
                slot_left[s] -= 1
                if slot_left[s] <= 0:
                    results[req.rid] = np.asarray(req.out_tokens, np.int32)
                    self._tokens_done += len(req.out_tokens)
                    slot_req[s] = None
            fill_slots()
        return results


def _splice_cache(batched_cache, single_cache, slot: int):
    """Copy a batch-1 cache into slot ``slot`` of the batched cache.

    The batch axis differs per leaf family — KV caches are (L, B, ...),
    per-slot lengths are (B,) — so it is located generically as the first
    axis where the batched and single shapes disagree."""
    def splice(b, s):
        if b.shape == s.shape:
            return s  # fully replicated leaf (none today, future-proof)
        for ax in range(b.ndim):
            if ax < s.ndim and b.shape[ax] != s.shape[ax]:
                idx = [slice(None)] * b.ndim
                idx[ax] = slice(slot, slot + 1)
                return b.at[tuple(idx)].set(s.astype(b.dtype))
        raise ValueError(f"cannot locate batch axis: {b.shape} vs {s.shape}")

    new = jax.tree_util.tree_map(splice, batched_cache, single_cache)
    # mutate the caller's dict in place (cache trees are dicts at top level)
    for k in batched_cache:
        batched_cache[k] = new[k]
