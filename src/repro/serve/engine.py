"""Bucketed continuous-batching engine over FAQ-quantized weights.

Slot-based continuous batching with three hot-path properties:

* **Bucketed batched prefill** — waiting requests are padded to a small
  fixed grid of length buckets (:mod:`.buckets`) and prefilled together
  in one slot-aligned batch with per-row ``prompt_len``; admission
  compiles at most once per bucket instead of once per distinct prompt
  length, and the prefilled rows land in the live decode cache through a
  single jitted merge (:func:`.cache_ops.merge_slots`).
* **On-device sampling** — a jitted batched sampler
  (:func:`.sampler.sample_tokens`, greedy/temperature/top-k keyed by
  per-slot temperature) runs fused with the decode step, so each step
  transfers one int32 per slot instead of a vocab-size logits row.
* **Inactive-slot masking** — finished/empty slots are frozen inside the
  jitted decode wrapper (``len`` restored, sampled token suppressed), so
  a draining batch can never advance a dead slot's cache length past
  ``max_len`` and corrupt its last cache position.

The weights are the *packed* QuantizedTensor representation — every
matmul runs through the dequant-matmul kernel path (``qlinear``
dispatch), i.e. the paper's deployment format is the first-class serving
path, not a simulation.  Orchestration stays in Python (jitted
prefill/decode inner loops) — on TPU the jitted steps dominate and
Python overhead hides under the device queue.

Models whose ``prefill`` does not accept ``prompt_len`` (hymba's ring
buffer, recurrent xlstm) fall back to per-request exact-length prefill
admitted through the jitted per-slot :func:`.cache_ops.write_slot` op —
correctness fixes apply there too, only the compile-per-length cost
remains.

``paged=True`` switches the persistent cache from one dense
``(n_slots, max_len)`` block to a pool of fixed-size pages with
per-slot page tables and shared-prefix reuse (:mod:`.pages`,
DESIGN.md §10); the dense path remains the default and the fallback
for models whose cache layout doesn't support paging.

``spec=SpecConfig(k=..., draft=...)`` turns each decode step into a
speculative cycle (:mod:`.spec`, DESIGN.md §12): the draft proposes
``k`` tokens, the target verifies all ``k+1`` positions in one span
forward, and the jitted accept/resample rule keeps greedy output
token-for-token identical to non-speculative serving while emitting up
to ``k+1`` tokens per step.  Models without the span-write decode path
decline via ``supports_spec()`` and serve non-speculatively.
"""
from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import (SERVE_DECODE_RULES, SERVE_PREFILL_RULES,
                                 axis_rules, shard_hint, tree_hint,
                                 tree_shardings)
from .buckets import bucket_for, default_buckets
from .cache_ops import (copy_page, merge_slots, scatter_prefill_pages,
                        truncate_slot, write_slot)
from .pages import PagePool, block_hashes
from .sampler import policy_in_use, sample_tokens


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (T,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 => greedy
    top_k: int = 0               # 0 => disabled
    top_p: float = 0.0           # 0 or >= 1 => disabled (nucleus)
    deadline: Optional[float] = None   # absolute time.time() cutoff
    on_token: Optional[Callable[[int, int], None]] = None
    on_finish: Optional[Callable[[int, np.ndarray], None]] = None
    out_tokens: Optional[list] = None


class TraceCounter:
    """Wraps a jitted callable; counts calls and distinct input
    shape/dtype signatures (== XLA traces for a jit with no static
    args).  The serving tests assert prefill traces <= bucket count."""

    def __init__(self, fn):
        self.fn = fn
        self.calls = 0
        self._sigs = set()

    def __call__(self, *args):
        self.calls += 1
        sig = tuple(
            (leaf.shape, str(leaf.dtype))
            for leaf in jax.tree_util.tree_leaves(args)
            if hasattr(leaf, "shape"))
        self._sigs.add(sig)
        return self.fn(*args)

    @property
    def traces(self) -> int:
        return len(self._sigs)


def _empty() -> np.ndarray:
    return np.zeros((0,), np.int32)


class ServeEngine:
    def __init__(self, model, params, *, n_slots: int = 4,
                 max_len: int = 512, buckets=None, rng_seed: int = 0,
                 paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None, spec=None, mesh=None):
        self.model = model
        self.mesh = mesh
        # serve-time sharding (DESIGN.md §13): with a mesh, weights are
        # laid out tensor-parallel once at admission-to-engine time —
        # QuantizedTensor codes *and* scales split on the same logical
        # axes — and every jitted entry point traces under its regime's
        # rule table (prefill vs decode).  mesh=None is the single-device
        # fast path: every placement/hint helper below degrades to
        # identity and the engine behaves exactly as before.
        self._cache_axes = (model.cache_axes()
                            if hasattr(model, "cache_axes") else None)
        self.params = self._place(params, model.param_axes()
                                  if hasattr(model, "param_axes") else None)
        self.n_slots = n_slots
        self.max_len = max_len
        self.cfg = model.cfg
        if buckets is None:
            self.buckets = default_buckets(max_len)
        else:
            # the largest bucket is always exactly max_len so every
            # admissible prompt has a bucket (same invariant as
            # default_buckets)
            self.buckets = tuple(sorted({min(int(b), max_len)
                                         for b in buckets} | {max_len}))
        self._supports_plen = (
            "prompt_len" in inspect.signature(model.prefill).parameters)
        probe = getattr(model, "supports_paged", None)
        self.paged = bool(paged and self._supports_plen
                          and probe is not None and probe())
        self._key = jax.random.PRNGKey(rng_seed)
        self._rng_step = 0

        # jitted entry points (TraceCounter feeds metrics()["*_traces"]).
        # Each is pinned to one rule regime: the axis_rules context is
        # (re-)entered around every call so the trace — whenever it
        # happens — always sees the same table.
        self._prefill1 = TraceCounter(
            self._jit(model.prefill, SERVE_PREFILL_RULES))
        self._prefill_admit = TraceCounter(
            self._jit(self._prefill_admit_fn, SERVE_PREFILL_RULES))
        self._admit_one = TraceCounter(
            self._jit(self._admit_one_fn, SERVE_PREFILL_RULES))
        self._decode = TraceCounter(
            self._jit(self._decode_fn, SERVE_DECODE_RULES))
        self._sample = self._jit(sample_tokens, SERVE_DECODE_RULES)

        if self.paged:
            self.page_size = page_size
            self.pages_per_slot = -(-max_len // page_size)
            # default capacity guarantees admission can never deadlock:
            # every slot can hold a full max_len sequence (+1 trash page)
            self.n_pages = (int(n_pages) if n_pages
                            else 1 + n_slots * self.pages_per_slot)
            self.pool = PagePool(self.n_pages, page_size)
            # persistent across serve() calls so the prefix index keeps
            # paying off between bursts; with a mesh the page stores are
            # sharded on the head axis (page tables stay replicated)
            self._store_axes = (model.paged_cache_axes()
                                if hasattr(model, "paged_cache_axes")
                                else None)
            self._store = self._place(
                model.init_paged_cache(self.n_pages, page_size),
                self._store_axes)
            self._prefill_paged = TraceCounter(
                self._jit(self._prefill_paged_fn, SERVE_PREFILL_RULES))
            self._decode_paged = TraceCounter(
                self._jit(self._decode_paged_fn, SERVE_DECODE_RULES))
            self._scatter_pages = self._jit(scatter_prefill_pages,
                                            SERVE_DECODE_RULES)
            self._copy_page = self._jit(copy_page, SERVE_DECODE_RULES)

        # speculative decoding (DESIGN.md §12): spec is a SpecConfig with
        # a draft source; models without the span-write decode path fall
        # back to plain decode
        self._spec = None
        probe_spec = getattr(model, "supports_spec", None)
        if spec is not None and probe_spec is not None and probe_spec():
            from .spec import SpecRunner
            self._spec = SpecRunner(self, spec)
            self._truncate = self._jit(truncate_slot, SERVE_DECODE_RULES)

        self._m = dict(tokens_generated=0, decode_steps=0, prefill_batches=0,
                       admitted=0, completed=0, expired=0, truncated=0,
                       prefix_hits=0, prefix_hit_tokens=0, fill_steps=0,
                       serve_time_s=0.0)
        self._req_stats: dict = {}   # rid -> dict(tokens=..., steps=...)

    # -- mesh plumbing -------------------------------------------------------
    def _jit(self, fn, rules):
        """jit ``fn``; with a mesh, every call (so also the trace) runs
        under ``axis_rules(mesh, rules)``.  The raw jitted callable stays
        reachable as ``.jitted`` (lowering/compile introspection)."""
        jf = jax.jit(fn)
        if self.mesh is None:
            return jf

        def wrapped(*args):
            with axis_rules(self.mesh, rules):
                return jf(*args)

        wrapped.jitted = jf
        return wrapped

    def _place(self, tree, axes_tree):
        """Place a param/cache tree onto the mesh per its logical-axis
        annotations (identity without a mesh or annotations)."""
        if self.mesh is None or axes_tree is None or tree is None:
            return tree
        return jax.device_put(
            tree, tree_shardings(self.mesh, tree, axes_tree,
                                 rules=SERVE_DECODE_RULES))

    def _hint_cache(self, cache):
        """Pin a dense cache tree to its canonical layout inside a jitted
        body — keeps the steady-state decode layout stable step to step."""
        if self.mesh is None or self._cache_axes is None:
            return cache
        return tree_hint(cache, self._cache_axes)

    def _hint_store(self, store):
        if self.mesh is None or self._store_axes is None:
            return store
        return tree_hint(store, self._store_axes)

    @staticmethod
    def _gathered(step_logits):
        """Replicate one step's (B, V) logits before sampling.  The
        projection leaves them vocab-sharded (logits_from_hidden's hint);
        this second constraint is the decode step's single all-gather —
        argmax/sampling then runs replicated with no further collectives.
        Identity without an active mesh."""
        return shard_hint(step_logits, "batch", None)

    # -- jitted bodies -------------------------------------------------------
    def _prefill_admit_fn(self, params, tokens, prompt_len, cache,
                          admit_mask, temps, top_k, top_p, key, slot_last):
        """Batched bucketed prefill + admission + first-token sampling.

        tokens (n_slots, bucket) is slot-aligned: row s is the prompt
        admitted into slot s (rows with admit_mask False are dummies).
        """
        scratch = self.model.init_cache(self.n_slots, self.max_len)
        logits, new = self.model.prefill(params, tokens, scratch, prompt_len)
        merged = self._hint_cache(merge_slots(cache, new, admit_mask))
        first = sample_tokens(self._gathered(logits[:, 0]), temps, top_k,
                              key, top_p)
        slot_last = jnp.where(admit_mask, first, slot_last)
        return slot_last, merged

    def _admit_one_fn(self, params, tokens, cache, slot, temps, top_k,
                      top_p, key, slot_last):
        """Fallback admission: exact-length batch-1 prefill, written into
        the batched cache by one per-slot dynamic_update_index_in_dim op
        (slot is traced — a single compile serves every slot)."""
        c1 = self.model.init_cache(1, self.max_len)
        logits, c1 = self.model.prefill(params, tokens, c1)
        merged = self._hint_cache(write_slot(cache, c1, slot))
        first = sample_tokens(self._gathered(logits[:, 0]), temps, top_k,
                              key, top_p)
        slot_last = jax.lax.dynamic_update_index_in_dim(
            slot_last, first[0], slot, 0)
        return slot_last, merged

    def _decode_fn(self, params, cache, slot_last, active, temps, top_k,
                   top_p, key):
        """One decode step with inactive slots masked.

        Inactive slots still flow through the batched matmuls (shape
        stability) but their ``len`` is restored afterwards and their
        in-bounds scratch write lands at a position attention masks out —
        a dead slot's cache length can never pass ``max_len``."""
        old_len = cache["len"]
        safe_len = jnp.where(active, old_len,
                             jnp.minimum(old_len, self.max_len - 1))
        cache = dict(cache, len=safe_len)
        logits, cache = self.model.decode_step(params, cache,
                                               slot_last[:, None])
        cache = dict(cache, len=jnp.where(active, cache["len"], old_len))
        cache = self._hint_cache(cache)
        nxt = sample_tokens(self._gathered(logits[:, 0]), temps, top_k,
                            key, top_p)
        nxt = jnp.where(active, nxt, slot_last)
        return nxt, cache

    def _prefill_paged_fn(self, params, tokens, prompt_len, admit_mask,
                          temps, top_k, top_p, key, slot_last):
        """Bucketed batched prefill for the paged path: fills a dense
        *scratch* cache sized to the bucket (padded up to a page
        multiple), samples first tokens, and returns the scratch for the
        host to scatter into freshly allocated pages.  Unlike the dense
        path there is no merge — the persistent cache is the page store.
        """
        t = tokens.shape[1]
        s_pages = -(-t // self.page_size) * self.page_size
        scratch = self.model.init_cache(self.n_slots, s_pages)
        logits, new = self.model.prefill(params, tokens, scratch, prompt_len)
        new = self._hint_cache(new)
        first = sample_tokens(self._gathered(logits[:, 0]), temps, top_k,
                              key, top_p)
        slot_last = jnp.where(admit_mask, first, slot_last)
        return slot_last, new

    def _decode_paged_fn(self, params, store, page_table, lens, slot_last,
                         active, temps, top_k, top_p, key):
        """One decode step against the page store.  ``lens`` is the
        host-managed per-slot valid length (already clamped for retired
        slots); retired slots' page-table rows point at the trash page,
        so their masked write can never touch a live page."""
        logits, store = self.model.decode_step_paged(
            params, store, slot_last[:, None], page_table, lens)
        store = self._hint_store(store)
        nxt = sample_tokens(self._gathered(logits[:, 0]), temps, top_k,
                            key, top_p)
        nxt = jnp.where(active, nxt, slot_last)
        return nxt, store

    # -- helpers -------------------------------------------------------------
    def _next_key(self):
        self._rng_step += 1
        return jax.random.fold_in(self._key, self._rng_step)

    @staticmethod
    def _policy_args(temps, top_k, top_p):
        """Device policy args for the jitted bodies, with top-k/top-p
        dropped to ``None`` when no slot in the batch uses them — the
        full-vocab sort/argsort behind those masks would otherwise run
        every decode step (None vs array is a different jit signature,
        so each variant compiles once).  The in-use predicates are
        shared with the speculative cycle (:func:`.sampler.policy_in_use`)."""
        use_tk, use_tp = policy_in_use(top_k, top_p)
        tk = jnp.asarray(top_k, jnp.int32) if use_tk else None
        tp = jnp.asarray(top_p, jnp.float32) if use_tp else None
        return jnp.asarray(temps, jnp.float32), tk, tp

    def _check_prompt(self, req: Request) -> int:
        n = int(np.asarray(req.prompt).shape[0])
        if n < 1:
            raise ValueError(f"req {req.rid}: empty prompt")
        limit = self.buckets[-1] if self._supports_plen else self.max_len
        if n > limit:
            raise ValueError(
                f"req {req.rid}: prompt length {n} exceeds {limit}")
        return n

    # -- single-request path -------------------------------------------------
    def generate(self, request: Request) -> np.ndarray:
        """Single-request generate (tests / quickstart): exact-length
        batch-1 prefill + batch-1 decode through the same jitted sampler
        ops as the batched path."""
        self._check_prompt(request)
        if request.max_new_tokens <= 0:
            return _empty()
        t0 = time.time()
        cache = self._place(self.model.init_cache(1, self.max_len),
                            self._cache_axes)
        tok = jnp.asarray(np.asarray(request.prompt, np.int32))[None]
        logits, cache = self._prefill1(self.params, tok, cache)
        temps, top_k, top_p = self._policy_args(
            [request.temperature], [request.top_k], [request.top_p])
        active = jnp.ones((1,), bool)
        nxt = self._sample(logits[:, 0], temps, top_k, self._next_key(),
                           top_p)
        out = [int(nxt[0])]
        n_steps = min(request.max_new_tokens - 1,
                      self.max_len - len(request.prompt))
        for _ in range(n_steps):
            nxt, cache = self._decode(self.params, cache, nxt, active,
                                      temps, top_k, top_p,
                                      self._next_key())
            self._m["decode_steps"] += 1
            out.append(int(nxt[0]))
        self._m["tokens_generated"] += len(out)
        self._m["serve_time_s"] += time.time() - t0
        return np.asarray(out, np.int32)

    def _handle_immediate(self, req: Request, results: dict) -> bool:
        """True if the request completes without ever taking a slot."""
        if req.deadline is not None and time.time() > req.deadline:
            results[req.rid] = _empty()
            self._m["expired"] += 1
            if req.on_finish:
                req.on_finish(req.rid, results[req.rid])
            return True
        if req.max_new_tokens <= 0:
            results[req.rid] = _empty()
            self._m["completed"] += 1
            if req.on_finish:
                req.on_finish(req.rid, results[req.rid])
            return True
        return False

    def _emit(self, req: Request, tok: int):
        req.out_tokens.append(tok)
        self._m["tokens_generated"] += 1
        self._req_stats.setdefault(
            req.rid, dict(tokens=0, steps=0))["tokens"] += 1
        if req.on_token:
            req.on_token(req.rid, tok)

    def _count_step(self, rid: int):
        """One engine step (prefill, decode step, or spec cycle) in
        which request ``rid`` occupied a live slot — the denominator of
        its ``tokens_per_step``."""
        self._req_stats.setdefault(
            rid, dict(tokens=0, steps=0))["steps"] += 1

    def request_summary(self) -> dict:
        """Per-request ``tokens_per_step`` (tokens emitted per engine
        step while resident; > 1 only with speculative bursts)."""
        return {rid: s["tokens"] / max(s["steps"], 1)
                for rid, s in self._req_stats.items()}

    # -- batched continuous path ---------------------------------------------
    def serve(self, requests: List[Request]) -> dict:
        """Run all requests to completion with slot-based batching.

        Returns {rid: np.ndarray of generated tokens}.  Requests with
        ``max_new_tokens=0`` complete immediately with an empty sequence;
        requests whose ``deadline`` already passed at admission expire
        with an empty sequence; a running request whose deadline passes
        mid-decode is truncated at the tokens produced so far.

        With ``paged=True`` (and a model whose cache layout supports it)
        the same contract is served from the paged KV cache."""
        self._req_stats = {}         # per-serve scope (no unbounded growth)
        if self.paged:
            return self._serve_paged(requests)
        t0 = time.time()
        for r in requests:
            self._check_prompt(r)
        queue = list(requests)
        results: dict = {}

        n = self.n_slots
        cache = self._place(self.model.init_cache(n, self.max_len),
                            self._cache_axes)
        slot_req: List[Optional[Request]] = [None] * n
        slot_last = jnp.zeros((n,), jnp.int32)
        slot_len = np.zeros(n, np.int64)      # host mirror of cache["len"]
        temps = np.zeros(n, np.float32)
        top_k = np.zeros(n, np.int32)
        top_p = np.zeros(n, np.float32)
        active = np.zeros(n, bool)

        def finish(s: int, counter: str = "completed"):
            req = slot_req[s]
            out = np.asarray(req.out_tokens, np.int32)
            results[req.rid] = out
            self._m[counter] += 1
            slot_req[s] = None
            active[s] = False
            if req.on_finish:
                req.on_finish(req.rid, out)

        def handle_immediate(req: Request) -> bool:
            return self._handle_immediate(req, results)

        def emit(req: Request, tok: int):
            self._emit(req, tok)

        def admit(group, free):
            nonlocal slot_last, cache
            for req, s in zip(group, free):
                req.out_tokens = []
                slot_req[s] = req
                active[s] = True
                temps[s] = req.temperature
                top_k[s] = req.top_k
                top_p[s] = req.top_p
                slot_len[s] = len(req.prompt)
                self._m["admitted"] += 1
                self._req_stats[req.rid] = dict(tokens=0, steps=0)
                if self._spec is not None:
                    self._spec.admit_slot(s, req.prompt)

        def post_admit(req, s, first_tok):
            self._count_step(req.rid)
            emit(req, first_tok)
            if len(req.out_tokens) >= req.max_new_tokens:
                finish(s)
            elif slot_len[s] >= self.max_len:
                finish(s, counter="truncated")  # cache already full

        def fill_slots():
            nonlocal slot_last, cache
            while True:
                free = [s for s in range(n) if slot_req[s] is None]
                if not free or not queue:
                    return
                if not self._supports_plen:
                    req = None
                    while queue:
                        cand = queue.pop(0)
                        if not handle_immediate(cand):
                            req = cand
                            break
                    if req is None:
                        continue
                    s = free[0]
                    admit([req], [s])
                    slot_last, cache = self._admit_one(
                        self.params,
                        jnp.asarray(np.asarray(req.prompt, np.int32))[None],
                        cache, jnp.asarray(s, jnp.int32),
                        *self._policy_args([req.temperature], [req.top_k],
                                           [req.top_p]),
                        self._next_key(), slot_last)
                    self._m["prefill_batches"] += 1
                    post_admit(req, s, int(np.asarray(slot_last)[s]))
                    continue

                # bucketed batched admission: group FIFO-ordered waiting
                # requests that share the head request's bucket
                while queue and handle_immediate(queue[0]):
                    queue.pop(0)
                if not queue:
                    continue
                b = bucket_for(self.buckets, len(queue[0].prompt))
                group = []
                i = 0
                while i < len(queue) and len(group) < len(free):
                    r = queue[i]
                    if handle_immediate(r):
                        queue.pop(i)
                        continue
                    if bucket_for(self.buckets, len(r.prompt)) == b:
                        group.append(queue.pop(i))
                        continue
                    i += 1
                if not group:
                    continue
                tokens = np.zeros((n, b), np.int32)
                plen = np.ones(n, np.int32)
                admit_mask = np.zeros(n, bool)
                targets = free[:len(group)]
                for req, s in zip(group, targets):
                    p = np.asarray(req.prompt, np.int32)
                    tokens[s, :len(p)] = p
                    plen[s] = len(p)
                    admit_mask[s] = True
                admit(group, targets)
                slot_last, cache = self._prefill_admit(
                    self.params, jnp.asarray(tokens), jnp.asarray(plen),
                    cache, jnp.asarray(admit_mask),
                    *self._policy_args(temps, top_k, top_p),
                    self._next_key(), slot_last)
                self._m["prefill_batches"] += 1
                toks = np.asarray(slot_last)
                for req, s in zip(group, targets):
                    post_admit(req, s, int(toks[s]))

        fill_slots()
        while active.any():
            k_eff = self._spec_k(slot_len, active, slot_req)
            if k_eff >= 1:
                # speculative cycle: draft k_eff, verify k_eff+1, roll
                # back rejected suffixes by republishing host lengths
                lens_safe = np.where(
                    active, slot_len,
                    np.minimum(slot_len, self.max_len - (k_eff + 1)))
                out, n_acc, cache = self._spec.run_cycle_dense(
                    cache, jnp.asarray(lens_safe.astype(np.int32)),
                    slot_last, jnp.asarray(active), temps, top_k, top_p,
                    self._next_key(), k_eff)
                self._m["decode_steps"] += 1
                last_np = np.asarray(slot_last).copy()
                now = time.time()
                for s in range(n):
                    req = slot_req[s]
                    if req is None or not active[s]:
                        continue
                    self._count_step(req.rid)
                    consumed = 0
                    for i in range(int(n_acc[s]) + 1):
                        consumed = i + 1
                        slot_len[s] += 1
                        assert slot_len[s] <= self.max_len, \
                            f"slot {s}: cache len {slot_len[s]} > max_len"
                        last_np[s] = int(out[s, i])
                        emit(req, int(out[s, i]))
                        if len(req.out_tokens) >= req.max_new_tokens:
                            finish(s)
                            break
                        elif req.deadline is not None and now > req.deadline:
                            finish(s, counter="truncated")
                            break
                        elif slot_len[s] >= self.max_len:
                            finish(s, counter="truncated")
                            break
                    # draft proposals that reached the output (position
                    # n_acc is the correction/bonus, not a proposal)
                    self._spec.m["emitted_draft_tokens"] += \
                        min(consumed, int(n_acc[s]))
                slot_last = jnp.asarray(last_np)
                cache = self._truncate(
                    cache, jnp.asarray(slot_len.astype(np.int32)))
            else:
                if self._spec is not None:
                    # keep the independent draft's KV aligned through
                    # plain fallback steps (self-draft shares the cache)
                    self._spec.track_step(
                        slot_last,
                        np.where(active, slot_len,
                                 np.minimum(slot_len, self.max_len - 1)))
                slot_last, cache = self._decode(
                    self.params, cache, slot_last, jnp.asarray(active),
                    *self._policy_args(temps, top_k, top_p),
                    self._next_key())
                self._m["decode_steps"] += 1
                toks = np.asarray(slot_last)
                now = time.time()
                for s in range(n):
                    req = slot_req[s]
                    if req is None or not active[s]:
                        continue
                    self._count_step(req.rid)
                    slot_len[s] += 1
                    assert slot_len[s] <= self.max_len, \
                        f"slot {s}: cache len {slot_len[s]} > max_len"
                    emit(req, int(toks[s]))
                    if len(req.out_tokens) >= req.max_new_tokens:
                        finish(s)
                    elif req.deadline is not None and now > req.deadline:
                        finish(s, counter="truncated")
                    elif slot_len[s] >= self.max_len:
                        finish(s, counter="truncated")
            if queue and any(r is None for r in slot_req):
                fill_slots()
        self._m["serve_time_s"] += time.time() - t0
        return results

    def _spec_k(self, slot_len, active, slot_req, filling=()) -> int:
        """Draft depth for this iteration: the configured k shrunk to
        (a) the tightest active slot's remaining cache room (a cycle
        writes k+1 fresh positions per slot) and (b) the *largest*
        remaining token budget across active slots — when every slot is
        near its ``max_new_tokens`` a full-depth burst would be paid
        for and thrown away, so the depth tracks what can still be
        emitted (slots below the max just drop their surplus, which is
        cheap).  0 means "run a plain decode step" — near-capacity
        slots and prompt-filling paged slots keep the exact truncation
        semantics of non-speculative serving."""
        if self._spec is None or any(filling):
            return 0
        room = min(self.max_len - int(slot_len[s])
                   for s in range(self.n_slots) if active[s])
        budget = max(slot_req[s].max_new_tokens - len(slot_req[s].out_tokens)
                     for s in range(self.n_slots) if active[s])
        return max(0, min(self._spec.cfg.k, room - 1, budget - 1))

    # -- paged continuous path -----------------------------------------------
    def _serve_paged(self, requests: List[Request]) -> dict:
        """Continuous batching over the paged KV cache (DESIGN.md §10).

        Same external contract as the dense ``serve()`` — results are
        token-for-token identical — but the persistent cache is a pool
        of fixed-size pages:

        * admission consults the prefix index; fully-cached leading
          blocks map to shared physical pages (refcounted) and their
          prefill is skipped entirely,
        * the uncached prompt remainder streams through the jitted
          decode step (teacher-forced chunk-1 chunked prefill) while
          other slots keep decoding in the same batch,
        * prompts with no cached prefix go through the bucketed batched
          prefill into a bucket-sized scratch, scattered into freshly
          allocated pages, and their full blocks are published to the
          prefix index,
        * any write into a shared page is preceded by a host-side
          copy-on-write, and retiring a slot releases its page refs
          (index-held pages survive for cross-request reuse).
        """
        t0 = time.time()
        for r in requests:
            self._check_prompt(r)
        queue = list(requests)
        results: dict = {}

        n, ps = self.n_slots, self.page_size
        pool = self.pool
        # prompt hashes are deterministic per request — compute once, not
        # once per fill_slots pass (admission runs in the decode loop)
        hash_cache: dict = {}

        def hashes_of(req: Request) -> list:
            key = id(req)
            if key not in hash_cache:
                hash_cache[key] = block_hashes(req.prompt, ps)
            return hash_cache[key]
        table = np.full((n, self.pages_per_slot), PagePool.TRASH, np.int32)
        slot_req: List[Optional[Request]] = [None] * n
        slot_last = jnp.zeros((n,), jnp.int32)
        slot_len = np.zeros(n, np.int64)
        fill: List[Optional[np.ndarray]] = [None] * n  # prompt tail to feed
        slot_hashes: List[Optional[list]] = [None] * n
        temps = np.zeros(n, np.float32)
        top_k = np.zeros(n, np.int32)
        top_p = np.zeros(n, np.float32)
        active = np.zeros(n, bool)

        def release(s: int):
            for j in range(self.pages_per_slot):
                if table[s, j] != PagePool.TRASH:
                    pool.decref(int(table[s, j]))
                    table[s, j] = PagePool.TRASH

        def finish(s: int, counter: str = "completed"):
            req = slot_req[s]
            out = np.asarray(req.out_tokens, np.int32)
            results[req.rid] = out
            self._m[counter] += 1
            slot_req[s] = None
            active[s] = False
            fill[s] = None
            slot_hashes[s] = None
            release(s)
            if req.on_finish:
                req.on_finish(req.rid, out)

        def ensure_writable(s: int, pos: int):
            """Make the page holding position ``pos`` safe for slot
            ``s`` to write: allocate if unmapped, copy-on-write if
            shared with another slot or the prefix index."""
            lp = pos // ps
            phys = int(table[s, lp])
            if phys == PagePool.TRASH:
                table[s, lp] = pool.alloc()
            elif pool.is_shared(phys):
                fresh = pool.alloc()
                self._store = self._copy_page(self._store, phys, fresh)
                pool.decref(phys)
                table[s, lp] = fresh
                pool.cow_copies += 1

        def register_prompt_pages(s: int):
            """Publish the slot's full prompt blocks for future reuse
            (the index takes its own ref; partial tail blocks and
            generated-token pages are never shared)."""
            for j in range(len(slot_req[s].prompt) // ps):
                pool.register(slot_hashes[s][j], int(table[s, j]))

        def admit(req: Request, s: int):
            req.out_tokens = []
            slot_req[s] = req
            active[s] = True
            temps[s] = req.temperature
            top_k[s] = req.top_k
            top_p[s] = req.top_p
            self._m["admitted"] += 1
            self._req_stats[req.rid] = dict(tokens=0, steps=0)
            if self._spec is not None:
                self._spec.admit_slot(s, req.prompt)

        def finish_checks(req: Request, s: int, now=None):
            if len(req.out_tokens) >= req.max_new_tokens:
                finish(s)
            elif now is not None and req.deadline is not None \
                    and now > req.deadline:
                finish(s, counter="truncated")
            elif slot_len[s] >= self.max_len:
                finish(s, counter="truncated")

        def fill_slots():
            nonlocal slot_last
            while True:
                free = [s for s in range(n) if slot_req[s] is None]
                if not free or not queue:
                    return
                while queue and self._handle_immediate(queue[0], results):
                    queue.pop(0)
                if not queue:
                    continue
                head = queue[0]
                head_hashes = hashes_of(head)
                if pool.lookup_blocks(head_hashes):
                    # prefix hit: map the shared pages, skip their
                    # prefill, stream the tail through decode
                    queue.pop(0)
                    s = free[0]
                    matched = pool.match(head_hashes)
                    npr = len(head.prompt)
                    # always leave >= 1 token to process so the first
                    # sampled token has logits; a fully-cached prompt
                    # re-feeds its last token (the write into the shared
                    # final page is what triggers copy-on-write)
                    cached = min(len(matched) * ps, npr - 1)
                    for j, phys in enumerate(matched):
                        table[s, j] = phys
                    admit(head, s)
                    slot_hashes[s] = head_hashes
                    slot_len[s] = cached
                    fill[s] = np.asarray(head.prompt, np.int32)[cached:]
                    self._m["prefix_hits"] += 1
                    self._m["prefix_hit_tokens"] += cached
                    continue

                # no cached prefix: bucketed batched prefill.  Defer
                # queued requests whose first block duplicates a group
                # member's — next pass they hit the index instead of
                # prefilling the same prefix twice.
                b = bucket_for(self.buckets, len(head.prompt))
                group, seen_block0 = [], set()
                i = 0
                while i < len(queue) and len(group) < len(free):
                    r = queue[i]
                    if self._handle_immediate(r, results):
                        queue.pop(i)
                        continue
                    hs = hashes_of(r)
                    if r is not head and hs and (
                            pool.lookup_blocks(hs) or hs[0] in seen_block0):
                        i += 1
                        continue
                    if bucket_for(self.buckets, len(r.prompt)) == b:
                        group.append((queue.pop(i), hs))
                        if hs:
                            seen_block0.add(hs[0])
                        continue
                    i += 1
                if not group:
                    continue
                tokens = np.zeros((n, b), np.int32)
                plen = np.ones(n, np.int32)
                admit_mask = np.zeros(n, bool)
                targets = free[:len(group)]
                for (req, hs), s in zip(group, targets):
                    p = np.asarray(req.prompt, np.int32)
                    tokens[s, :len(p)] = p
                    plen[s] = len(p)
                    admit_mask[s] = True
                    admit(req, s)
                    slot_hashes[s] = hs
                    slot_len[s] = len(p)
                slot_last, scratch = self._prefill_paged(
                    self.params, jnp.asarray(tokens), jnp.asarray(plen),
                    jnp.asarray(admit_mask),
                    *self._policy_args(temps, top_k, top_p),
                    self._next_key(), slot_last)
                self._m["prefill_batches"] += 1
                n_scratch_pages = -(-b // ps)
                all_ids = np.full((len(group), n_scratch_pages),
                                  PagePool.TRASH, np.int32)
                for gi, ((req, hs), s) in enumerate(zip(group, targets)):
                    npages = -(-len(req.prompt) // ps)
                    phys = [pool.alloc() for _ in range(npages)]
                    all_ids[gi, :npages] = phys
                    table[s, :npages] = phys
                self._store = self._scatter_pages(
                    self._store, scratch,
                    jnp.asarray(np.asarray(targets, np.int32)),
                    jnp.asarray(all_ids))
                for (req, hs), s in zip(group, targets):
                    register_prompt_pages(s)
                toks = np.asarray(slot_last)
                for (req, hs), s in zip(group, targets):
                    self._count_step(req.rid)
                    self._emit(req, int(toks[s]))
                    finish_checks(req, s)

        fill_slots()
        while active.any():
            k_eff = self._spec_k(
                slot_len, active, slot_req,
                filling=[fill[s] is not None
                         for s in range(n) if active[s]])
            if k_eff >= 1:
                # paged speculative cycle: pre-own the burst's pages
                # (alloc / copy-on-write), draft+verify in one jitted
                # call, then trim exclusively-owned rejected-suffix
                # pages back to the pool
                lens = np.minimum(slot_len, self.max_len - (k_eff + 1))
                for s in range(n):
                    if not active[s]:
                        continue
                    lens[s] = slot_len[s]
                    for pos in range(int(slot_len[s]),
                                     int(slot_len[s]) + k_eff + 1):
                        ensure_writable(s, pos)
                out, n_acc, self._store = self._spec.run_cycle_paged(
                    self._store, jnp.asarray(table),
                    jnp.asarray(lens.astype(np.int32)), slot_last,
                    jnp.asarray(active), temps, top_k, top_p,
                    self._next_key(), k_eff)
                self._m["decode_steps"] += 1
                last_np = np.asarray(slot_last).copy()
                now = time.time()
                for s in range(n):
                    req = slot_req[s]
                    if req is None or not active[s]:
                        continue
                    self._count_step(req.rid)
                    consumed = 0
                    for i in range(int(n_acc[s]) + 1):
                        consumed = i + 1
                        slot_len[s] += 1
                        assert slot_len[s] <= self.max_len, \
                            f"slot {s}: cache len {slot_len[s]} > max_len"
                        last_np[s] = int(out[s, i])
                        self._emit(req, int(out[s, i]))
                        if len(req.out_tokens) >= req.max_new_tokens:
                            finish(s)
                            break
                        elif req.deadline is not None and now > req.deadline:
                            finish(s, counter="truncated")
                            break
                        elif slot_len[s] >= self.max_len:
                            finish(s, counter="truncated")
                            break
                    self._spec.m["emitted_draft_tokens"] += \
                        min(consumed, int(n_acc[s]))
                    if active[s]:
                        # rejected-suffix rollback: pages wholly past the
                        # accepted depth were allocated (or COW'd) for
                        # this burst and are exclusively owned — shared
                        # prefix pages all sit below slot_len
                        for j in range(self.pages_per_slot):
                            phys = int(table[s, j])
                            if phys != PagePool.TRASH \
                                    and j * ps >= slot_len[s]:
                                assert not pool.is_shared(phys)
                                pool.decref(phys)
                                table[s, j] = PagePool.TRASH
                slot_last = jnp.asarray(last_np)
            else:
                sl = np.asarray(slot_last).copy()
                lens = np.minimum(slot_len, self.max_len - 1)  # retired
                for s in range(n):
                    if not active[s]:
                        continue
                    lens[s] = slot_len[s]
                    ensure_writable(s, int(slot_len[s]))
                    if fill[s] is not None:
                        sl[s] = fill[s][0]      # teacher-force the prompt
                if self._spec is not None:
                    # align the independent draft's KV through fill /
                    # fallback steps (it sees the same token stream)
                    self._spec.track_step(jnp.asarray(sl), lens)
                slot_last, self._store = self._decode_paged(
                    self.params, self._store, jnp.asarray(table),
                    jnp.asarray(lens.astype(np.int32)), jnp.asarray(sl),
                    jnp.asarray(active),
                    *self._policy_args(temps, top_k, top_p),
                    self._next_key())
                self._m["decode_steps"] += 1
                toks = np.asarray(slot_last)
                now = time.time()
                for s in range(n):
                    req = slot_req[s]
                    if req is None or not active[s]:
                        continue
                    self._count_step(req.rid)
                    slot_len[s] += 1
                    assert slot_len[s] <= self.max_len, \
                        f"slot {s}: cache len {slot_len[s]} > max_len"
                    if fill[s] is not None:
                        self._m["fill_steps"] += 1
                        fill[s] = fill[s][1:]
                        if len(fill[s]):
                            if req.deadline is not None \
                                    and now > req.deadline:
                                finish(s, counter="truncated")
                            continue        # still prefilling this slot
                        # fill done: this step consumed the last prompt
                        # token, so the sampled token is the first output
                        fill[s] = None
                        register_prompt_pages(s)
                    self._emit(req, int(toks[s]))
                    finish_checks(req, s, now)
            if queue and any(r is None for r in slot_req):
                fill_slots()
        self._m["serve_time_s"] += time.time() - t0
        return results

    # -- observability -------------------------------------------------------
    def metrics(self) -> dict:
        """Counter snapshot: throughput, prefill/decode call and trace
        counts, and the retrace count (compiles beyond the first per
        jitted entry point — bounded by len(buckets)-1 for the bucketed
        prefill)."""
        m = dict(self._m)
        counters = [self._prefill_admit, self._admit_one, self._prefill1,
                    self._decode]
        m["prefill_calls"] = (self._prefill_admit.calls
                              + self._admit_one.calls + self._prefill1.calls)
        m["prefill_traces"] = self._prefill_admit.traces
        m["prefill_traces_single"] = (self._admit_one.traces
                                      + self._prefill1.traces)
        m["decode_traces"] = self._decode.traces
        m["paged"] = self.paged
        m["mesh"] = dict(self.mesh.shape) if self.mesh is not None else None
        if self.paged:
            counters += [self._prefill_paged, self._decode_paged]
            m["prefill_calls"] += self._prefill_paged.calls
            m["prefill_traces"] += self._prefill_paged.traces
            m["decode_traces"] += self._decode_paged.traces
            m["page_size"] = self.page_size
            m["pages_total"] = self.n_pages - 1      # minus the trash page
            m["pages_in_use"] = self.pool.pages_in_use()
            m["pages_peak"] = self.pool.in_use_peak
            m["page_bytes"] = self.page_bytes()
            # peak_cache_bytes counts *pinned* pages — the provisioning
            # signal a deployment would size n_pages from.  The engine's
            # actual device allocation is alloc_cache_bytes (the full
            # pool; with the deadlock-free default sizing that exceeds
            # the dense cache — pass n_pages to provision to peak+slack)
            m["peak_cache_bytes"] = self.pool.in_use_peak * self.page_bytes()
            m["alloc_cache_bytes"] = sum(leaf.nbytes
                                         for leaf in self._store.values())
            m["page_allocs"] = self.pool.alloc_count
            m["cow_copies"] = self.pool.cow_copies
            m["page_evictions"] = self.pool.evictions
            m["prefix_index_blocks"] = len(self.pool.index)
            m["prefix_lookups"] = self.pool.prefix_lookups
            m["prefix_block_hits"] = self.pool.prefix_block_hits
        m["retrace_count"] = sum(max(0, c.traces - 1) for c in counters)
        m["buckets"] = list(self.buckets)
        m["spec"] = self._spec is not None
        if self._spec is not None:
            m.update(self._spec.metrics())
            m["accept_rate"] = (m["accepted_tokens"]
                                / max(m["proposed_tokens"], 1))
            # share of emitted tokens that the draft proposed (the rest
            # are prefill first-tokens and verify corrections/bonuses);
            # uses the emitted count, not acceptances — a burst cut by a
            # budget or deadline accepts more than it emits
            m["draft_share"] = (m["emitted_draft_tokens"]
                                / max(m["tokens_generated"], 1))
        m["tokens_per_step"] = (m["tokens_generated"]
                                / max(m["decode_steps"], 1))
        dt = m["serve_time_s"]
        m["tokens_per_s"] = (m["tokens_generated"] / dt) if dt > 0 else 0.0
        return m

    def page_bytes(self) -> int:
        """Device bytes of one physical KV page (every leaf, all
        layers)."""
        if not self.paged:
            return 0
        return sum(leaf.nbytes // leaf.shape[1]
                   for leaf in self._store.values())
