"""Bucketed continuous-batching engine over FAQ-quantized weights.

Slot-based continuous batching with three hot-path properties:

* **Bucketed batched prefill** — waiting requests are padded to a small
  fixed grid of length buckets (:mod:`.buckets`) and prefilled together
  in one slot-aligned batch with per-row ``prompt_len``; admission
  compiles at most once per bucket instead of once per distinct prompt
  length, and the prefilled rows land in the live decode cache through a
  single jitted merge (:func:`.cache_ops.merge_slots`).
* **On-device sampling** — a jitted batched sampler
  (:func:`.sampler.sample_tokens`, greedy/temperature/top-k keyed by
  per-slot temperature) runs fused with the decode step, so each step
  transfers one int32 per slot instead of a vocab-size logits row.
* **Inactive-slot masking** — finished/empty slots are frozen inside the
  jitted decode wrapper (``len`` restored, sampled token suppressed), so
  a draining batch can never advance a dead slot's cache length past
  ``max_len`` and corrupt its last cache position.

The weights are the *packed* QuantizedTensor representation — every
matmul runs through the dequant-matmul kernel path (``qlinear``
dispatch), i.e. the paper's deployment format is the first-class serving
path, not a simulation.  Orchestration stays in Python (jitted
prefill/decode inner loops) — on TPU the jitted steps dominate and
Python overhead hides under the device queue.

Models whose ``prefill`` does not accept ``prompt_len`` (hymba's ring
buffer, recurrent xlstm) fall back to per-request exact-length prefill
admitted through the jitted per-slot :func:`.cache_ops.write_slot` op —
correctness fixes apply there too, only the compile-per-length cost
remains.
"""
from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .buckets import bucket_for, default_buckets
from .cache_ops import merge_slots, write_slot
from .sampler import sample_tokens


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (T,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 => greedy
    top_k: int = 0               # 0 => disabled
    deadline: Optional[float] = None   # absolute time.time() cutoff
    on_token: Optional[Callable[[int, int], None]] = None
    on_finish: Optional[Callable[[int, np.ndarray], None]] = None
    out_tokens: Optional[list] = None


class TraceCounter:
    """Wraps a jitted callable; counts calls and distinct input
    shape/dtype signatures (== XLA traces for a jit with no static
    args).  The serving tests assert prefill traces <= bucket count."""

    def __init__(self, fn):
        self.fn = fn
        self.calls = 0
        self._sigs = set()

    def __call__(self, *args):
        self.calls += 1
        sig = tuple(
            (leaf.shape, str(leaf.dtype))
            for leaf in jax.tree_util.tree_leaves(args)
            if hasattr(leaf, "shape"))
        self._sigs.add(sig)
        return self.fn(*args)

    @property
    def traces(self) -> int:
        return len(self._sigs)


def _empty() -> np.ndarray:
    return np.zeros((0,), np.int32)


class ServeEngine:
    def __init__(self, model, params, *, n_slots: int = 4,
                 max_len: int = 512, buckets=None, rng_seed: int = 0):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cfg = model.cfg
        if buckets is None:
            self.buckets = default_buckets(max_len)
        else:
            # the largest bucket is always exactly max_len so every
            # admissible prompt has a bucket (same invariant as
            # default_buckets)
            self.buckets = tuple(sorted({min(int(b), max_len)
                                         for b in buckets} | {max_len}))
        self._supports_plen = (
            "prompt_len" in inspect.signature(model.prefill).parameters)
        self._key = jax.random.PRNGKey(rng_seed)
        self._rng_step = 0

        # jitted entry points (TraceCounter feeds metrics()["*_traces"])
        self._prefill1 = TraceCounter(jax.jit(model.prefill))
        self._prefill_admit = TraceCounter(jax.jit(self._prefill_admit_fn))
        self._admit_one = TraceCounter(jax.jit(self._admit_one_fn))
        self._decode = TraceCounter(jax.jit(self._decode_fn))
        self._sample = jax.jit(sample_tokens)

        self._m = dict(tokens_generated=0, decode_steps=0, prefill_batches=0,
                       admitted=0, completed=0, expired=0, truncated=0,
                       serve_time_s=0.0)

    # -- jitted bodies -------------------------------------------------------
    def _prefill_admit_fn(self, params, tokens, prompt_len, cache,
                          admit_mask, temps, top_k, key, slot_last):
        """Batched bucketed prefill + admission + first-token sampling.

        tokens (n_slots, bucket) is slot-aligned: row s is the prompt
        admitted into slot s (rows with admit_mask False are dummies).
        """
        scratch = self.model.init_cache(self.n_slots, self.max_len)
        logits, new = self.model.prefill(params, tokens, scratch, prompt_len)
        merged = merge_slots(cache, new, admit_mask)
        first = sample_tokens(logits[:, 0], temps, top_k, key)
        slot_last = jnp.where(admit_mask, first, slot_last)
        return slot_last, merged

    def _admit_one_fn(self, params, tokens, cache, slot, temps, top_k, key,
                      slot_last):
        """Fallback admission: exact-length batch-1 prefill, written into
        the batched cache by one per-slot dynamic_update_index_in_dim op
        (slot is traced — a single compile serves every slot)."""
        c1 = self.model.init_cache(1, self.max_len)
        logits, c1 = self.model.prefill(params, tokens, c1)
        merged = write_slot(cache, c1, slot)
        first = sample_tokens(logits[:, 0], temps, top_k, key)
        slot_last = jax.lax.dynamic_update_index_in_dim(
            slot_last, first[0], slot, 0)
        return slot_last, merged

    def _decode_fn(self, params, cache, slot_last, active, temps, top_k,
                   key):
        """One decode step with inactive slots masked.

        Inactive slots still flow through the batched matmuls (shape
        stability) but their ``len`` is restored afterwards and their
        in-bounds scratch write lands at a position attention masks out —
        a dead slot's cache length can never pass ``max_len``."""
        old_len = cache["len"]
        safe_len = jnp.where(active, old_len,
                             jnp.minimum(old_len, self.max_len - 1))
        cache = dict(cache, len=safe_len)
        logits, cache = self.model.decode_step(params, cache,
                                               slot_last[:, None])
        cache = dict(cache, len=jnp.where(active, cache["len"], old_len))
        nxt = sample_tokens(logits[:, 0], temps, top_k, key)
        nxt = jnp.where(active, nxt, slot_last)
        return nxt, cache

    # -- helpers -------------------------------------------------------------
    def _next_key(self):
        self._rng_step += 1
        return jax.random.fold_in(self._key, self._rng_step)

    def _check_prompt(self, req: Request) -> int:
        n = int(np.asarray(req.prompt).shape[0])
        if n < 1:
            raise ValueError(f"req {req.rid}: empty prompt")
        limit = self.buckets[-1] if self._supports_plen else self.max_len
        if n > limit:
            raise ValueError(
                f"req {req.rid}: prompt length {n} exceeds {limit}")
        return n

    # -- single-request path -------------------------------------------------
    def generate(self, request: Request) -> np.ndarray:
        """Single-request generate (tests / quickstart): exact-length
        batch-1 prefill + batch-1 decode through the same jitted sampler
        ops as the batched path."""
        self._check_prompt(request)
        if request.max_new_tokens <= 0:
            return _empty()
        t0 = time.time()
        cache = self.model.init_cache(1, self.max_len)
        tok = jnp.asarray(np.asarray(request.prompt, np.int32))[None]
        logits, cache = self._prefill1(self.params, tok, cache)
        temps = jnp.asarray([request.temperature], jnp.float32)
        top_k = jnp.asarray([request.top_k], jnp.int32)
        active = jnp.ones((1,), bool)
        nxt = self._sample(logits[:, 0], temps, top_k, self._next_key())
        out = [int(nxt[0])]
        n_steps = min(request.max_new_tokens - 1,
                      self.max_len - len(request.prompt))
        for _ in range(n_steps):
            nxt, cache = self._decode(self.params, cache, nxt, active,
                                      temps, top_k, self._next_key())
            self._m["decode_steps"] += 1
            out.append(int(nxt[0]))
        self._m["tokens_generated"] += len(out)
        self._m["serve_time_s"] += time.time() - t0
        return np.asarray(out, np.int32)

    # -- batched continuous path ---------------------------------------------
    def serve(self, requests: List[Request]) -> dict:
        """Run all requests to completion with slot-based batching.

        Returns {rid: np.ndarray of generated tokens}.  Requests with
        ``max_new_tokens=0`` complete immediately with an empty sequence;
        requests whose ``deadline`` already passed at admission expire
        with an empty sequence; a running request whose deadline passes
        mid-decode is truncated at the tokens produced so far."""
        t0 = time.time()
        for r in requests:
            self._check_prompt(r)
        queue = list(requests)
        results: dict = {}

        n = self.n_slots
        cache = self.model.init_cache(n, self.max_len)
        slot_req: List[Optional[Request]] = [None] * n
        slot_last = jnp.zeros((n,), jnp.int32)
        slot_len = np.zeros(n, np.int64)      # host mirror of cache["len"]
        temps = np.zeros(n, np.float32)
        top_k = np.zeros(n, np.int32)
        active = np.zeros(n, bool)

        def finish(s: int, counter: str = "completed"):
            req = slot_req[s]
            out = np.asarray(req.out_tokens, np.int32)
            results[req.rid] = out
            self._m[counter] += 1
            slot_req[s] = None
            active[s] = False
            if req.on_finish:
                req.on_finish(req.rid, out)

        def handle_immediate(req: Request) -> bool:
            """True if the request completes without ever taking a slot."""
            if req.deadline is not None and time.time() > req.deadline:
                results[req.rid] = _empty()
                self._m["expired"] += 1
                if req.on_finish:
                    req.on_finish(req.rid, results[req.rid])
                return True
            if req.max_new_tokens <= 0:
                results[req.rid] = _empty()
                self._m["completed"] += 1
                if req.on_finish:
                    req.on_finish(req.rid, results[req.rid])
                return True
            return False

        def emit(req: Request, tok: int):
            req.out_tokens.append(tok)
            self._m["tokens_generated"] += 1
            if req.on_token:
                req.on_token(req.rid, tok)

        def admit(group, free):
            nonlocal slot_last, cache
            for req, s in zip(group, free):
                req.out_tokens = []
                slot_req[s] = req
                active[s] = True
                temps[s] = req.temperature
                top_k[s] = req.top_k
                slot_len[s] = len(req.prompt)
                self._m["admitted"] += 1

        def post_admit(req, s, first_tok):
            emit(req, first_tok)
            if len(req.out_tokens) >= req.max_new_tokens:
                finish(s)
            elif slot_len[s] >= self.max_len:
                finish(s, counter="truncated")  # cache already full

        def fill_slots():
            nonlocal slot_last, cache
            while True:
                free = [s for s in range(n) if slot_req[s] is None]
                if not free or not queue:
                    return
                if not self._supports_plen:
                    req = None
                    while queue:
                        cand = queue.pop(0)
                        if not handle_immediate(cand):
                            req = cand
                            break
                    if req is None:
                        continue
                    s = free[0]
                    admit([req], [s])
                    slot_last, cache = self._admit_one(
                        self.params,
                        jnp.asarray(np.asarray(req.prompt, np.int32))[None],
                        cache, jnp.asarray(s, jnp.int32),
                        jnp.asarray([req.temperature], jnp.float32),
                        jnp.asarray([req.top_k], jnp.int32),
                        self._next_key(), slot_last)
                    self._m["prefill_batches"] += 1
                    post_admit(req, s, int(np.asarray(slot_last)[s]))
                    continue

                # bucketed batched admission: group FIFO-ordered waiting
                # requests that share the head request's bucket
                while queue and handle_immediate(queue[0]):
                    queue.pop(0)
                if not queue:
                    continue
                b = bucket_for(self.buckets, len(queue[0].prompt))
                group = []
                i = 0
                while i < len(queue) and len(group) < len(free):
                    r = queue[i]
                    if handle_immediate(r):
                        queue.pop(i)
                        continue
                    if bucket_for(self.buckets, len(r.prompt)) == b:
                        group.append(queue.pop(i))
                        continue
                    i += 1
                if not group:
                    continue
                tokens = np.zeros((n, b), np.int32)
                plen = np.ones(n, np.int32)
                admit_mask = np.zeros(n, bool)
                targets = free[:len(group)]
                for req, s in zip(group, targets):
                    p = np.asarray(req.prompt, np.int32)
                    tokens[s, :len(p)] = p
                    plen[s] = len(p)
                    admit_mask[s] = True
                admit(group, targets)
                slot_last, cache = self._prefill_admit(
                    self.params, jnp.asarray(tokens), jnp.asarray(plen),
                    cache, jnp.asarray(admit_mask), jnp.asarray(temps),
                    jnp.asarray(top_k), self._next_key(), slot_last)
                self._m["prefill_batches"] += 1
                toks = np.asarray(slot_last)
                for req, s in zip(group, targets):
                    post_admit(req, s, int(toks[s]))

        fill_slots()
        while active.any():
            slot_last, cache = self._decode(
                self.params, cache, slot_last, jnp.asarray(active),
                jnp.asarray(temps), jnp.asarray(top_k), self._next_key())
            self._m["decode_steps"] += 1
            toks = np.asarray(slot_last)
            now = time.time()
            for s in range(n):
                req = slot_req[s]
                if req is None or not active[s]:
                    continue
                slot_len[s] += 1
                assert slot_len[s] <= self.max_len, \
                    f"slot {s}: cache len {slot_len[s]} > max_len"
                emit(req, int(toks[s]))
                if len(req.out_tokens) >= req.max_new_tokens:
                    finish(s)
                elif req.deadline is not None and now > req.deadline:
                    finish(s, counter="truncated")
                elif slot_len[s] >= self.max_len:
                    finish(s, counter="truncated")
            if queue and any(r is None for r in slot_req):
                fill_slots()
        self._m["serve_time_s"] += time.time() - t0
        return results

    # -- observability -------------------------------------------------------
    def metrics(self) -> dict:
        """Counter snapshot: throughput, prefill/decode call and trace
        counts, and the retrace count (compiles beyond the first per
        jitted entry point — bounded by len(buckets)-1 for the bucketed
        prefill)."""
        m = dict(self._m)
        m["prefill_calls"] = (self._prefill_admit.calls
                              + self._admit_one.calls + self._prefill1.calls)
        m["prefill_traces"] = self._prefill_admit.traces
        m["prefill_traces_single"] = (self._admit_one.traces
                                      + self._prefill1.traces)
        m["decode_traces"] = self._decode.traces
        m["retrace_count"] = sum(
            max(0, c.traces - 1)
            for c in (self._prefill_admit, self._admit_one, self._prefill1,
                      self._decode))
        m["buckets"] = list(self.buckets)
        dt = m["serve_time_s"]
        m["tokens_per_s"] = (m["tokens_generated"] / dt) if dt > 0 else 0.0
        return m
