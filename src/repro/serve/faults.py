"""Deterministic fault injection for the serving stack (DESIGN.md §16).

Chaos testing the serve loop needs faults that are *reproducible*: the
same seed and schedule must fail the same allocation, stall the same
step, and burst the same arrivals on every run, so tests can assert
bit-identical survivor outputs and exact metric accounting.  Mirroring
the PR-7 ``clock=`` seam, :class:`FaultInjector` is one injectable
object consulted at the stack's failure points:

* **page allocations** — :meth:`alloc_ok` is polled by
  :meth:`~.pages.PagePool.try_alloc`; a vetoed allocation looks exactly
  like pool exhaustion and routes through the engine's backpressure
  protocol (preempt → retry), so chaos runs exercise preemption even
  when the pool is sized generously;
* **slow / hung steps** — :meth:`on_loop` is called once per serve-loop
  iteration and burns the scheduled stall through ``advance`` (tests
  pass the fake clock's advance; the default sleeps real time);
* **forced preemptions** — :meth:`take_preempt` tells the engine to
  preempt its lowest-priority slot this iteration, driving the
  preempt/resume machinery on the *dense* cache kind too (which has no
  page pressure of its own);
* **checkpoint write errors** — :meth:`ckpt_hook` is passed as
  ``fault_hook=`` to :func:`repro.dist.checkpoint.save` and raises
  ``OSError`` on scheduled write indices (the atomic tmp-dir protocol
  must leave ``latest_step`` untouched);
* **arrival bursts** — :func:`burstify` compresses seeded spans of a
  loadgen trace to simultaneous arrivals without changing any request.

Every trigger is counted in :meth:`metrics` (surfaced under the
engine's ``metrics()["faults"]``), so chaos tests can assert each
injected fault was actually consumed.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Tuple

import numpy as np

from repro.obs import MetricsRegistry


@dataclasses.dataclass
class FaultConfig:
    """Seeded fault schedule.  All indices are 0-based event counters
    (allocation calls, serve-loop iterations, checkpoint writes), so a
    schedule is deterministic regardless of wall time."""
    seed: int = 0
    alloc_fail_at: Tuple[int, ...] = ()    # allocation-call indices to veto
    alloc_fail_every: int = 0              # also veto every Nth call (0=off)
    alloc_fail_max: int = 64               # cap on *_every vetoes (liveness)
    stall_at: Tuple[int, ...] = ()         # serve-loop iterations to stall
    stall_s: float = 0.0                   # seconds per injected stall
    preempt_at: Tuple[int, ...] = ()       # iterations forcing a preemption
    ckpt_fail_at: Tuple[int, ...] = ()     # checkpoint writes to fail
    burst_every: int = 0                   # burstify: collapse every Nth gap
    burst_span: int = 4                    # arrivals merged per burst


class FaultInjector:
    """One deterministic fault source for a whole serve stack.

    ``advance`` is the time-burning hook for injected stalls: tests pass
    their fake clock's advance function; the default is ``time.sleep``
    (bounded by the schedule, never a clock *read* — the RPR006 seam is
    untouched).
    """

    def __init__(self, cfg: Optional[FaultConfig] = None, *,
                 advance: Optional[Callable[[float], None]] = None):
        self.cfg = cfg or FaultConfig()
        self.advance = advance if advance is not None else time.sleep
        self._alloc_calls = 0
        self._loop_iters = 0
        self._ckpt_writes = 0
        # registry-backed counter group (mapping-compatible with the
        # plain dict it replaces); an injector built standalone gets a
        # private registry and the engine rebinds it at attach time
        self.counts = MetricsRegistry().group("faults").init(
            alloc_failures=0, stalls=0, forced_preempts=0, ckpt_failures=0)

    # -- page allocations ----------------------------------------------------
    def alloc_ok(self) -> bool:
        """Polled by ``PagePool.try_alloc`` once per allocation attempt;
        False makes the attempt look like pool exhaustion."""
        i = self._alloc_calls
        self._alloc_calls += 1
        fail = i in self.cfg.alloc_fail_at
        if not fail and self.cfg.alloc_fail_every:
            fail = ((i + 1) % self.cfg.alloc_fail_every == 0
                    and self.counts["alloc_failures"]
                    < self.cfg.alloc_fail_max)
        if fail:
            self.counts["alloc_failures"] += 1
        return not fail

    # -- serve-loop iteration hooks ------------------------------------------
    def on_loop(self):
        """Called once per serve-loop iteration; burns any scheduled
        stall for this iteration through ``advance``."""
        i = self._loop_iters
        self._loop_iters += 1
        if i in self.cfg.stall_at and self.cfg.stall_s > 0:
            self.counts["stalls"] += 1
            self.advance(self.cfg.stall_s)

    def take_preempt(self) -> bool:
        """True when this iteration is scheduled to force-preempt (the
        engine picks the victim by its normal priority order).  Uses the
        iteration counter advanced by :meth:`on_loop`, so call order is
        on_loop() first, take_preempt() second, every iteration.  The
        count records *landed* preemptions, not scheduled ones — a
        schedule hit with no active slot injects nothing, so the engine
        reports back through :meth:`count_preempt` after it evicts."""
        return (self._loop_iters - 1) in self.cfg.preempt_at

    def count_preempt(self):
        self.counts["forced_preempts"] += 1

    # -- checkpoint writes ---------------------------------------------------
    def ckpt_hook(self):
        """Pass as ``fault_hook=`` to ``dist.checkpoint.save``; raises
        OSError on scheduled write indices (after the data payload is
        on disk, before the manifest promotes — the atomicity window
        the checkpoint protocol must survive)."""
        i = self._ckpt_writes
        self._ckpt_writes += 1
        if i in self.cfg.ckpt_fail_at:
            self.counts["ckpt_failures"] += 1
            raise OSError(f"injected checkpoint write failure #{i}")

    # -- observability -------------------------------------------------------
    def metrics(self) -> dict:
        return dict(self.counts,
                    alloc_calls=self._alloc_calls,
                    loop_iters=self._loop_iters,
                    ckpt_writes=self._ckpt_writes)


def burstify(trace, cfg: FaultConfig):
    """Compress seeded spans of a ``[(arrival_offset_s, Request)]``
    trace into simultaneous bursts: every ``burst_every``-th arrival
    pulls the following ``burst_span - 1`` arrivals onto its own
    timestamp.  Requests are untouched — only *when* they arrive
    changes, so greedy outputs stay comparable to the unbursted run."""
    if not cfg.burst_every:
        return list(trace)
    items = sorted(trace, key=lambda it: it[0])
    out, i = [], 0
    rng = np.random.default_rng(cfg.seed)
    while i < len(items):
        if (i // cfg.burst_every) and i % cfg.burst_every == 0:
            span = 1 + int(rng.integers(1, max(cfg.burst_span, 2)))
            t0 = items[i][0]
            for t, req in items[i:i + span]:
                out.append((t0, req))
            i += span
        else:
            out.append(items[i])
            i += 1
    return out
