"""Serve-side observability glue (DESIGN.md §17).

The engine's serve loop stays thin (RPR005, module line budget);
everything it does to *observe itself* lives here as free functions
over the engine + request state, same pattern as :mod:`.overload`:

* **request lifecycle** — :func:`enqueued` / :func:`bound` /
  :func:`first_token` / :func:`retired` (+ :func:`preempted` /
  :func:`shed` / :func:`settled`) stamp the request's phase-boundary
  times and emit its swimlane spans: ``queue`` (enqueue → slot bind),
  ``prefill`` (bind → first emitted token, covering chunked
  teacher-forcing), ``decode`` (first token → retire/preempt).  A
  preemption closes the decode span and restarts the clock, so a
  twice-preempted request renders as three queue/prefill/decode
  triples on one row.
* **engine step loop** — :func:`step_span` wraps one admit pass,
  decode step, spec cycle, or sampler sync as an engine-track span and
  feeds the phase-labeled ``serve.step_ms`` histogram.
* **pages** — :func:`page_event` marks alloc / copy-on-write / trim /
  pressure instants with a pages-in-use counter track.
* **metrics digest** — :func:`collect_metrics` is the body of
  ``ServeEngine.metrics()``: the frozen key surface existing consumers
  (benches, tests, launch scripts) read, now assembled from the
  registry-backed groups, plus the per-entry-point retrace breakdown
  (``retrace_by_entry``) that de-opaques ``retrace_count``.

Every timestamp is read through ``eng.clock`` — the injectable seam
(RPR006) — and nothing here touches device values: tracing adds zero
host transfers to the serve path (RPR002 + the HLO audit stay clean).
With ``eng.tracer is None`` every hook is a cheap early return.
"""
from __future__ import annotations

from contextlib import contextmanager

from repro.obs import PID_REQUESTS


# ---------------------------------------------------------------------------
# Request lifecycle
# ---------------------------------------------------------------------------

def enqueued(eng, req):
    """Request entered the engine's queue (directly or via the arrival
    feed): open its swimlane and stamp the queue-span start."""
    tr = eng.tracer
    if tr is None:
        return
    req.t_enqueue = req.arrival if req.arrival is not None else eng.clock()
    tr.thread_name(PID_REQUESTS, req.rid, f"req {req.rid}")
    tr.instant("arrival", pid=PID_REQUESTS, tid=req.rid, cat="lifecycle",
               args=dict(tenant=req.tenant, resume=bool(req.resume)))


def bound(eng, req, s: int):
    """Slot granted: close the queue span, start the prefill phase."""
    tr = eng.tracer
    if tr is None:
        return
    now = eng.clock()
    if req.t_enqueue is not None:
        tr.complete("queue", req.t_enqueue, now, pid=PID_REQUESTS,
                    tid=req.rid, cat="lifecycle",
                    args=dict(slot=s, resume=bool(req.resume)))
    req.t_bind, req.t_first = now, None


def first_token(eng, req):
    """First emitted token: close the prefill span (for chunked or
    prefix-hit admissions this includes the teacher-forced fill steps —
    the whole time the request occupied a slot without emitting)."""
    req.t_first = eng.clock()
    tr = eng.tracer
    if tr is not None and req.t_bind is not None:
        tr.complete("prefill", req.t_bind, req.t_first, pid=PID_REQUESTS,
                    tid=req.rid, cat="lifecycle")


def fill_done(eng, req):
    """A chunked / prefix-hit admission finished teacher-forcing its
    prompt tail (the next sampled token is real output)."""
    tr = eng.tracer
    if tr is not None:
        tr.instant("fill_done", pid=PID_REQUESTS, tid=req.rid,
                   cat="lifecycle")


def retired(eng, req, outcome: str):
    """Terminal outcome from a slot: close the decode span."""
    tr = eng.tracer
    if tr is None:
        return
    now = eng.clock()
    start = req.t_first if req.t_first is not None else req.t_bind
    if start is not None:
        tr.complete("decode", start, now, pid=PID_REQUESTS, tid=req.rid,
                    cat="lifecycle",
                    args=dict(outcome=outcome,
                              tokens=len(req.out_tokens or [])))
    tr.instant("retire", pid=PID_REQUESTS, tid=req.rid, cat="lifecycle",
               args=dict(outcome=outcome))


def preempted(eng, req, s: int):
    """Evicted mid-flight: close the decode span as a preemption and
    restart the request's queue clock — the resume renders as a fresh
    queue/prefill/decode triple on the same row."""
    tr = eng.tracer
    if tr is None:
        return
    now = eng.clock()
    start = req.t_first if req.t_first is not None else req.t_bind
    if start is not None:
        tr.complete("decode", start, now, pid=PID_REQUESTS, tid=req.rid,
                    cat="lifecycle",
                    args=dict(outcome="preempt",
                              tokens=len(req.out_tokens or [])))
    tr.instant("preempt", pid=PID_REQUESTS, tid=req.rid, cat="lifecycle",
               args=dict(slot=s))
    req.t_enqueue, req.t_bind, req.t_first = now, None, None


def shed(eng, req, retried: bool):
    """Admission-time shed (terminal or retried), tenant-labeled."""
    eng.registry.counter("serve.shed_by_tenant", tenant=req.tenant).inc()
    tr = eng.tracer
    if tr is not None:
        tr.instant("shed_retry" if retried else "shed", pid=PID_REQUESTS,
                   tid=req.rid, cat="lifecycle",
                   args=dict(tenant=req.tenant, retries=req.retries))


def settled(eng, req, outcome: str):
    """Terminal outcome without ever taking a slot (expiry at
    admission, zero-budget completion)."""
    tr = eng.tracer
    if tr is not None:
        tr.instant("settle", pid=PID_REQUESTS, tid=req.rid,
                   cat="lifecycle", args=dict(outcome=outcome))


# ---------------------------------------------------------------------------
# Engine step loop
# ---------------------------------------------------------------------------

@contextmanager
def step_span(eng, phase: str, **args):
    """Engine-track span around one step-loop phase (admit pass,
    decode step, spec cycle, sampler sync); the duration also lands in
    the phase-labeled ``serve.step_ms`` histogram.  No-op (single
    attribute check) when the engine has no tracer."""
    tr = eng.tracer
    if tr is None:
        yield args
        return
    t0 = eng.clock()
    try:
        yield args
    finally:
        t1 = eng.clock()
        tr.complete(phase, t0, t1, cat="step", args=args or None)
        eng.registry.histogram("serve.step_ms",
                               phase=phase).observe((t1 - t0) * 1e3)


def page_event(eng, kind: str, **args):
    """Page-machinery instant (alloc / cow / trim / pressure) plus a
    pages-in-use counter sample for the Perfetto counter track."""
    tr = eng.tracer
    if tr is None:
        return
    tr.instant(kind, cat="pages", args=args or None)
    if eng.paged:
        tr.counter("pages_in_use",
                   {"pages": eng.pool.pages_in_use()})


def export_trace(eng, path) -> str:
    """Write the engine's trace as Chrome/Perfetto trace_event JSON."""
    if eng.tracer is None:
        raise ValueError("engine was built without a tracer — pass "
                         "tracer=repro.obs.Tracer() to ServeEngine")
    return eng.tracer.export(path)


# ---------------------------------------------------------------------------
# Metrics digest (the body of ServeEngine.metrics())
# ---------------------------------------------------------------------------

def collect_metrics(eng) -> dict:
    """Assemble the engine's frozen metrics surface from the
    registry-backed groups.  Key set is a strict superset of the
    pre-registry dict (``tests/test_obs.py`` guards the frozen part);
    ``retrace_by_entry`` names which jitted body retraced instead of
    one summed integer."""
    m = dict(eng._m)
    entries = [("prefill_admit", eng._prefill_admit),
               ("admit_one", eng._admit_one),
               ("prefill1", eng._prefill1),
               ("decode", eng._decode)]
    m["prefill_calls"] = (eng._prefill_admit.calls
                          + eng._admit_one.calls + eng._prefill1.calls)
    m["prefill_traces"] = eng._prefill_admit.traces
    m["prefill_traces_single"] = (eng._admit_one.traces
                                  + eng._prefill1.traces)
    m["decode_traces"] = eng._decode.traces
    m["paged"] = eng.paged
    m["mesh"] = dict(eng.mesh.shape) if eng.mesh is not None else None
    m["prefill_chunk"] = eng.prefill_chunk or 0
    if eng.paged:
        entries += [("prefill_paged", eng._prefill_paged),
                    ("decode_paged", eng._decode_paged)]
        m["prefill_calls"] += eng._prefill_paged.calls
        m["prefill_traces"] += eng._prefill_paged.traces
        m["decode_traces"] += eng._decode_paged.traces
        m["page_size"] = eng.page_size
        m["pages_total"] = eng.n_pages - 1       # minus the trash page
        m["pages_in_use"] = eng.pool.pages_in_use()
        m["pages_peak"] = eng.pool.in_use_peak
        m["page_bytes"] = eng.page_bytes()
        # peak_cache_bytes counts *pinned* pages — the provisioning
        # signal a deployment would size n_pages from.  The engine's
        # actual device allocation is alloc_cache_bytes (the full
        # pool; with the deadlock-free default sizing that exceeds
        # the dense cache — pass n_pages to provision to peak+slack)
        m["peak_cache_bytes"] = eng.pool.in_use_peak * eng.page_bytes()
        m["alloc_cache_bytes"] = sum(leaf.nbytes
                                     for leaf in eng._store.values())
        m["page_allocs"] = eng.pool.alloc_count
        m["cow_copies"] = eng.pool.cow_copies
        m["page_evictions"] = eng.pool.evictions
        m["prefix_index_blocks"] = len(eng.pool.index)
        m["prefix_lookups"] = eng.pool.prefix_lookups
        m["prefix_block_hits"] = eng.pool.prefix_block_hits
    m["retrace_count"] = sum(max(0, c.traces - 1) for _, c in entries)
    by_entry = {name: max(0, c.traces - 1) for name, c in entries}
    m["buckets"] = list(eng.buckets)
    m["faults"] = (eng.faults.metrics()
                   if eng.faults is not None else None)
    m["spec"] = eng._spec is not None
    if eng._spec is not None:
        m.update(eng._spec.metrics())
        m["accept_rate"] = (m["accepted_tokens"]
                            / max(m["proposed_tokens"], 1))
        # share of emitted tokens that the draft proposed (the rest
        # are prefill first-tokens and verify corrections/bonuses);
        # uses the emitted count, not acceptances — a burst cut by a
        # budget or deadline accepts more than it emits
        m["draft_share"] = (m["emitted_draft_tokens"]
                            / max(m["tokens_generated"], 1))
        by_entry.update({name: max(0, c.traces - 1)
                         for name, c in eng._spec.trace_entries()})
    m["retrace_by_entry"] = by_entry
    m["tokens_per_step"] = (m["tokens_generated"]
                            / max(m["decode_steps"], 1))
    dt = m["serve_time_s"]
    m["tokens_per_s"] = (m["tokens_generated"] / dt) if dt > 0 else 0.0
    if eng.tracer is not None:
        m["trace"] = dict(events=len(eng.tracer.events()),
                          dropped=eng.tracer.dropped,
                          capacity=eng.tracer.capacity)
    return m
