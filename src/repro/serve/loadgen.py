"""Open-loop load generation + latency-percentile reporting.

Closed-loop benchmarks (hand the engine N requests, divide by wall
time) hide exactly the failure modes production serving cares about:
queueing behind a long prefill, burst absorption, tail latency.  This
module generates *open-loop* traffic — arrivals follow a seeded random
process and do not wait for the engine — and reports the distribution
tails:

* :class:`TrafficConfig` + :func:`make_trace` — a reproducible trace of
  ``(arrival_offset_s, Request)`` pairs: Poisson or bursty arrivals,
  log-normal long-tail prompt lengths, and a shared-prefix mixture (a
  fraction of requests reuse one of ``n_prefixes`` common prefixes, the
  workload the paged prefix index monetizes).
* :class:`ArrivalFeed` — the open-loop valve: the engine's serve loop
  polls it with the engine clock and receives the requests whose
  arrival time has passed (same-time arrivals are released EDF-ordered).
* :func:`summarize` — p50/p95/p99 TTFT (arrival to first token),
  queue delay (arrival to slot admission), and per-token decode latency
  from the per-request timestamp records that
  :meth:`.scheduler.Scheduler.run_traffic` collects.

Everything is driven by the engine's injectable ``clock`` — tests run
traffic against a fake clock without monkeypatching.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.obs import Histogram, dist_ms
from .slots import Request


@dataclasses.dataclass
class TrafficConfig:
    """Seeded open-loop workload description."""
    n_requests: int = 100
    process: str = "poisson"       # "poisson" | "bursty"
    rate: float = 16.0             # mean arrivals per second
    burst_size: int = 8            # bursty: simultaneous arrivals per burst
    prompt_len_median: int = 12    # log-normal long-tail prompt lengths
    prompt_len_sigma: float = 0.6
    prompt_len_max: int = 48
    shared_prefix_frac: float = 0.5   # fraction reusing a common prefix
    n_prefixes: int = 4
    prefix_len: int = 16
    max_new_tokens: int = 8
    vocab_size: int = 256
    deadline_s: Optional[float] = None   # per-request SLO, relative to arrival
    seed: int = 0

    def workload(self) -> dict:
        """JSON-serializable record of the generated workload (lands in
        BENCH_serve.json next to the percentiles it produced)."""
        return dataclasses.asdict(self)


def _arrival_offsets(cfg: TrafficConfig, rng) -> np.ndarray:
    if cfg.process == "poisson":
        gaps = rng.exponential(1.0 / cfg.rate, cfg.n_requests)
        times = np.cumsum(gaps)
    elif cfg.process == "bursty":
        # bursts of burst_size simultaneous arrivals; burst inter-arrival
        # keeps the same long-run rate as the Poisson process
        n_bursts = -(-cfg.n_requests // cfg.burst_size)
        gaps = rng.exponential(cfg.burst_size / cfg.rate, n_bursts)
        burst_t = np.cumsum(gaps)
        times = np.repeat(burst_t, cfg.burst_size)[:cfg.n_requests]
    else:
        raise ValueError(f"unknown arrival process: {cfg.process!r}")
    return times - times[0]        # first request arrives at t=0


def make_trace(cfg: TrafficConfig,
               rid_base: int = 0) -> List[Tuple[float, Request]]:
    """Generate the seeded trace: ``[(arrival_offset_s, Request)]``,
    sorted by arrival offset."""
    rng = np.random.default_rng(cfg.seed)
    times = _arrival_offsets(cfg, rng)
    prefixes = [rng.integers(1, cfg.vocab_size, cfg.prefix_len)
                .astype(np.int32) for _ in range(cfg.n_prefixes)]
    trace = []
    for i in range(cfg.n_requests):
        n = int(round(cfg.prompt_len_median
                      * math.exp(cfg.prompt_len_sigma
                                 * rng.standard_normal())))
        shared = (cfg.shared_prefix_frac > 0
                  and rng.random() < cfg.shared_prefix_frac)
        if shared:
            tail_n = max(1, min(n, cfg.prompt_len_max - cfg.prefix_len))
            pre = prefixes[int(rng.integers(cfg.n_prefixes))]
            tail = rng.integers(1, cfg.vocab_size, tail_n).astype(np.int32)
            prompt = np.concatenate([pre, tail])
        else:
            n = max(1, min(n, cfg.prompt_len_max))
            prompt = rng.integers(1, cfg.vocab_size, n).astype(np.int32)
        trace.append((float(times[i]),
                      Request(rid=rid_base + i, prompt=prompt,
                              max_new_tokens=cfg.max_new_tokens,
                              rel_deadline=cfg.deadline_s)))
    return trace


class ArrivalFeed:
    """Open-loop arrival valve for ``ServeEngine.serve(feed=...)``.

    The first ``poll(now)`` anchors the trace's t=0 at ``now``; each
    later poll releases every request whose absolute arrival time has
    passed (simultaneous arrivals EDF-ordered).  ``record`` (if given)
    is called with ``(rid, absolute_arrival_time)`` as each request is
    released — the arrival timestamp latency percentiles measure from.
    """

    def __init__(self, trace: List[Tuple[float, Request]],
                 record: Optional[Callable[[int, float], None]] = None):
        self._items = sorted(trace, key=lambda it: it[0])
        self._i = 0
        self.t0: Optional[float] = None
        self.record = record

    def poll(self, now: float) -> List[Request]:
        if self.t0 is None:
            self.t0 = now
        out = []
        while (self._i < len(self._items)
               and self.t0 + self._items[self._i][0] <= now):
            offset, req = self._items[self._i]
            self._i += 1
            t_arr = self.t0 + offset
            if req.arrival is None:
                # first release stamps arrival and resolves a relative
                # SLO into an absolute deadline; a shed-retried
                # re-release keeps both (the client has been waiting
                # since the original arrival)
                req.arrival = t_arr
                if req.rel_deadline is not None and req.deadline is None:
                    req.deadline = t_arr + req.rel_deadline
            if self.record is not None:
                self.record(req.rid, t_arr)
            out.append(req)
        # same-poll arrivals honor EDF ordering before hitting the FIFO
        out.sort(key=lambda r: (r.deadline if r.deadline is not None
                                else float("inf")))
        return out

    def push(self, t_abs: float, req: Request):
        """Re-schedule a request (shed retry-after): it re-enters the
        open loop at absolute time ``t_abs`` through the same valve —
        inserted past the cursor so the remaining tail stays sorted."""
        off = t_abs - self.t0 if self.t0 is not None else t_abs
        keys = [it[0] for it in self._items[self._i:]]
        j = self._i + bisect.bisect_right(keys, off)
        self._items.insert(j, (off, req))

    def pending(self) -> bool:
        return self._i < len(self._items)

    def next_time(self) -> Optional[float]:
        if self.t0 is None or not self.pending():
            return None
        return self.t0 + self._items[self._i][0]


def summarize(records: dict) -> dict:
    """Latency percentiles from per-request timestamp records
    (``{rid: {arrival, admit, first, end, tokens}}`` — absolute engine
    clock, as collected by :meth:`.scheduler.Scheduler.run_traffic`).

    * ``ttft_ms`` — arrival to first emitted token,
    * ``queue_delay_ms`` — arrival to slot admission (the open-loop
      queueing cost: prefill time is excluded),
    * ``per_token_ms`` — steady decode latency, (end - first) over the
      tokens after the first.

    Every percentile is zero (never NaN) on empty samples — the
    hardening lives in :func:`repro.obs.never_nan_percentile`, shared
    with the benchmark reporters — so a fully shed overload run still
    produces a valid report.  ``outcomes`` tallies per-request terminal
    states (completed / expired / truncated / shed) plus shed-retry and
    preemption totals when the records carry them.  ``hists`` carries
    the same three distributions as fixed-bucket
    :class:`repro.obs.Histogram` snapshots (mergeable across runs,
    unlike percentiles).
    """
    recs = list(records.values())
    done = [r for r in recs if r.get("end") is not None]
    ttft = [r["first"] - r["arrival"] for r in recs
            if r.get("first") is not None and r.get("arrival") is not None]
    queue_delay = [r["admit"] - r["arrival"] for r in recs
                   if r.get("admit") is not None
                   and r.get("arrival") is not None]
    per_token = [(r["end"] - r["first"]) / (r["tokens"] - 1) for r in done
                 if r.get("first") is not None and r.get("tokens", 0) > 1]
    tokens = sum(r.get("tokens", 0) for r in recs)
    ends = [r["end"] for r in done]
    starts = [r["arrival"] for r in recs if r.get("arrival") is not None]
    duration = (max(ends) - min(starts)) if ends and starts else 0.0
    outcomes: dict = {}
    for r in recs:
        o = r.get("outcome")
        if o is not None:
            outcomes[o] = outcomes.get(o, 0) + 1
    # survivors = requests that produced their full output despite the
    # overload; their tail TTFT is the headline SLO number
    surv_ttft = [r["first"] - r["arrival"] for r in recs
                 if r.get("outcome") == "completed"
                 and r.get("first") is not None
                 and r.get("arrival") is not None]
    return {
        "submitted": len(recs),
        "completed": len(done),
        "tokens": tokens,
        "duration_s": duration,
        "tokens_per_s": (tokens / duration) if duration > 0 else 0.0,
        "ttft_ms": dist_ms(ttft),
        "queue_delay_ms": dist_ms(queue_delay),
        "per_token_ms": dist_ms(per_token),
        "outcomes": outcomes,
        "survivor_ttft_ms": dist_ms(surv_ttft),
        "retries": sum(r.get("retries", 0) for r in recs),
        "preempts": sum(r.get("preempts", 0) for r in recs),
        "hists": {
            name: Histogram.from_samples(1e3 * x for x in xs).snapshot()
            for name, xs in (("ttft_ms", ttft),
                             ("queue_delay_ms", queue_delay),
                             ("per_token_ms", per_token))},
    }
