"""Overload response: SLO-aware shedding, quotas, and slot preemption.

The engine's serve loop stays thin (RPR005, line budget); everything it
does *under pressure* lives here as free functions over the engine +
run state (DESIGN.md §16):

* :class:`SLOAdmission` — the admission-time SLO gate.  It keeps a
  sliding window of observed queue delays (admit − arrival, the same
  quantity :func:`.loadgen.summarize` reports percentiles of), and
  sheds a request at the head of the queue when
  ``now + margin · delay_estimate > deadline`` — the request is doomed;
  rejecting it early returns its slot time to requests that can still
  make their SLO.  Shed requests get a seeded, jittered, exponential
  ``retry-after`` surfaced to closed-loop clients via ``on_shed``;
  after ``retry_max`` re-arrivals the shed is terminal.  It also owns
  per-tenant in-flight token quotas (acquired at bind, released at
  finish/preempt) and the weighted-fairness virtual time the scheduler
  uses as a secondary heap key.
* :func:`pick_victim` / :func:`preempt_slot` — the backpressure
  response.  The victim is the active slot with the *latest* deadline
  (no deadline = infinitely late), breaking ties toward the fewest
  emitted tokens (least recompute lost).  Preemption registers the
  victim's full KV blocks in the paged prefix index before releasing
  its page refs, re-queues the request with ``resume=True`` in
  deadline order, and the next admission rebuilds its state — paged
  resumes prefix-hit the just-registered pages; dense resumes recompute
  via teacher-forced prefill.  Greedy outputs are bit-identical either
  way because the recomputed KV is exactly the KV that was released.
* :func:`relieve_pressure` — the engine's ``PagePressure`` handler:
  preempt one victim and let the loop retry the step.  A sole active
  slot that can never fit another page (its own length exceeds the
  pool) is truncated instead of self-preempting forever.
* :func:`shed_request` / :func:`never_admissible` — terminal-shed
  bookkeeping and the provably-unadmittable check behind the loop's
  no-progress guard (a request larger than the whole pool or its
  tenant's whole quota can never bind; waiting will not help).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from . import instrument
from .slots import effective_prompt, empty_tokens


def request_tokens(req) -> int:
    """Admission cost of a request in cache positions: its (effective)
    prompt plus everything it may still generate — what a bound slot
    can end up holding.  Quotas and capacity checks both use it."""
    emitted = len(req.out_tokens or [])
    return len(req.prompt) + emitted + max(req.max_new_tokens - emitted, 0)


@dataclasses.dataclass
class SLOConfig:
    """SLO-aware admission policy knobs."""
    margin: float = 1.0            # shed when now + margin*est > deadline
    window: int = 64               # queue-delay observations kept
    pct: float = 90.0              # window percentile used as the estimate
    retry_base_s: float = 0.05     # jittered exponential retry-after base
    retry_max: int = 3             # re-arrivals before a shed is terminal
    quota_tokens: int = 0          # per-tenant in-flight tokens (0 = off)
    quotas: dict = dataclasses.field(default_factory=dict)   # per-tenant
    weights: dict = dataclasses.field(default_factory=dict)  # fairness
    seed: int = 0


class SLOAdmission:
    """Queue-delay estimator + shed gate + tenant quotas + fair vtime."""

    def __init__(self, cfg: Optional[SLOConfig] = None):
        self.cfg = cfg or SLOConfig()
        self._delays = deque(maxlen=self.cfg.window)
        self._inflight: dict = {}      # tenant -> bound tokens
        self._vtime: dict = {}         # tenant -> virtual time
        self._rng = np.random.default_rng(self.cfg.seed)
        self._reg = None               # set by bind_registry at engine attach
        self._hist = None
        self._est = None

    def bind_registry(self, registry):
        """Attach the engine's metrics registry: queue delays land in a
        ``slo.queue_delay_ms`` histogram and the current estimate in a
        gauge, alongside the engine's own groups."""
        self._reg = registry
        self._hist = registry.histogram("slo.queue_delay_ms")
        self._est = registry.gauge("slo.queue_delay_est_s")

    # -- queue-delay estimate -------------------------------------------------
    def observe(self, delay_s: float):
        self._delays.append(max(float(delay_s), 0.0))
        if self._hist is not None:
            self._hist.observe(max(float(delay_s), 0.0) * 1e3)
            self._est.set(self.estimate())

    def estimate(self) -> float:
        if not self._delays:
            return 0.0
        return float(np.percentile(np.asarray(self._delays, np.float64),
                                   self.cfg.pct))

    def should_shed(self, req, now: float) -> bool:
        if req.deadline is None:
            return False
        return now + self.cfg.margin * self.estimate() > req.deadline

    def retry_after(self, req) -> float:
        """Seeded jittered exponential backoff for this shed (retries
        was already incremented, so the first retry uses the base)."""
        back = self.cfg.retry_base_s * (2.0 ** max(req.retries - 1, 0))
        return back * (0.5 + float(self._rng.random()))

    # -- per-tenant quotas ----------------------------------------------------
    def quota_for(self, tenant: str) -> int:
        return int(self.cfg.quotas.get(tenant, self.cfg.quota_tokens))

    def quota_ok(self, req) -> bool:
        q = self.quota_for(req.tenant)
        if q <= 0:
            return True
        return self._inflight.get(req.tenant, 0) + request_tokens(req) <= q

    def acquire(self, req):
        self._inflight[req.tenant] = (self._inflight.get(req.tenant, 0)
                                      + request_tokens(req))
        self._track_inflight(req.tenant)

    def release(self, req):
        left = self._inflight.get(req.tenant, 0) - request_tokens(req)
        self._inflight[req.tenant] = max(left, 0)
        self._track_inflight(req.tenant)

    def _track_inflight(self, tenant: str):
        if self._reg is not None:
            self._reg.gauge("slo.inflight_tokens",
                            tenant=tenant).set(self._inflight[tenant])

    # -- weighted fairness ----------------------------------------------------
    def fair_key(self, req) -> float:
        """Start-time fair queuing: each submission advances its
        tenant's virtual time by cost/weight; the pre-advance value is
        the request's secondary sort key, so a heavy tenant's backlog
        sorts behind a light tenant's at equal deadlines."""
        w = float(self.cfg.weights.get(req.tenant, 1.0))
        v = self._vtime.get(req.tenant, 0.0)
        self._vtime[req.tenant] = v + request_tokens(req) / max(w, 1e-9)
        return v


# ---------------------------------------------------------------------------
# Preemption
# ---------------------------------------------------------------------------

def _deadline(req) -> float:
    return req.deadline if req.deadline is not None else float("inf")


def pick_victim(st, exclude: Optional[int] = None) -> Optional[int]:
    """Latest-deadline active slot, ties toward fewest emitted tokens
    (least recompute thrown away), then the highest slot index.

    ``exclude`` names the slot whose allocation raised the pressure:
    preempting the requester itself frees nothing for anyone else —
    the loop would re-admit it and hit the same wall (a livelock, not
    backpressure) — so it is only eligible when it is the sole active
    slot."""
    cands = [s for s in range(st.n) if st.active[s]]
    if exclude is not None and len(cands) > 1:
        cands = [s for s in cands if s != exclude]
    if not cands:
        return None
    return max(cands, key=lambda s: (_deadline(st.req[s]),
                                     -len(st.req[s].out_tokens or []), s))


def preempt_slot(eng, run, s: int):
    """Release slot ``s`` and re-queue its request for a later resume.

    The stepper hook runs *before* the slot clears: the paged stepper
    registers every full KV block (prompt and generated tokens alike)
    in the prefix index under the effective-sequence hash chain, so the
    resume's prefix-hit admission maps the same physical pages back and
    only recomputes the partial tail block.  The request re-enters the
    queue in deadline order with ``resume=True``; its ``out_tokens``
    survive and admission treats prompt+out as the prompt."""
    st = run.st
    req = st.req[s]
    eng._m["preempted"] += 1
    req.preempts += 1
    instrument.preempted(eng, req, s)
    if eng.slo is not None:
        eng.slo.release(req)
    eng._stepper.preempt(st, s)
    st.clear(s)
    req.resume = True
    dl = _deadline(req)
    pos = next((i for i, r in enumerate(run.queue) if _deadline(r) > dl),
               len(run.queue))
    run.queue.insert(pos, req)


def relieve_pressure(eng, run, pressure) -> bool:
    """Handle one :class:`.pages.PagePressure` from a step or an
    admission reservation: preempt the victim and let the loop retry.
    Returns False only when there is nothing to preempt (pressure during
    admission with no active slots — the retry itself is the response,
    the fault or transient that vetoed the allocation has passed)."""
    eng._m["pressure_events"] += 1
    st = run.st
    victim = pick_victim(st, exclude=pressure.slot)
    if victim is None:
        return False
    if pressure.slot == victim and sum(st.active) == 1 \
            and eng._stepper.slot_overflows(st, victim):
        # sole active slot and its own sequence can no longer fit: a
        # self-preempt would resume into the same wall forever — cut it
        # at the tokens produced so far instead
        eng._finish(run, victim, counter="truncated")
        return True
    preempt_slot(eng, run, victim)
    return True


# ---------------------------------------------------------------------------
# Shedding
# ---------------------------------------------------------------------------

def shed_request(eng, req, results, terminal: bool = False) -> None:
    """Shed at admission time.  With retry budget left and an
    ``on_shed`` hook (the closed-loop client seam), the request is
    handed back with a jittered retry-after and re-enters through the
    arrival feed; otherwise — or when ``terminal`` says retrying can
    never help (the no-progress guard) — the shed is final: empty
    output (or the tokens already produced, for a resumed request),
    counted exactly once."""
    slo = eng.slo
    if (not terminal and slo is not None and req.on_shed is not None
            and req.retries < slo.cfg.retry_max):
        req.retries += 1
        eng._m["shed_retried"] += 1
        instrument.shed(eng, req, retried=True)
        req.on_shed(req, slo.retry_after(req))
        return
    out = (np.asarray(req.out_tokens, np.int32) if req.out_tokens
           else empty_tokens())
    req.outcome = "shed"
    results[req.rid] = out
    eng._m["shed"] += 1
    instrument.shed(eng, req, retried=False)
    if req.on_finish:
        req.on_finish(req.rid, out)


def never_admissible(eng, req) -> Optional[str]:
    """Reason this request can *never* bind (so waiting is pointless),
    or None.  Used by the serve loop's no-progress guard: with no slot
    active every quota is free and the pool is at its emptiest — if the
    request still cannot fit, it never will."""
    if eng.slo is not None:
        q = eng.slo.quota_for(req.tenant)
        if 0 < q < request_tokens(req):
            return (f"needs {request_tokens(req)} tokens > tenant "
                    f"{req.tenant!r} quota {q}")
    need = eng._stepper.pages_needed(len(effective_prompt(req)) + 1)
    if need is not None and not eng._stepper.fits_pool(need):
        return f"needs {need} pages > pool capacity"
    return None
