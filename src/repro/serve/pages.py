"""Paged KV-cache block pool: allocator, refcounts, prefix index.

The serving engine's dense cache allocates ``n_slots * max_len`` KV
positions up front, so memory scales with the *worst-case* request and
identical system-prompt prefixes are re-prefilled per request.  Paged
attention (vLLM-style) fixes both: the physical cache is a pool of
fixed-size pages, each slot maps logical token blocks to physical pages
through a per-slot page table, and a prefix index keyed on chained
token-block hashes lets requests that share a prompt prefix map their
leading pages to the *same* physical blocks.

Everything in this module is host-side bookkeeping (plain Python / NumPy
over int page ids); the device-side page store and the jitted
gather/scatter ops live in ``models/common.py`` and
``serve/cache_ops.py``.  Under a sharded engine (DESIGN.md §13) the
page *stores* are sharded on the KV-head axis while page *tables* stay
replicated — every device holds the same id -> page mapping and gathers
its own head slice, so the allocator/refcount/prefix logic here is
identical for single-device and tensor-parallel serving (page ids are
global, never per-device).

Invariants (DESIGN.md §10):

* Physical page 0 is the **trash page**: never allocated, permanently
  pinned.  Unmapped page-table entries point at it, so masked writes
  from inactive slots land somewhere harmless.
* ``ref[p]`` counts owners: each slot mapping the page holds one ref,
  and a prefix-index entry holds one ref.  A page returns to the free
  list only at refcount zero.
* A slot only ever *writes* a page it owns exclusively (refcount 1 and
  unregistered); the engine copies-on-write before any divergent write
  into a shared page.
* Index entries whose page has no other owner are evictable: allocation
  falls back to dropping one of them when the free list is empty, so
  the prefix cache can never deadlock the pool.
"""
from __future__ import annotations

import hashlib
from typing import List, Optional, Sequence

import numpy as np

from repro.obs import MetricsRegistry


def _pool_counter(name: str):
    """Property pair keeping the old attribute surface
    (``pool.cow_copies += 1``) while every mutation lands in the
    registry-backed group."""
    return property(lambda self: self.m[name],
                    lambda self, v: self.m.__setitem__(name, v))


class PoolExhausted(RuntimeError):
    """Terminal pool-exhaustion error for *direct* :meth:`PagePool.alloc`
    callers (tests, offline tools).  The serve path never raises this:
    steppers allocate through :meth:`PagePool.try_alloc` and convert a
    ``None`` into :class:`PagePressure`, which the engine resolves by
    preempting a slot (DESIGN.md §16)."""


class PagePressure(Exception):
    """Backpressure signal: a serve-path page allocation could not be
    satisfied right now.  Not an error — the engine catches it, preempts
    the lowest-priority slot (or sheds, as a last resort), and retries
    the step.  ``slot`` is the slot that needed the page (None during
    admission reservation)."""

    def __init__(self, slot: Optional[int] = None, needed: int = 1):
        super().__init__(f"page pressure (slot={slot}, needed={needed})")
        self.slot = slot
        self.needed = needed


def block_hashes(tokens: Sequence[int], page_size: int) -> List[bytes]:
    """Chained hash per full token block: ``h[i] = H(h[i-1] || block_i)``.

    Chaining makes each hash identify the whole prefix up to and
    including block ``i``, so a single dict lookup per block walks the
    shared-prefix chain.  Only *full* blocks are hashed — a partial tail
    block is never shared.
    """
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
    out: List[bytes] = []
    h = b""
    for i in range(len(toks) // page_size):
        h = hashlib.sha1(h + toks[i * page_size:(i + 1) * page_size]
                         .tobytes()).digest()
        out.append(h)
    return out


class PagePool:
    """Fixed-capacity page allocator with refcounts and a prefix index."""

    TRASH = 0

    def __init__(self, n_pages: int, page_size: int, faults=None,
                 registry=None):
        if n_pages < 2:
            raise ValueError("need at least the trash page plus one "
                             f"allocatable page, got n_pages={n_pages}")
        self.n_pages = n_pages
        self.page_size = page_size
        # fault-injection seam (serve/faults.py): when set, alloc_ok()
        # may deterministically veto an allocation so chaos tests can
        # exercise the backpressure/preemption protocol on a full bench
        self.faults = faults
        # pop() hands out ascending ids (cosmetic, but makes tests and
        # logs readable)
        self.free = list(range(n_pages - 1, 0, -1))
        self.ref = np.zeros(n_pages, np.int64)
        self.ref[self.TRASH] = 1          # pinned forever
        self.index: dict = {}             # block hash -> phys page
        self._page_hash: dict = {}        # phys page -> block hash
        # counters surfaced via ServeEngine.metrics(): a cache-kind
        # labeled group in the engine's registry (a standalone pool
        # gets a private registry so the surface is identical)
        reg = registry if registry is not None else MetricsRegistry()
        self.m = reg.group("pool", cache_kind="paged").init(
            alloc_count=0, cow_copies=0, evictions=0, prefix_lookups=0,
            prefix_block_hits=0, in_use_peak=0)

    alloc_count = _pool_counter("alloc_count")
    cow_copies = _pool_counter("cow_copies")
    evictions = _pool_counter("evictions")
    prefix_lookups = _pool_counter("prefix_lookups")
    prefix_block_hits = _pool_counter("prefix_block_hits")
    in_use_peak = _pool_counter("in_use_peak")

    # -- capacity ------------------------------------------------------------
    def pages_in_use(self) -> int:
        return self.n_pages - 1 - len(self.free)

    def evictable(self) -> int:
        """Prefix-index pages with no other owner — reclaimable on
        demand by :meth:`try_alloc`'s eviction fallback."""
        return sum(1 for p in self.index.values() if self.ref[p] == 1)

    def available(self) -> int:
        """Pages an allocator could obtain right now (free list plus
        index-only evictables).  Admission checks this *before* binding
        slots so a group reservation can only fail under injected
        faults, never from a miscounted capacity."""
        return len(self.free) + self.evictable()

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` cache positions."""
        return -(-int(n_tokens) // self.page_size)

    def try_alloc(self) -> Optional[int]:
        """Take a fresh page (refcount 1), or ``None`` when the pool is
        exhausted (after the index-eviction fallback) or an injected
        fault vetoes the allocation.  This is the *only* allocator on
        the serve path — exhaustion routes through the engine's
        backpressure protocol instead of an exception (DESIGN.md §16)."""
        if self.faults is not None and not self.faults.alloc_ok():
            return None
        if not self.free and not self._evict_one():
            return None
        p = self.free.pop()
        self.ref[p] = 1
        self.alloc_count += 1
        self.in_use_peak = max(self.in_use_peak, self.pages_in_use())
        return p

    def alloc(self) -> int:
        """Terminal-path variant of :meth:`try_alloc` for direct callers
        outside the serve loop; raises :class:`PoolExhausted` instead of
        returning ``None``."""
        p = self.try_alloc()
        if p is None:
            raise PoolExhausted(  # repro: noqa[RPR008] the protocol's own terminal path — serve steppers call try_alloc and never reach this
                f"page pool exhausted ({self.n_pages - 1} pages, "
                f"page_size={self.page_size}); raise n_pages")
        return p

    def _evict_one(self) -> bool:
        """Drop one prefix-index entry whose page has no other owner."""
        for h, p in list(self.index.items()):
            if self.ref[p] == 1:
                self._unregister(h, p)
                self.ref[p] = 0
                self.free.append(p)
                self.evictions += 1
                return True
        return False

    # -- refcounts -----------------------------------------------------------
    def incref(self, p: int):
        assert p != self.TRASH
        self.ref[p] += 1

    def decref(self, p: int):
        assert p != self.TRASH and self.ref[p] > 0, (p, self.ref[p])
        self.ref[p] -= 1
        if self.ref[p] == 0:
            h = self._page_hash.get(p)
            if h is not None:       # defensive; index normally holds a ref
                self._unregister(h, p)
            self.free.append(p)

    # -- prefix index --------------------------------------------------------
    def match(self, hashes: Sequence[bytes]) -> List[int]:
        """Longest cached prefix: physical pages for the leading blocks
        whose hash chain is indexed.  The caller owns one ref per
        returned page (already incref'd here)."""
        out: List[int] = []
        self.prefix_lookups += 1
        for h in hashes:
            p = self.index.get(h)
            if p is None:
                break
            out.append(p)
        for p in out:
            self.incref(p)
        self.prefix_block_hits += len(out)
        return out

    def lookup_blocks(self, hashes: Sequence[bytes]) -> int:
        """Non-acquiring variant of :meth:`match`: how many leading
        blocks are cached right now (admission grouping only)."""
        n = 0
        for h in hashes:
            if h not in self.index:
                break
            n += 1
        return n

    def register(self, h: bytes, p: int):
        """Publish page ``p`` as the block for hash ``h``.  The index
        holds its own ref, so the page survives slot retirement until
        evicted.  First registration wins; re-registering is a no-op."""
        if p == self.TRASH or h in self.index:
            return
        self.index[h] = p
        self._page_hash[p] = h
        self.incref(p)

    def _unregister(self, h: bytes, p: int):
        del self.index[h]
        del self._page_hash[p]

    def is_shared(self, p: int) -> bool:
        """True if writing ``p`` needs copy-on-write first: someone else
        (another slot or the prefix index) also owns it."""
        return p != self.TRASH and self.ref[p] > 1
