"""On-device batched token sampling for the serve loop.

One jitted call samples the whole decode batch: greedy, temperature, and
top-k are all expressed per-slot, so mixed-policy batches share a single
XLA program and the decode loop transfers one int32 per slot per step
instead of a vocab-size logits row.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(logits: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, key: jax.Array) -> jax.Array:
    """Sample one token per batch row.

    logits: (B, V) — may carry the -1e30 padded-vocab mask from
    :func:`~repro.models.common.logits_from_hidden`; masked columns have
    probability zero and are never the argmax.
    temperature: (B,) f32 — ``<= 0`` means greedy for that row.
    top_k: (B,) int32 — ``0`` disables top-k for that row; otherwise only
    the k highest logits stay eligible.
    key: PRNG key for the whole batch (rows draw independent noise).

    Returns (B,) int32.
    """
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    desc = jnp.sort(logits, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(
        desc, jnp.clip(top_k.astype(jnp.int32) - 1, 0, v - 1)[:, None],
        axis=-1)
    use_topk = (top_k > 0)[:, None]
    masked = jnp.where(use_topk & (logits < kth), -jnp.inf, logits)

    do_sample = temperature > 0
    scaled = masked / jnp.maximum(temperature, 1e-6)[:, None]
    # greedy rows skip the (potentially inf-scaled) division result
    scaled = jnp.where(do_sample[:, None], scaled, 0.0)
    drawn = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(do_sample, drawn, greedy)
