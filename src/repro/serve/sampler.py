"""On-device batched token sampling for the serve loop.

One jitted call samples the whole decode batch: greedy, temperature,
top-k, and top-p (nucleus) are all expressed per-slot, so mixed-policy
batches share a single XLA program and the decode loop transfers one
int32 per slot per step instead of a vocab-size logits row.

The speculative-decoding accept/resample step (:func:`spec_accept`)
lives here too: it consumes the draft's proposal distributions and the
target's verify logits and applies standard leftover-probability
rejection sampling (Leviathan et al.), so the emitted stream is an
exact sample from the target policy — and greedy output is
token-for-token identical to non-speculative decode.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _apply_top_k(logits: jax.Array, top_k: jax.Array) -> jax.Array:
    """Mask all but each row's k highest logits (k=0 disables)."""
    v = logits.shape[-1]
    desc = jnp.sort(logits, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(
        desc, jnp.clip(top_k.astype(jnp.int32) - 1, 0, v - 1)[:, None],
        axis=-1)
    use_topk = (top_k > 0)[:, None]
    return jnp.where(use_topk & (logits < kth), -jnp.inf, logits)


def _apply_top_p(scaled: jax.Array, top_p: jax.Array) -> jax.Array:
    """Nucleus mask on already temperature-scaled logits.

    Keeps, per row, the smallest set of highest-probability tokens whose
    cumulative probability reaches ``top_p`` (the top-1 token always
    survives).  ``top_p <= 0`` or ``>= 1`` disables the mask for that
    row.
    """
    probs = jax.nn.softmax(scaled, axis=-1)
    order = jnp.argsort(-probs, axis=-1)
    sorted_p = jnp.take_along_axis(probs, order, axis=-1)
    csum = jnp.cumsum(sorted_p, axis=-1)
    # token i (sorted) stays while the mass *before* it is < top_p
    keep_sorted = (csum - sorted_p) < top_p[:, None]
    inv = jnp.argsort(order, axis=-1)
    keep = jnp.take_along_axis(keep_sorted, inv, axis=-1)
    active = ((top_p > 0.0) & (top_p < 1.0))[:, None]
    return jnp.where(active & ~keep, -jnp.inf, scaled)


def policy_in_use(top_k, top_p) -> Tuple[bool, bool]:
    """Host-side "does any row actually use top-k / top-p" predicates.

    The single source of truth for the disable semantics (``top_k <= 0``,
    ``top_p <= 0`` or ``>= 1``): both the engine's jitted decode bodies
    and the speculative cycle specialize their compiled programs on
    these flags, and they must agree or the draft policy would diverge
    from the target policy.
    """
    import numpy as np
    tk, tp = np.asarray(top_k), np.asarray(top_p)
    return bool((tk > 0).any()), bool(((tp > 0) & (tp < 1)).any())


def sample_tokens(logits: jax.Array, temperature: jax.Array,
                  top_k: Optional[jax.Array], key: jax.Array,
                  top_p: Optional[jax.Array] = None) -> jax.Array:
    """Sample one token per batch row.

    logits: (B, V) — may carry the -1e30 padded-vocab mask from
    :func:`~repro.models.common.logits_from_hidden`; masked columns have
    probability zero and are never the argmax.
    temperature: (B,) f32 — ``<= 0`` means greedy for that row.
    top_k: (B,) int32 — ``0`` disables top-k for that row; otherwise only
    the k highest logits stay eligible.
    key: PRNG key for the whole batch (rows draw independent noise).
    top_p: optional (B,) f32 nucleus threshold — ``<= 0`` or ``>= 1``
    disables it for that row; applied after top-k on the
    temperature-scaled distribution.

    ``top_k``/``top_p`` may be ``None`` when the caller knows no row
    uses them: the full-vocab sort/argsort behind the masks is the
    expensive part of this function, and the serve engine specializes
    it away per batch (the decode loop runs this every token).

    Returns (B,) int32.
    """
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    masked = logits if top_k is None else _apply_top_k(logits, top_k)

    do_sample = temperature > 0
    scaled = masked / jnp.maximum(temperature, 1e-6)[:, None]
    if top_p is not None:
        scaled = _apply_top_p(scaled, top_p)
    # greedy rows skip the (potentially inf-scaled) division result
    scaled = jnp.where(do_sample[:, None], scaled, 0.0)
    drawn = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(do_sample, drawn, greedy)


def policy_probs(logits: jax.Array, temperature: jax.Array,
                 top_k: Optional[jax.Array] = None,
                 top_p: Optional[jax.Array] = None) -> jax.Array:
    """The per-row sampling policy as an explicit distribution.

    Returns (B, V) probabilities: softmax of the temperature-scaled,
    top-k/top-p-masked logits for sampling rows, and an exact one-hot at
    the argmax for greedy rows (``temperature <= 0``).  This is the
    distribution :func:`sample_tokens` draws from, materialized so the
    speculative accept/resample rule can evaluate p(x)/q(x) ratios.

    ``top_k``/``top_p`` may be ``None`` when the caller knows no row in
    the batch uses them — the full-vocab sort/argsort those masks cost
    is the expensive part of this function, so the speculative cycle
    specializes it away per batch.
    """
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    onehot = (jnp.arange(v)[None, :]
              == jnp.argmax(logits, axis=-1)[:, None]).astype(jnp.float32)
    masked = logits if top_k is None else _apply_top_k(logits, top_k)
    scaled = masked / jnp.maximum(temperature, 1e-6)[:, None]
    if top_p is not None:
        scaled = _apply_top_p(scaled, top_p)
    probs = jax.nn.softmax(scaled, axis=-1)
    return jnp.where((temperature > 0)[:, None], probs, onehot)


def draw_from_probs(probs: jax.Array, key: jax.Array) -> jax.Array:
    """Categorical draw from explicit probabilities (last axis).

    Zero-probability entries are exactly excluded (``log 0 = -inf``); a
    one-hot row draws its hot index deterministically, so greedy rows
    fed through :func:`policy_probs` stay deterministic.
    """
    return jax.random.categorical(key, jnp.log(probs), axis=-1) \
              .astype(jnp.int32)


def spec_accept(draft_tokens: jax.Array, draft_probs: jax.Array,
                target_logits: jax.Array, temperature: jax.Array,
                top_k: Optional[jax.Array], top_p: Optional[jax.Array],
                key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Leftover-probability rejection sampling over one speculative burst.

    draft_tokens: (B, K) int32 — draft proposals d_1..d_K.
    draft_probs: (B, K, V) — the draft *policy* distribution each
    proposal was drawn from (same temperature/top-k/top-p policy).
    target_logits: (B, K+1, V) — verify logits; position ``i`` is the
    target's next-token distribution after consuming the last committed
    token plus d_1..d_i.
    temperature/top_k/top_p: (B,) per-slot policy (shared with the draft).

    Returns ``(out_tokens (B, K+1), n_accept (B,))``: proposal ``d_{i+1}``
    is accepted with probability ``min(1, p_i(d)/q_i(d))``; the first
    rejected position resamples from ``norm(max(p - q, 0))``; if all K
    are accepted a bonus token is drawn from the target's last position.
    The emitted burst is ``out_tokens[:, :n_accept + 1]``.  Greedy rows
    (one-hot p and q) reduce to "accept while the draft token equals the
    target argmax, then emit the target argmax" — token-for-token
    identical to non-speculative greedy decode.
    """
    b, k = draft_tokens.shape
    v = target_logits.shape[-1]
    p = jax.vmap(policy_probs, in_axes=(1, None, None, None), out_axes=1)(
        target_logits.astype(jnp.float32), temperature, top_k, top_p)

    px = jnp.take_along_axis(p[:, :k], draft_tokens[..., None],
                             axis=-1)[..., 0]              # (B, K)
    qx = jnp.take_along_axis(draft_probs, draft_tokens[..., None],
                             axis=-1)[..., 0]              # (B, K)
    k_u, k_r, k_b = jax.random.split(key, 3)
    u = jax.random.uniform(k_u, (b, k))
    # accept iff u < p/q  <=>  u*q < p (q(x) > 0 since x ~ q); greedy
    # rows have q one-hot so this is exactly "draft == target argmax"
    accept = (u * qx) < px
    n_accept = jnp.cumprod(accept.astype(jnp.int32), axis=1).sum(axis=1)

    # leftover distribution per position; if p == q exactly the residual
    # is empty — that position is only ever read when rejected (p != q
    # at the drawn token), but guard the normalization anyway
    res = jnp.clip(p[:, :k] - draft_probs, 0.0, None)
    norm = res.sum(axis=-1, keepdims=True)
    res = jnp.where(norm > 0, res / jnp.maximum(norm, 1e-30), p[:, :k])
    resampled = draw_from_probs(res, k_r)                  # (B, K)
    bonus = draw_from_probs(p[:, k], k_b)                  # (B,)

    corrections = jnp.concatenate([resampled, bonus[:, None]], axis=1)
    padded = jnp.concatenate(
        [draft_tokens, jnp.zeros((b, 1), jnp.int32)], axis=1)
    idx = jnp.arange(k + 1)[None, :]
    out = jnp.where(idx < n_accept[:, None], padded, corrections)
    return out.astype(jnp.int32), n_accept.astype(jnp.int32)
