"""Admission scheduling in front of :class:`~repro.serve.engine.ServeEngine`.

The engine drains a FIFO of requests; the scheduler decides the FIFO.
It keeps an earliest-deadline-first priority queue (requests without a
deadline sort last, FIFO among themselves), attaches per-request
streaming callbacks, and exposes the engine's metrics snapshot.

Deadline semantics (enforced by the engine, ordered by the scheduler):

* a request whose deadline has already passed when it would be admitted
  **expires** — empty output, counted in ``metrics()["expired"]``;
* a running request whose deadline passes mid-decode is **truncated** at
  the tokens produced so far (``metrics()["truncated"]``).
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional

import numpy as np

from .engine import Request, ServeEngine
from .loadgen import ArrivalFeed, summarize


class RunResult(dict):
    """``{rid: tokens}`` mapping plus a ``summary`` attribute.

    ``summary`` carries the run-level digest — completion/expiry/
    truncation counts, throughput, and (when the engine runs
    speculatively) ``accept_rate``/``tokens_per_step``/``draft_share``
    plus per-request ``tokens_per_step`` — so callers don't have to
    reach into engine-level counters.  The summary is computed as a
    delta over the engine's metrics registry (DESIGN.md §17); the raw
    qualified-name delta rides along as ``registry_delta``.  Traffic
    runs (:meth:`Scheduler.run_traffic`) additionally attach
    ``records`` — per-request arrival/admit/first-token/finish
    timestamps — and a ``traffic`` percentile report.
    """
    summary: dict = {}
    records: dict = {}
    traffic: dict = {}
    registry_delta: dict = {}


class Scheduler:
    """EDF admission queue over a ServeEngine."""

    def __init__(self, engine: ServeEngine):
        self.engine = engine
        self._heap: list = []
        self._seq = itertools.count()
        self._queued_rids: set = set()
        self.last_summary: dict = {}

    @property
    def clock(self):
        """The engine's injectable deadline clock (one seam end-to-end:
        deadlines, traffic timestamps, and serve timing all read it)."""
        return self.engine.clock

    def submit(self, request: Request, *,
               deadline: Optional[float] = None,
               on_token: Optional[Callable[[int, int], None]] = None,
               on_finish: Optional[Callable[[int, np.ndarray], None]] = None,
               ) -> int:
        """Queue a request; returns its rid.

        ``deadline`` is an absolute ``time.time()`` cutoff.  ``on_token``
        is called as ``on_token(rid, token)`` for every generated token
        (streaming); ``on_finish(rid, tokens)`` once on completion,
        expiry, truncation, or shed.

        Duplicate rids are rejected: results are keyed by rid, so a
        double-queued id would silently drop one request's output.

        With an SLO-enabled engine the EDF key gains a secondary
        weighted-fairness component (per-tenant virtual time): at equal
        deadlines a heavy tenant's backlog sorts behind a light
        tenant's submissions."""
        if request.rid in self._queued_rids:
            raise ValueError(
                f"rid {request.rid} is already queued — results are "
                "keyed by rid, so reuse would drop one request's output")
        if deadline is not None:
            request.deadline = deadline
        if on_token is not None:
            request.on_token = on_token
        if on_finish is not None:
            request.on_finish = on_finish
        key = request.deadline if request.deadline is not None else float("inf")
        fair = (self.engine.slo.fair_key(request)
                if self.engine.slo is not None else 0.0)
        heapq.heappush(self._heap, (key, fair, next(self._seq), request))
        self._queued_rids.add(request.rid)
        return request.rid

    def pending(self) -> int:
        return len(self._heap)

    def run(self) -> RunResult:
        """Drain the queue through the engine in EDF order.

        Returns a :class:`RunResult`: ``{rid: np.ndarray of generated
        tokens}`` whose ``summary`` attribute digests the run — overall
        and per-request ``tokens_per_step`` and, for speculative
        engines, ``accept_rate``/``draft_share`` — instead of leaving
        those buried in engine-level counters."""
        reqs = [heapq.heappop(self._heap)[-1] for _ in range(len(self._heap))]
        self._queued_rids.clear()
        snap0 = self.engine.registry.snapshot()
        out = RunResult()
        if reqs:
            out.update(self.engine.serve(reqs))
        m = self.engine.metrics()
        # engine counters are engine-lifetime cumulative; the summary
        # digests *this* run, so report one registry delta against the
        # pre-run snapshot (a reused Scheduler must not re-report
        # earlier runs)
        delta = self.engine.registry.delta(snap0)
        out.registry_delta = delta
        d = lambda key: delta.get("serve." + key, 0)
        rids = {r.rid for r in reqs}
        per_req = {rid: tps
                   for rid, tps in self.engine.request_summary().items()
                   if rid in rids}
        tokens, steps = d("tokens_generated"), d("decode_steps")
        dt = d("serve_time_s")
        out.summary = {
            "requests": len(reqs),
            "completed": d("completed"),
            "expired": d("expired"),
            "truncated": d("truncated"),
            "shed": d("shed"),
            "preempted": d("preempted"),
            "resumed": d("resumed"),
            "tokens_generated": tokens,
            "tokens_per_s": (tokens / dt) if dt > 0 else 0.0,
            "tokens_per_step": tokens / max(steps, 1),
            "tokens_per_step_by_request": per_req,
            "spec": m["spec"],
        }
        if m["spec"]:
            ds = lambda key: delta.get("spec." + key, 0)
            out.summary.update(
                accept_rate=(ds("accepted_tokens")
                             / max(ds("proposed_tokens"), 1)),
                draft_share=(ds("emitted_draft_tokens") / max(tokens, 1)),
                spec_cycles=ds("spec_cycles"),
                spec_k=m["spec_k"],
                draft_kind=m["draft_kind"])
        self.last_summary = out.summary
        return out

    def run_traffic(self, trace) -> RunResult:
        """Drive the engine with an open-loop arrival trace
        (``[(arrival_offset_s, Request)]``, e.g. from
        :func:`.loadgen.make_trace`).

        Unlike :meth:`run`, requests are *not* all admitted up front:
        an :class:`.loadgen.ArrivalFeed` releases each one as its
        arrival time passes on the engine clock, so queueing is real.
        Per-request arrival / admission / first-token / finish
        timestamps are recorded and digested into p50/p95/p99 TTFT,
        queue-delay, and per-token-latency percentiles
        (``result.traffic``, raw records on ``result.records``)."""
        clock = self.engine.clock
        records: dict = {}
        items = sorted(trace, key=lambda it: it[0])
        for offset, req in items:
            rec = records[req.rid] = dict(
                scheduled=float(offset), arrival=None, admit=None,
                first=None, end=None, tokens=0, outcome=None,
                retries=0, preempts=0)
            prev_admit = req.on_admit
            prev_token = req.on_token
            prev_finish = req.on_finish

            def on_admit(rid, _rec=rec, _p=prev_admit):
                # first admit only: a preempted-and-resumed request's
                # queue delay is measured to its original slot grant
                if _rec["admit"] is None:
                    _rec["admit"] = clock()
                if _p:
                    _p(rid)

            def on_token(rid, tok, _rec=rec, _p=prev_token):
                if _rec["first"] is None:
                    _rec["first"] = clock()
                _rec["tokens"] += 1
                if _p:
                    _p(rid, tok)

            def on_finish(rid, out, _rec=rec, _req=req, _p=prev_finish):
                _rec["end"] = clock()
                _rec["outcome"] = _req.outcome
                _rec["retries"] = _req.retries
                _rec["preempts"] = _req.preempts
                _rec["tokens"] = len(out)
                if _p:
                    _p(rid, out)

            req.on_admit = on_admit
            req.on_token = on_token
            req.on_finish = on_finish
        # the arrival timestamp is the FIRST release — a shed-retried
        # request re-enters the feed but its latency still counts from
        # the original arrival (the client has been waiting since then)
        feed = ArrivalFeed(
            items,
            record=lambda rid, t: (
                records[rid].__setitem__("arrival", t)
                if records[rid]["arrival"] is None else None))
        # closed-loop retry seam: a shed request re-arrives after the
        # engine's jittered retry-after, through the same feed
        for _, req in items:
            if req.on_shed is None:
                req.on_shed = (lambda r, after, _f=feed, _c=clock:
                               _f.push(_c() + after, r))
        snap0 = self.engine.registry.snapshot()
        out = RunResult()
        out.update(self.engine.serve((), feed=feed))
        m = self.engine.metrics()
        delta = self.engine.registry.delta(snap0)
        out.registry_delta = delta
        d = lambda key: delta.get("serve." + key, 0)
        tokens, steps = d("tokens_generated"), d("decode_steps")
        dt = d("serve_time_s")
        out.summary = {
            "requests": len(items),
            "completed": d("completed"),
            "expired": d("expired"),
            "truncated": d("truncated"),
            "shed": d("shed"),
            "shed_retried": d("shed_retried"),
            "preempted": d("preempted"),
            "resumed": d("resumed"),
            "pressure_events": d("pressure_events"),
            "tokens_generated": tokens,
            "tokens_per_s": (tokens / dt) if dt > 0 else 0.0,
            "tokens_per_step": tokens / max(steps, 1),
            "spec": m["spec"],
        }
        out.records = records
        out.traffic = summarize(records)
        self.last_summary = out.summary
        return out

    def metrics(self) -> dict:
        return self.engine.metrics()
