"""Admission scheduling in front of :class:`~repro.serve.engine.ServeEngine`.

The engine drains a FIFO of requests; the scheduler decides the FIFO.
It keeps an earliest-deadline-first priority queue (requests without a
deadline sort last, FIFO among themselves), attaches per-request
streaming callbacks, and exposes the engine's metrics snapshot.

Deadline semantics (enforced by the engine, ordered by the scheduler):

* a request whose deadline has already passed when it would be admitted
  **expires** — empty output, counted in ``metrics()["expired"]``;
* a running request whose deadline passes mid-decode is **truncated** at
  the tokens produced so far (``metrics()["truncated"]``).
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional

import numpy as np

from .engine import Request, ServeEngine


class Scheduler:
    """EDF admission queue over a ServeEngine."""

    def __init__(self, engine: ServeEngine):
        self.engine = engine
        self._heap: list = []
        self._seq = itertools.count()

    def submit(self, request: Request, *,
               deadline: Optional[float] = None,
               on_token: Optional[Callable[[int, int], None]] = None,
               on_finish: Optional[Callable[[int, np.ndarray], None]] = None,
               ) -> int:
        """Queue a request; returns its rid.

        ``deadline`` is an absolute ``time.time()`` cutoff.  ``on_token``
        is called as ``on_token(rid, token)`` for every generated token
        (streaming); ``on_finish(rid, tokens)`` once on completion,
        expiry, or truncation."""
        if deadline is not None:
            request.deadline = deadline
        if on_token is not None:
            request.on_token = on_token
        if on_finish is not None:
            request.on_finish = on_finish
        key = request.deadline if request.deadline is not None else float("inf")
        heapq.heappush(self._heap, (key, next(self._seq), request))
        return request.rid

    def pending(self) -> int:
        return len(self._heap)

    def run(self) -> dict:
        """Drain the queue through the engine in EDF order.

        Returns {rid: np.ndarray of generated tokens}."""
        reqs = [heapq.heappop(self._heap)[2] for _ in range(len(self._heap))]
        if not reqs:
            return {}
        return self.engine.serve(reqs)

    def metrics(self) -> dict:
        return self.engine.metrics()
