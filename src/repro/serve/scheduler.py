"""Admission scheduling in front of :class:`~repro.serve.engine.ServeEngine`.

The engine drains a FIFO of requests; the scheduler decides the FIFO.
It keeps an earliest-deadline-first priority queue (requests without a
deadline sort last, FIFO among themselves), attaches per-request
streaming callbacks, and exposes the engine's metrics snapshot.

Deadline semantics (enforced by the engine, ordered by the scheduler):

* a request whose deadline has already passed when it would be admitted
  **expires** — empty output, counted in ``metrics()["expired"]``;
* a running request whose deadline passes mid-decode is **truncated** at
  the tokens produced so far (``metrics()["truncated"]``).
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional

import numpy as np

from .engine import Request, ServeEngine


class RunResult(dict):
    """``{rid: tokens}`` mapping plus a ``summary`` attribute.

    ``summary`` carries the run-level digest — completion/expiry/
    truncation counts, throughput, and (when the engine runs
    speculatively) ``accept_rate``/``tokens_per_step``/``draft_share``
    plus per-request ``tokens_per_step`` — so callers don't have to
    reach into engine-level counters.
    """
    summary: dict = {}


class Scheduler:
    """EDF admission queue over a ServeEngine."""

    def __init__(self, engine: ServeEngine):
        self.engine = engine
        self._heap: list = []
        self._seq = itertools.count()
        self.last_summary: dict = {}

    def submit(self, request: Request, *,
               deadline: Optional[float] = None,
               on_token: Optional[Callable[[int, int], None]] = None,
               on_finish: Optional[Callable[[int, np.ndarray], None]] = None,
               ) -> int:
        """Queue a request; returns its rid.

        ``deadline`` is an absolute ``time.time()`` cutoff.  ``on_token``
        is called as ``on_token(rid, token)`` for every generated token
        (streaming); ``on_finish(rid, tokens)`` once on completion,
        expiry, or truncation."""
        if deadline is not None:
            request.deadline = deadline
        if on_token is not None:
            request.on_token = on_token
        if on_finish is not None:
            request.on_finish = on_finish
        key = request.deadline if request.deadline is not None else float("inf")
        heapq.heappush(self._heap, (key, next(self._seq), request))
        return request.rid

    def pending(self) -> int:
        return len(self._heap)

    def run(self) -> RunResult:
        """Drain the queue through the engine in EDF order.

        Returns a :class:`RunResult`: ``{rid: np.ndarray of generated
        tokens}`` whose ``summary`` attribute digests the run — overall
        and per-request ``tokens_per_step`` and, for speculative
        engines, ``accept_rate``/``draft_share`` — instead of leaving
        those buried in engine-level counters."""
        reqs = [heapq.heappop(self._heap)[2] for _ in range(len(self._heap))]
        m0 = self.engine.metrics()
        out = RunResult()
        if reqs:
            out.update(self.engine.serve(reqs))
        m = self.engine.metrics()
        # engine counters are engine-lifetime cumulative; the summary
        # digests *this* run, so report deltas against the pre-run
        # snapshot (a reused Scheduler must not re-report earlier runs)
        d = lambda key: m[key] - m0[key]
        rids = {r.rid for r in reqs}
        per_req = {rid: tps
                   for rid, tps in self.engine.request_summary().items()
                   if rid in rids}
        tokens, steps = d("tokens_generated"), d("decode_steps")
        dt = m["serve_time_s"] - m0["serve_time_s"]
        out.summary = {
            "requests": len(reqs),
            "completed": d("completed"),
            "expired": d("expired"),
            "truncated": d("truncated"),
            "tokens_generated": tokens,
            "tokens_per_s": (tokens / dt) if dt > 0 else 0.0,
            "tokens_per_step": tokens / max(steps, 1),
            "tokens_per_step_by_request": per_req,
            "spec": m["spec"],
        }
        if m["spec"]:
            out.summary.update(
                accept_rate=(d("accepted_tokens")
                             / max(d("proposed_tokens"), 1)),
                draft_share=(d("emitted_draft_tokens") / max(tokens, 1)),
                spec_cycles=d("spec_cycles"),
                spec_k=m["spec_k"],
                draft_kind=m["draft_kind"])
        self.last_summary = out.summary
        return out

    def metrics(self) -> dict:
        return self.engine.metrics()
