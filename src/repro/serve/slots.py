"""Slot-table state shared by every admission strategy and cache kind.

The serving engine is slot-based continuous batching: ``n_slots`` fixed
batch rows, each either free or bound to one in-flight
:class:`Request`.  :class:`SlotTable` owns the *host-side* mirror of
that binding — per-slot request pointers, sampling policy rows, the
host-tracked cache lengths, the pending prompt tails of chunked
admissions, and the per-slot prompt block hashes the paged prefix index
keys on.  Device state (the dense cache block or the page store) lives
in the stepper (:mod:`.stepper`); the engine's serve loop and the
admission strategies (:mod:`.admission`) only ever talk to slots
through this table, which is what lets dense and paged share one loop.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import annotation as obs_annotation


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (T,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 => greedy
    top_k: int = 0               # 0 => disabled
    top_p: float = 0.0           # 0 or >= 1 => disabled (nucleus)
    deadline: Optional[float] = None   # absolute engine-clock cutoff
    on_token: Optional[Callable[[int, int], None]] = None
    on_finish: Optional[Callable[[int, np.ndarray], None]] = None
    on_admit: Optional[Callable[[int], None]] = None
    out_tokens: Optional[list] = None
    # overload machinery (DESIGN.md §16)
    tenant: str = "default"      # quota/fairness bucket
    rel_deadline: Optional[float] = None  # deadline relative to arrival
    arrival: Optional[float] = None       # stamped by the arrival feed
    on_shed: Optional[Callable] = None    # (req, retry_after_s) on shed
    retries: int = 0             # shed-retry re-arrivals so far
    preempts: int = 0            # times evicted from a slot
    resume: bool = False         # re-queued mid-flight; keep out_tokens
    outcome: Optional[str] = None    # completed|expired|truncated|shed
    # lifecycle stamps (serve/instrument.py): engine-clock times of the
    # current queue/prefill/decode phase boundaries; a preemption
    # resets them so the resume traces as a fresh triple
    t_enqueue: Optional[float] = None
    t_bind: Optional[float] = None
    t_first: Optional[float] = None


def effective_prompt(req: Request) -> np.ndarray:
    """The token sequence admission must (re)build KV for: the prompt,
    plus — for a resumed preempted request — everything it already
    emitted.  Treating prompt+out as the prompt makes resume ordinary
    admission: prefill (or a prefix-index hit) recomputes exactly the
    KV that was released, and the first sampled token continues the
    output stream bit-identically under greedy decoding."""
    p = np.asarray(req.prompt, np.int32)
    if req.resume and req.out_tokens:
        return np.concatenate([p, np.asarray(req.out_tokens, np.int32)])
    return p


class TraceCounter:
    """Wraps a jitted callable; counts calls and distinct input
    shape/dtype signatures (== XLA traces for a jit with no static
    args).  The serving tests assert prefill traces <= bucket count.

    With a ``name`` and an ``engine``, every *new* signature also lands
    in the observability layer: a ``compile`` (first trace) or
    ``retrace`` instant on the engine's tracer and an entry-labeled
    ``serve.jit_traces`` registry counter — so a recompile mid-traffic
    shows up as a named event instead of a mystery latency spike.  When
    the engine was built with ``profile=True`` each dispatch runs under
    a named ``jax.profiler`` annotation."""

    def __init__(self, fn, name: Optional[str] = None, engine=None):
        self.fn = fn
        self.name = name or getattr(fn, "__name__", "jit")
        self.engine = engine
        self.calls = 0
        self._sigs = set()

    def _on_new_sig(self):
        eng = self.engine
        if eng is None:
            return
        eng.registry.counter("serve.jit_traces", entry=self.name).inc()
        if eng.tracer is not None:
            eng.tracer.instant(
                "compile" if len(self._sigs) == 1 else "retrace",
                cat="jit", args=dict(entry=self.name,
                                     trace=len(self._sigs),
                                     call=self.calls))

    def __call__(self, *args):
        self.calls += 1
        sig = tuple(
            (leaf.shape, str(leaf.dtype))
            for leaf in jax.tree_util.tree_leaves(args)
            if hasattr(leaf, "shape"))
        if sig not in self._sigs:
            self._sigs.add(sig)
            self._on_new_sig()
        if self.engine is not None and self.engine._profile:
            with obs_annotation(self.name):
                return self.fn(*args)
        return self.fn(*args)

    @property
    def traces(self) -> int:
        return len(self._sigs)


def empty_tokens() -> np.ndarray:
    return np.zeros((0,), np.int32)


class SlotTable:
    """Host-side slot <-> request state.

    ``slot_len`` is the host mirror of each slot's valid cache length
    (dense ``cache["len"]`` / paged page-table occupancy).  ``fill[s]``
    is the not-yet-prefilled prompt tail of a chunked or prefix-hit
    admission — while non-None the slot is teacher-forcing its prompt
    through the decode step and emits nothing.  ``hashes[s]`` keeps the
    prompt's block hashes for paged prefix-index registration.
    """

    def __init__(self, n: int):
        self.n = n
        self.req: List[Optional[Request]] = [None] * n
        self.active = np.zeros(n, bool)
        self.temps = np.zeros(n, np.float32)
        self.top_k = np.zeros(n, np.int32)
        self.top_p = np.zeros(n, np.float32)
        self.slot_len = np.zeros(n, np.int64)
        self.fill: List[Optional[np.ndarray]] = [None] * n
        self.hashes: List[Optional[list]] = [None] * n
        self.slot_last = jnp.zeros((n,), jnp.int32)

    def free(self) -> List[int]:
        return [s for s in range(self.n) if self.req[s] is None]

    def any_active(self) -> bool:
        return bool(self.active.any())

    def bind(self, req: Request, s: int):
        """Bind a request to slot ``s`` (policy rows + request pointer;
        engine-level accounting stays in the engine).  A resumed
        preempted request keeps its emitted tokens — the finish checks
        and token budget continue from where the eviction cut it."""
        if not req.resume:
            req.out_tokens = []
        self.req[s] = req
        self.active[s] = True
        self.temps[s] = req.temperature
        self.top_k[s] = req.top_k
        self.top_p[s] = req.top_p

    def clear(self, s: int):
        self.req[s] = None
        self.active[s] = False
        self.fill[s] = None
        self.hashes[s] = None

    def filling(self) -> List[bool]:
        """Per-active-slot "still teacher-forcing its prompt" flags —
        feeds the spec-depth decision (no speculative bursts while any
        slot is mid-prompt)."""
        return [self.fill[s] is not None
                for s in range(self.n) if self.active[s]]

    def input_tokens(self):
        """Next decode-step input per slot: the last sampled token,
        with filling slots teacher-forced from their prompt tail.

        Steady state (nothing filling) passes ``slot_last`` through as
        the device array — the steppers feed it straight back into the
        jitted step, so the common decode path never round-trips the
        sampled tokens device→host→device.  Only a slot mid-prompt
        (chunked or prefix-hit admission) forces the transfer, because
        its next input lives in a host-side prompt tail."""
        filling = [s for s in range(self.n)
                   if self.active[s] and self.fill[s] is not None]
        if not filling:
            return self.slot_last
        sl = np.asarray(self.slot_last).copy()  # repro: noqa[RPR002] fill tokens live on host; only chunked-admission steps pay this
        for s in filling:
            sl[s] = self.fill[s][0]
        return sl
