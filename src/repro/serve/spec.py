"""Speculative decoding: draft-K, batched verify, accept/rollback.

One speculative *cycle* replaces one decode step of the engine loop:

1. **Draft** — the draft source (:mod:`.draft`) runs K cheap sequential
   decode steps, proposing ``d_1..d_K`` per slot under each slot's own
   sampling policy.  The self-draft writes its speculative K/V straight
   into the target cache/page store (overwritten in step 2); an
   independent draft uses its own dense cache plus one alignment step
   so its cache stays complete when the whole burst is accepted.
2. **Verify** — the target scores all K+1 positions in one span forward
   (``verify_step`` / ``verify_step_paged``): per-slot kv_lens shift the
   causal mask, so slots at different acceptance depths stay in one
   batch, and each position runs the same decode-attention kernel
   dispatch as the non-speculative loop.
3. **Accept** — the jitted leftover-probability rejection rule
   (:func:`.sampler.spec_accept`) emits ``n_accept + 1`` tokens per slot
   (greedy reduces to exact target argmaxes, so greedy output is
   token-for-token identical to non-speculative decode).
4. **Rollback** — the engine truncates per-slot lengths
   (:func:`.cache_ops.truncate_slot`) and, in paged mode, trims
   exclusively-owned pages past the accepted depth (refcount-safe: the
   burst pages were allocated or copied-on-write before the cycle, so
   shared prefix pages are never touched).

The cycle is one jitted XLA program per (k, cache-kind); the engine
caches them in :class:`SpecRunner` and picks ``k`` per iteration from
the tightest slot's remaining cache room.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import SERVE_DECODE_RULES, SERVE_PREFILL_RULES
from .buckets import bucket_for
from .cache_ops import write_slot
from .sampler import (draw_from_probs, policy_in_use, policy_probs,
                      spec_accept)


@dataclasses.dataclass
class SpecConfig:
    """Engine-level speculative decoding configuration.

    ``k`` is the draft depth (tokens proposed per cycle; up to ``k + 1``
    emitted).  ``draft`` is a draft source instance —
    :class:`~repro.serve.draft.SelfDraft` or
    :class:`~repro.serve.draft.ModelDraft`.
    """
    k: int = 3
    draft: Any = None


class SpecRunner:
    """Owns the draft state and the per-k jitted speculative cycles."""

    def __init__(self, engine, cfg: SpecConfig):
        from .engine import TraceCounter
        if cfg.draft is None:
            raise ValueError("SpecConfig.draft must be a draft source "
                             "(serve.draft.SelfDraft / ModelDraft)")
        if cfg.k < 1:
            raise ValueError(f"spec k must be >= 1, got {cfg.k}")
        self.engine = engine
        self.cfg = cfg
        self.draft = cfg.draft
        self.dmodel = (self.draft.model if self.draft.model is not None
                       else engine.model)
        dv = getattr(self.dmodel.cfg, "vocab_size", None)
        tv = engine.model.cfg.vocab_size
        if dv != tv:
            # fail fast: a vocab mismatch would otherwise surface as an
            # opaque broadcast error deep inside the jitted cycle (and
            # silently clamp draft token ids before that)
            raise ValueError(
                f"draft vocab_size {dv} != target vocab_size {tv}; the "
                "accept/resample rule compares the two distributions "
                "elementwise")
        self.shares = bool(getattr(self.draft, "shares_cache", False))
        self._trace_counter = TraceCounter
        self._cycles: dict = {}
        # sharded engine: the draft's weights live on the same mesh, TP
        # split along the draft model's own logical axes (engine._place
        # / engine._jit are identity when mesh is None)
        if engine.mesh is not None and hasattr(self.draft, "place"):
            self.draft.place(engine._place, self.dmodel)
        self.dcache = None
        if not self.shares:
            self.dcache = engine._place(
                self.dmodel.init_cache(engine.n_slots, engine.max_len),
                self.dmodel.cache_axes()
                if hasattr(self.dmodel, "cache_axes") else None)
            self._dprefill = TraceCounter(
                engine._jit(self.dmodel.prefill, SERVE_PREFILL_RULES),
                "draft_prefill", engine)
            # distinct function object: jit caches key on the underlying
            # callable, and this wrapper's draft-cache signatures must
            # not mingle with other write_slot users' cache entries
            self._dwrite = engine._jit(
                lambda cache, single, slot: write_slot(cache, single, slot),
                SERVE_DECODE_RULES)
            self._dtrack = engine._jit(self.dmodel.decode_step,
                                       SERVE_DECODE_RULES)
            self._dplen = ("prompt_len" in inspect.signature(
                self.dmodel.prefill).parameters)
        self.m = engine.registry.group("spec").init(
            spec_cycles=0, draft_steps=0, proposed_tokens=0,
            accepted_tokens=0, emitted_draft_tokens=0)

    # -- admission -----------------------------------------------------------
    def admit_slot(self, slot: int, prompt):
        """Prefill the independent draft's cache row for a fresh slot.

        The self-draft shares the target cache (the prompt's K/V is the
        target's own prefill output) — nothing to do.  The independent
        draft pads to the engine's bucket grid when it supports
        ``prompt_len``, bounding compiles by the bucket count.
        """
        if self.shares:
            return
        p = np.asarray(prompt, np.int32)
        eng = self.engine
        c1 = self.dmodel.init_cache(1, eng.max_len)
        if self._dplen:
            b = bucket_for(eng.buckets, len(p))
            tokens = np.zeros((1, b), np.int32)
            tokens[0, :len(p)] = p
            _, c1 = self._dprefill(self.draft.params, jnp.asarray(tokens),
                                   c1, jnp.asarray([len(p)], jnp.int32))
        else:
            _, c1 = self._dprefill(self.draft.params, jnp.asarray(p[None]),
                                   c1)
        self.dcache = self._dwrite(self.dcache, c1,
                                   jnp.asarray(slot, jnp.int32))

    def track_step(self, last, lens):
        """Advance the independent draft's KV through one *plain* decode
        iteration (the engine fell back to non-speculative decode —
        near-capacity slot, or a paged slot teacher-forcing its prompt
        tail).  Without this the draft's cache would hold permanent
        holes at those positions and acceptance would silently collapse
        for the rest of the request.  The self-draft shares the target
        cache, so there is nothing to track.

        ``last`` is the batch's input token for this step, ``lens`` the
        pre-step per-slot lengths (inactive slots already clamped by
        the engine)."""
        if self.shares:
            return
        dc = dict(self.dcache,
                  len=jnp.asarray(np.asarray(lens, np.int32)))  # repro: noqa[RPR002] lens is already a host array (engine slot_len)
        _, self.dcache = self._dtrack(self.draft.params, dc,
                                      jnp.asarray(last)[:, None])
        self.m["draft_steps"] += 1

    # -- jitted cycle bodies --------------------------------------------------
    def _draft_burst(self, step, carry, last, temps, top_k, top_p, key, k):
        """K sequential draft decode steps.  ``step(carry, tok, j)``
        advances the draft one token and returns ``(logits, carry)`` —
        the dense and paged self/independent variants differ only in
        that callable, so proposal sampling and RNG keying live in one
        place.  Returns (draft_tokens (B, K), draft_probs (B, K, V),
        carry).  ``top_k``/``top_p`` are ``None`` when no slot in the
        batch uses them (skips the full-vocab sort masks)."""
        tok = last
        d_toks, d_qs = [], []
        for j in range(k):
            logits, carry = step(carry, tok, j)
            q = policy_probs(logits[:, 0], temps, top_k, top_p)
            tok = draw_from_probs(q, jax.random.fold_in(key, j))
            d_toks.append(tok)
            d_qs.append(q)
        return jnp.stack(d_toks, axis=1), jnp.stack(d_qs, axis=1), carry

    def _build_dense(self, k: int, use_topk: bool, use_topp: bool):
        model, dmodel, shares = self.engine.model, self.dmodel, self.shares

        def body(params, dparams, cache, dcache, lens, last, active, temps,
                 top_k, top_p, key):
            top_k = top_k if use_topk else None
            top_p = top_p if use_topp else None
            lens = jnp.asarray(lens, jnp.int32)
            dc = dict(cache if shares else dcache, len=lens)
            step = lambda c, tok, j: dmodel.decode_step(dparams, c,
                                                        tok[:, None])
            d_toks, d_qs, dc = self._draft_burst(step, dc, last, temps,
                                                 top_k, top_p, key, k)
            if not shares:
                # alignment step: if the whole burst is accepted the
                # draft must also hold d_K's K/V (it only consumed
                # last..d_{K-1}); the proposal it yields is discarded
                _, dc = dmodel.decode_step(dparams, dc,
                                           d_toks[:, -1][:, None])
            vt = jnp.concatenate([last[:, None], d_toks], axis=1)
            base = dict(dc if shares else cache, len=lens)
            vlogits, new_cache = model.verify_step(params, base, vt)
            out, n_acc = spec_accept(d_toks, d_qs, vlogits, temps, top_k,
                                     top_p, jax.random.fold_in(key, k + 1))
            n_acc = jnp.where(active, n_acc, 0)
            if shares:
                return out, n_acc, new_cache
            return out, n_acc, new_cache, dc

        if shares:
            return lambda params, dparams, cache, lens, last, active, \
                temps, top_k, top_p, key: body(
                    params, dparams, cache, None, lens, last, active, temps,
                    top_k, top_p, key)
        return body

    def _build_paged(self, k: int, use_topk: bool, use_topp: bool):
        model, dmodel, shares = self.engine.model, self.dmodel, self.shares

        def body(params, dparams, store, table, dcache, lens, last, active,
                 temps, top_k, top_p, key):
            top_k = top_k if use_topk else None
            top_p = top_p if use_topp else None
            lens = jnp.asarray(lens, jnp.int32)
            if shares:
                # self-draft: speculative K/V goes straight into the
                # (pre-ensured-writable) target pages; verify overwrites
                step = lambda st_, tok, j: dmodel.decode_step_paged(
                    dparams, st_, tok[:, None], table, lens + j)
                d_toks, d_qs, st = self._draft_burst(step, store, last,
                                                     temps, top_k, top_p,
                                                     key, k)
            else:
                step = lambda c, tok, j: dmodel.decode_step(dparams, c,
                                                            tok[:, None])
                dc = dict(dcache, len=lens)
                d_toks, d_qs, dc = self._draft_burst(step, dc, last,
                                                     temps, top_k, top_p,
                                                     key, k)
                _, dc = dmodel.decode_step(dparams, dc,
                                           d_toks[:, -1][:, None])
                st = store
            vt = jnp.concatenate([last[:, None], d_toks], axis=1)
            vlogits, st = model.verify_step_paged(params, st, vt, table,
                                                  lens)
            out, n_acc = spec_accept(d_toks, d_qs, vlogits, temps, top_k,
                                     top_p, jax.random.fold_in(key, k + 1))
            n_acc = jnp.where(active, n_acc, 0)
            if shares:
                return out, n_acc, st
            return out, n_acc, st, dc

        if shares:
            return lambda params, dparams, store, table, lens, last, \
                active, temps, top_k, top_p, key: body(
                    params, dparams, store, table, None, lens, last, active,
                    temps, top_k, top_p, key)
        return body

    def _get_cycle(self, kind: str, k: int, use_topk: bool, use_topp: bool):
        key = (kind, k, use_topk, use_topp)
        if key not in self._cycles:
            build = self._build_dense if kind == "dense" else \
                self._build_paged
            self._cycles[key] = self._trace_counter(
                self.engine._jit(build(k, use_topk, use_topp),
                                 SERVE_DECODE_RULES),
                f"spec_cycle[{kind},k={k}]", self.engine)
        return self._cycles[key]

    # -- host entry points ----------------------------------------------------
    def run_cycle_dense(self, cache, lens, last, active, temps, top_k,
                        top_p, key, k: int):
        """One dense speculative cycle.  ``temps``/``top_k``/``top_p``
        are host arrays — the cycle specializes on whether any slot
        actually uses top-k/top-p (the full-vocab sort masks dominate
        the accept step's cost otherwise).  Returns host arrays
        (out (B, k+1), n_acc (B,)) and the updated cache (device)."""
        fn = self._get_cycle("dense", k, *policy_in_use(top_k, top_p))
        temps, top_k, top_p = (jnp.asarray(temps), jnp.asarray(top_k),
                               jnp.asarray(top_p))
        if self.shares:
            out, n_acc, cache = fn(self.engine.params, self.draft.params,
                                   cache, lens, last, active, temps, top_k,
                                   top_p, key)
        else:
            out, n_acc, cache, self.dcache = fn(
                self.engine.params, self.draft.params, cache, self.dcache,
                lens, last, active, temps, top_k, top_p, key)
        n_acc = np.asarray(n_acc)  # repro: noqa[RPR002] acceptance depths drive the host emission loop
        self._account(np.asarray(active), n_acc, k)  # repro: noqa[RPR002] active mask is a host-side bool row
        return np.asarray(out), n_acc, cache  # repro: noqa[RPR002] burst tokens are emitted host-side; (k+1) int32 per slot per cycle

    def run_cycle_paged(self, store, table, lens, last, active, temps,
                        top_k, top_p, key, k: int):
        """One paged speculative cycle (same contract, page store)."""
        fn = self._get_cycle("paged", k, *policy_in_use(top_k, top_p))
        temps, top_k, top_p = (jnp.asarray(temps), jnp.asarray(top_k),
                               jnp.asarray(top_p))
        if self.shares:
            out, n_acc, store = fn(self.engine.params, self.draft.params,
                                   store, table, lens, last, active, temps,
                                   top_k, top_p, key)
        else:
            out, n_acc, store, self.dcache = fn(
                self.engine.params, self.draft.params, store, table,
                self.dcache, lens, last, active, temps, top_k, top_p, key)
        n_acc = np.asarray(n_acc)  # repro: noqa[RPR002] acceptance depths drive the host emission loop
        self._account(np.asarray(active), n_acc, k)  # repro: noqa[RPR002] active mask is a host-side bool row
        return np.asarray(out), n_acc, store  # repro: noqa[RPR002] burst tokens are emitted host-side; (k+1) int32 per slot per cycle

    def _account(self, active, n_acc, k: int):
        """accepted_tokens counts *acceptances* (draft quality, the
        accept_rate numerator); the engine separately adds the subset
        that actually reached the output stream to
        ``emitted_draft_tokens`` (the draft_share numerator) — a burst
        cut short by a slot's token budget or deadline accepts more
        than it emits."""
        n_active = int(active.sum())
        self.m["spec_cycles"] += 1
        self.m["draft_steps"] += k + (0 if self.shares else 1)
        self.m["proposed_tokens"] += k * n_active
        self.m["accepted_tokens"] += int(n_acc.sum())

    def metrics(self) -> dict:
        m = dict(self.m)
        m["spec_traces"] = sum(c.traces for c in self._cycles.values())
        m["spec_k"] = self.cfg.k
        m["draft_kind"] = ("self-int%d" % getattr(self.draft, "bits", 8)
                          if self.shares else "model")
        return m

    def trace_entries(self):
        """Named TraceCounters for the per-entry retrace breakdown
        (``metrics()["retrace_by_entry"]``): one per compiled cycle
        variant, plus the independent draft's prefill."""
        out = [(c.name, c) for _, c in sorted(self._cycles.items(),
                                              key=lambda kv: str(kv[0]))]
        if not self.shares:
            out.append(("draft_prefill", self._dprefill))
        return out
