"""Decode steppers: the jitted prefill/decode/spec cores per cache kind.

A stepper owns the *device* half of serving — the jitted entry points
(wrapped in :class:`.slots.TraceCounter` so ``metrics()`` can report
call/trace counts) and the persistent cache state they advance: the
dense ``(n_slots, max_len)`` cache block for :class:`DenseStepper`, the
page store + :class:`.pages.PagePool` + per-slot page tables for
:class:`PagedStepper`.  The engine's single serve loop drives whichever
stepper the engine was built with through one narrow interface:

* ``begin()`` — reset per-serve device state (dense allocates a fresh
  cache; the page store persists so the prefix index keeps paying off),
* ``admit_group`` / ``admit_single`` — bucketed batched admission and
  the exact-length fallback for models without ``prompt_len`` prefill,
* ``plain_step`` — one masked decode step (teacher-forcing chunked /
  prefix-hit prompt tails from the slot table's ``fill`` lists),
* ``spec_cycle`` + ``post_spec_slot`` / ``spec_rollback`` — one
  speculative draft+verify burst and its rejected-suffix rollback
  (dense: jitted length truncation; paged: returning exclusively-owned
  pages past the accepted depth),
* ``retire`` / ``fill_done`` — slot lifecycle hooks (paged: release
  page refs / publish finished prompt blocks to the prefix index).

Everything the two cache kinds *share* (emission, budgets, deadlines,
chunk bookkeeping, spec-depth policy) lives once, in the engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import SERVE_DECODE_RULES, SERVE_PREFILL_RULES, tree_hint
from . import instrument
from .cache_ops import copy_page, merge_slots, scatter_prefill_pages, write_slot
from .pages import PagePool, PagePressure, block_hashes
from .sampler import sample_tokens
from .slots import SlotTable, TraceCounter


class DenseStepper:
    """Jitted serving core over one dense ``(n_slots, max_len)`` cache."""

    kind = "dense"

    def __init__(self, engine):
        self.engine = engine
        self._prefill1 = TraceCounter(
            engine._jit(engine.model.prefill, SERVE_PREFILL_RULES),
            "prefill1", engine)
        self._prefill_admit = TraceCounter(
            engine._jit(self._prefill_admit_fn, SERVE_PREFILL_RULES),
            "prefill_admit", engine)
        self._admit_one = TraceCounter(
            engine._jit(self._admit_one_fn, SERVE_PREFILL_RULES),
            "admit_one", engine)
        self._decode = TraceCounter(
            engine._jit(self._decode_fn, SERVE_DECODE_RULES),
            "decode", engine)
        self.cache = None

    # -- lifecycle -----------------------------------------------------------
    def begin(self):
        eng = self.engine
        self.cache = eng._place(
            eng.model.init_cache(eng.n_slots, eng.max_len), eng._cache_axes)

    def retire(self, st: SlotTable, s: int):
        pass

    def preempt(self, st: SlotTable, s: int):
        """Release the slot for eviction-and-resume.  Dense KV is a
        fixed block per slot — nothing to hand back; the resume's
        teacher-forced prefill recomputes it exactly."""
        self.retire(st, s)

    def fill_done(self, st: SlotTable, s: int):
        pass

    # -- capacity (backpressure protocol; trivially satisfied dense) ---------
    def reserve_admit(self, counts):
        """Pre-own pages for a whole admission group before any slot
        binds (paged only) — a mid-group allocation failure must not
        leave half-bound slots behind."""
        return None

    def pages_needed(self, n_tokens: int):
        """Pages a sequence of ``n_tokens`` needs, or None when the
        cache kind has no page concept."""
        return None

    def fits_pool(self, n_pages: int) -> bool:
        return True

    def slot_overflows(self, st: SlotTable, s: int) -> bool:
        """True when the slot's own next token can never be allocated
        (its sequence exceeds the whole pool) — preempting it would
        livelock; the engine truncates instead."""
        return False

    # -- jitted bodies -------------------------------------------------------
    def _prefill_admit_fn(self, params, tokens, prompt_len, cache,
                          admit_mask, temps, top_k, top_p, key, slot_last):
        """Batched bucketed prefill + admission + first-token sampling.

        tokens (n_slots, bucket) is slot-aligned: row s is the prompt
        admitted into slot s (rows with admit_mask False are dummies).
        """
        eng = self.engine
        scratch = eng.model.init_cache(eng.n_slots, eng.max_len)
        logits, new = eng.model.prefill(params, tokens, scratch, prompt_len)
        merged = eng._hint_cache(merge_slots(cache, new, admit_mask))
        first = sample_tokens(eng._gathered(logits[:, 0]), temps, top_k,
                              key, top_p)
        slot_last = jnp.where(admit_mask, first, slot_last)
        return slot_last, merged

    def _admit_one_fn(self, params, tokens, cache, slot, temps, top_k,
                      top_p, key, slot_last):
        """Fallback admission: exact-length batch-1 prefill, written into
        the batched cache by one per-slot dynamic_update_index_in_dim op
        (slot is traced — a single compile serves every slot)."""
        eng = self.engine
        c1 = eng.model.init_cache(1, eng.max_len)
        logits, c1 = eng.model.prefill(params, tokens, c1)
        merged = eng._hint_cache(write_slot(cache, c1, slot))
        first = sample_tokens(eng._gathered(logits[:, 0]), temps, top_k,
                              key, top_p)
        slot_last = jax.lax.dynamic_update_index_in_dim(
            slot_last, first[0], slot, 0)
        return slot_last, merged

    def _decode_fn(self, params, cache, slot_last, active, temps, top_k,
                   top_p, key):
        """One decode step with inactive slots masked.

        Inactive slots still flow through the batched matmuls (shape
        stability) but their ``len`` is restored afterwards and their
        in-bounds scratch write lands at a position attention masks out —
        a dead slot's cache length can never pass ``max_len``."""
        eng = self.engine
        old_len = cache["len"]
        safe_len = jnp.where(active, old_len,
                             jnp.minimum(old_len, eng.max_len - 1))
        cache = dict(cache, len=safe_len)
        logits, cache = eng.model.decode_step(params, cache,
                                              slot_last[:, None])
        cache = dict(cache, len=jnp.where(active, cache["len"], old_len))
        cache = eng._hint_cache(cache)
        nxt = sample_tokens(eng._gathered(logits[:, 0]), temps, top_k,
                            key, top_p)
        nxt = jnp.where(active, nxt, slot_last)
        return nxt, cache

    # -- admission entry points ----------------------------------------------
    def admit_group(self, st: SlotTable, tokens, plen, admit_mask, group,
                    reserved=None):
        eng = self.engine
        st.slot_last, self.cache = self._prefill_admit(
            eng.params, jnp.asarray(tokens), jnp.asarray(plen),
            self.cache, jnp.asarray(admit_mask),
            *eng._policy_args(st.temps, st.top_k, st.top_p),
            eng._next_key(), st.slot_last)

    def admit_single(self, st: SlotTable, req, s: int, eff=None):
        eng = self.engine
        p = np.asarray(req.prompt if eff is None else eff, np.int32)
        st.slot_last, self.cache = self._admit_one(
            eng.params, jnp.asarray(p)[None],
            self.cache, jnp.asarray(s, jnp.int32),
            *eng._policy_args([req.temperature], [req.top_k], [req.top_p]),
            eng._next_key(), st.slot_last)

    # -- decode-loop entry points --------------------------------------------
    def plain_step(self, st: SlotTable):
        eng = self.engine
        sl = st.input_tokens()
        if eng._spec is not None:
            # keep the independent draft's KV aligned through plain
            # fallback / fill steps (self-draft shares the cache)
            eng._spec.track_step(
                jnp.asarray(sl),
                np.where(st.active, st.slot_len,
                         np.minimum(st.slot_len, eng.max_len - 1)))
        st.slot_last, self.cache = self._decode(
            eng.params, self.cache, jnp.asarray(sl),
            jnp.asarray(st.active),
            *eng._policy_args(st.temps, st.top_k, st.top_p),
            eng._next_key())

    def spec_cycle(self, st: SlotTable, k_eff: int):
        eng = self.engine
        lens_safe = np.where(
            st.active, st.slot_len,
            np.minimum(st.slot_len, eng.max_len - (k_eff + 1)))
        out, n_acc, self.cache = eng._spec.run_cycle_dense(
            self.cache, jnp.asarray(lens_safe.astype(np.int32)),
            st.slot_last, jnp.asarray(st.active), st.temps, st.top_k,
            st.top_p, eng._next_key(), k_eff)
        return out, n_acc

    def post_spec_slot(self, st: SlotTable, s: int):
        pass

    def spec_rollback(self, st: SlotTable):
        """Republish host lengths after a burst — rejected suffixes roll
        back via one jitted length truncation."""
        self.cache = self.engine._truncate(
            self.cache, jnp.asarray(st.slot_len.astype(np.int32)))


class PagedStepper(DenseStepper):
    """Serving core over the paged KV cache (DESIGN.md §10).

    Inherits the dense jitted entry points — ``generate()`` and the
    trace-count metrics use them — and overrides the serve-loop hooks to
    run against the persistent page store.  The per-slot page ``table``
    maps logical to physical pages; retired rows point at the trash
    page so masked writes can never touch a live page.
    """

    kind = "paged"

    def __init__(self, engine, page_size: int, n_pages):
        super().__init__(engine)
        eng = engine
        self.page_size = page_size
        self.pages_per_slot = -(-eng.max_len // page_size)
        # default capacity guarantees admission can never deadlock:
        # every slot can hold a full max_len sequence (+1 trash page)
        self.n_pages = (int(n_pages) if n_pages
                        else 1 + eng.n_slots * self.pages_per_slot)
        self.pool = PagePool(self.n_pages, page_size,
                             faults=getattr(eng, "faults", None),
                             registry=eng.registry)
        # persistent across serve() calls so the prefix index keeps
        # paying off between bursts; with a mesh the page stores are
        # sharded on the head axis (page tables stay replicated)
        self._store_axes = (eng.model.paged_cache_axes()
                            if hasattr(eng.model, "paged_cache_axes")
                            else None)
        self.store = eng._place(
            eng.model.init_paged_cache(self.n_pages, page_size),
            self._store_axes)
        self.table = np.full((eng.n_slots, self.pages_per_slot),
                             PagePool.TRASH, np.int32)
        self._prefill_paged = TraceCounter(
            eng._jit(self._prefill_paged_fn, SERVE_PREFILL_RULES),
            "prefill_paged", eng)
        self._decode_paged = TraceCounter(
            eng._jit(self._decode_paged_fn, SERVE_DECODE_RULES),
            "decode_paged", eng)
        self._scatter_pages = eng._jit(scatter_prefill_pages,
                                       SERVE_DECODE_RULES)
        self._copy_page = eng._jit(copy_page, SERVE_DECODE_RULES)

    # -- lifecycle -----------------------------------------------------------
    def begin(self):
        pass    # page store persists; slot tables were released at retire

    def retire(self, st: SlotTable, s: int):
        """Release the slot's page refs (index-held pages survive for
        cross-request reuse)."""
        for j in range(self.pages_per_slot):
            if self.table[s, j] != PagePool.TRASH:
                self.pool.decref(int(self.table[s, j]))
                self.table[s, j] = PagePool.TRASH

    def preempt(self, st: SlotTable, s: int):
        """Backpressure eviction: publish every *full* KV block —
        prompt and generated tokens alike — to the prefix index under
        the effective-sequence hash chain, then release the slot's
        refs.  The index refs keep those pages alive, so the resume's
        prefix-hit admission maps them straight back and only the
        partial tail block recomputes.  (Under continued pressure the
        registered pages are index-only and evictable — publishing
        them can never wedge the pool.)"""
        req = st.req[s]
        ps = self.page_size
        nfull = int(st.slot_len[s]) // ps
        if nfull:
            eff = np.concatenate([
                np.asarray(req.prompt, np.int32),
                np.asarray(req.out_tokens or [], np.int32)])
            hs = block_hashes(eff[:nfull * ps], ps)
            for j in range(nfull):
                if self.table[s, j] != PagePool.TRASH:
                    self.pool.register(hs[j], int(self.table[s, j]))
        self.retire(st, s)

    def fill_done(self, st: SlotTable, s: int):
        self.register_prompt_pages(st, s)

    # -- capacity (backpressure protocol) ------------------------------------
    def _take_page(self, slot=None) -> int:
        p = self.pool.try_alloc()
        if p is None:
            raise PagePressure(slot)
        return p

    def reserve_admit(self, counts):
        """Allocate every page an admission group needs up front; on
        failure release the partial reservation and raise
        :class:`.pages.PagePressure` with nothing bound.  Admission
        pre-checks ``pool.available()``, so this only fails under an
        injected allocation fault."""
        got = []
        for c in counts:
            pages = []
            for _ in range(c):
                p = self.pool.try_alloc()
                if p is None:
                    for q in pages:
                        self.pool.decref(q)
                    for lst in got:
                        for q in lst:
                            self.pool.decref(q)
                    raise PagePressure(None, c)
                pages.append(p)
            got.append(pages)
        return got

    def pages_needed(self, n_tokens: int):
        return self.pool.pages_for(n_tokens)

    def fits_pool(self, n_pages: int) -> bool:
        return n_pages <= self.n_pages - 1

    def slot_overflows(self, st: SlotTable, s: int) -> bool:
        return not self.fits_pool(
            self.pool.pages_for(int(st.slot_len[s]) + 1))

    # -- jitted bodies -------------------------------------------------------
    def _hint_store(self, store):
        if self.engine.mesh is None or self._store_axes is None:
            return store
        return tree_hint(store, self._store_axes)

    def _prefill_paged_fn(self, params, tokens, prompt_len, admit_mask,
                          temps, top_k, top_p, key, slot_last):
        """Bucketed batched prefill for the paged path: fills a dense
        *scratch* cache sized to the bucket (padded up to a page
        multiple), samples first tokens, and returns the scratch for the
        host to scatter into freshly allocated pages.  Unlike the dense
        path there is no merge — the persistent cache is the page store.
        """
        eng = self.engine
        t = tokens.shape[1]
        s_pages = -(-t // self.page_size) * self.page_size
        scratch = eng.model.init_cache(eng.n_slots, s_pages)
        logits, new = eng.model.prefill(params, tokens, scratch, prompt_len)
        new = eng._hint_cache(new)
        first = sample_tokens(eng._gathered(logits[:, 0]), temps, top_k,
                              key, top_p)
        slot_last = jnp.where(admit_mask, first, slot_last)
        return slot_last, new

    def _decode_paged_fn(self, params, store, page_table, lens, slot_last,
                         active, temps, top_k, top_p, key):
        """One decode step against the page store.  ``lens`` is the
        host-managed per-slot valid length (already clamped for retired
        slots); retired slots' page-table rows point at the trash page,
        so their masked write can never touch a live page."""
        eng = self.engine
        logits, store = eng.model.decode_step_paged(
            params, store, slot_last[:, None], page_table, lens)
        store = self._hint_store(store)
        nxt = sample_tokens(eng._gathered(logits[:, 0]), temps, top_k,
                            key, top_p)
        nxt = jnp.where(active, nxt, slot_last)
        return nxt, store

    # -- page bookkeeping ----------------------------------------------------
    def ensure_writable(self, s: int, pos: int):
        """Make the page holding position ``pos`` safe for slot ``s`` to
        write: allocate if unmapped, copy-on-write if shared with
        another slot or the prefix index.  Exhaustion raises
        :class:`.pages.PagePressure` for the engine to relieve by
        preemption — never a terminal error on the serve path."""
        ps = self.page_size
        lp = pos // ps
        phys = int(self.table[s, lp])
        if phys == PagePool.TRASH:
            self.table[s, lp] = self._take_page(s)
            instrument.page_event(self.engine, "page_alloc", slot=s,
                                  block=lp)
        elif self.pool.is_shared(phys):
            fresh = self._take_page(s)
            self.store = self._copy_page(self.store, phys, fresh)
            self.pool.decref(phys)
            self.table[s, lp] = fresh
            self.pool.cow_copies += 1
            instrument.page_event(self.engine, "cow", slot=s, block=lp)

    def register_prompt_pages(self, st: SlotTable, s: int):
        """Publish the slot's hashed full blocks for future reuse (the
        index takes its own ref; partial tail blocks are never shared).
        ``st.hashes[s]`` covers the *effective* prompt — for a resumed
        request that includes previously emitted tokens, so its blocks
        re-register under the same chain they were published to at
        preemption."""
        for j in range(len(st.hashes[s])):
            self.pool.register(st.hashes[s][j], int(self.table[s, j]))

    # -- admission entry points ----------------------------------------------
    def admit_group(self, st: SlotTable, tokens, plen, admit_mask, group,
                    reserved=None):
        """Bucketed batched prefill into scratch, scattered into pages
        pre-owned by :meth:`reserve_admit` (``reserved``, one page list
        per group member in order).  ``st.slot_len`` already holds each
        slot's admitted length (== prompt length, or the first chunk of
        a chunked admission); chunked slots defer prefix-index
        registration to ``fill_done``."""
        eng = self.engine
        st.slot_last, scratch = self._prefill_paged(
            eng.params, jnp.asarray(tokens), jnp.asarray(plen),
            jnp.asarray(admit_mask),
            *eng._policy_args(st.temps, st.top_k, st.top_p),
            eng._next_key(), st.slot_last)
        b = tokens.shape[1]
        ps = self.page_size
        n_scratch_pages = -(-b // ps)
        targets = [s for _, s in group]
        all_ids = np.full((len(group), n_scratch_pages),
                          PagePool.TRASH, np.int32)
        for gi, (req, s) in enumerate(group):
            npages = -(-int(st.slot_len[s]) // ps)
            phys = (reserved[gi] if reserved is not None
                    else [self._take_page(s) for _ in range(npages)])
            assert len(phys) == npages
            all_ids[gi, :npages] = phys
            self.table[s, :npages] = phys
        self.store = self._scatter_pages(
            self.store, scratch,
            jnp.asarray(np.asarray(targets, np.int32)),
            jnp.asarray(all_ids))
        for req, s in group:
            if st.fill[s] is None:
                self.register_prompt_pages(st, s)

    def admit_single(self, st: SlotTable, req, s: int, eff=None):
        raise NotImplementedError(
            "paged serving requires prompt_len prefill")

    # -- decode-loop entry points --------------------------------------------
    def plain_step(self, st: SlotTable):
        eng = self.engine
        sl = st.input_tokens()
        lens = np.minimum(st.slot_len, eng.max_len - 1)  # retired slots
        for s in range(eng.n_slots):
            if not st.active[s]:
                continue
            lens[s] = st.slot_len[s]
            self.ensure_writable(s, int(st.slot_len[s]))
        if eng._spec is not None:
            # align the independent draft's KV through fill / fallback
            # steps (it sees the same token stream)
            eng._spec.track_step(jnp.asarray(sl), lens)
        st.slot_last, self.store = self._decode_paged(
            eng.params, self.store, jnp.asarray(self.table),
            jnp.asarray(lens.astype(np.int32)), jnp.asarray(sl),
            jnp.asarray(st.active),
            *eng._policy_args(st.temps, st.top_k, st.top_p),
            eng._next_key())

    def spec_cycle(self, st: SlotTable, k_eff: int):
        """Paged speculative cycle: pre-own the burst's pages (alloc /
        copy-on-write), then draft+verify in one jitted call."""
        eng = self.engine
        lens = np.minimum(st.slot_len, eng.max_len - (k_eff + 1))
        for s in range(eng.n_slots):
            if not st.active[s]:
                continue
            lens[s] = st.slot_len[s]
            for pos in range(int(st.slot_len[s]),
                             int(st.slot_len[s]) + k_eff + 1):
                self.ensure_writable(s, pos)
        out, n_acc, self.store = eng._spec.run_cycle_paged(
            self.store, jnp.asarray(self.table),
            jnp.asarray(lens.astype(np.int32)), st.slot_last,
            jnp.asarray(st.active), st.temps, st.top_k, st.top_p,
            eng._next_key(), k_eff)
        return out, n_acc

    def post_spec_slot(self, st: SlotTable, s: int):
        """Rejected-suffix rollback: pages wholly past the accepted
        depth were allocated (or COW'd) for this burst and are
        exclusively owned — shared prefix pages all sit below
        ``slot_len``."""
        ps = self.page_size
        trimmed = 0
        for j in range(self.pages_per_slot):
            phys = int(self.table[s, j])
            if phys != PagePool.TRASH and j * ps >= st.slot_len[s]:
                assert not self.pool.is_shared(phys)
                self.pool.decref(phys)
                self.table[s, j] = PagePool.TRASH
                trimmed += 1
        if trimmed:
            instrument.page_event(self.engine, "page_trim", slot=s,
                                  pages=trimmed)

    def spec_rollback(self, st: SlotTable):
        pass    # per-slot page trim happens in post_spec_slot
