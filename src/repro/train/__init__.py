"""Training substrate: optimizer, trainer, gradient compression."""
from .optimizer import AdamW, cosine_schedule, global_norm
from .trainer import TrainConfig, cross_entropy, make_train_step
