"""Int8 error-feedback gradient compression for the data-parallel axis.

Large-scale trick: before the optimizer consumes gradients, each tensor is
quantized to int8 with a per-tensor scale; the quantization residual is
kept in an error-feedback buffer and added back next step (Seide et al.,
1-bit SGD lineage; EF-SGD convergence guarantees).  On a real pod this
pairs with an int8 all-reduce on the DP axis (XLA performs the reduction
in the compressed domain when the operand is int8 under shard_map psum);
here the compress->decompress round-trip is exact to what the wire would
carry, so convergence behaviour is faithfully reproduced on CPU.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    error: dict  # residual buffer, same structure as grads (fp32)


def ef_init(params) -> EFState:
    return EFState(error=jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _compress_one(g: jax.Array, err: jax.Array):
    g32 = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_err = g32 - deq
    return deq, new_err


def compress_grads(grads, ef: EFState):
    """Returns (decompressed grads as the wire would deliver, new EF state)."""
    out = jax.tree_util.tree_map(_compress_one, grads, ef.error)
    deq = jax.tree_util.tree_map(lambda t: t[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree_util.tree_map(lambda t: t[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
    return deq, EFState(error=err)
