"""AdamW + gradient clipping + LR schedules, on raw pytrees.

(optax is not available in this environment; this implementation follows
the standard decoupled-weight-decay AdamW.)  Moments live in fp32 by
default (``moment_dtype="bfloat16"`` halves optimizer memory — used by
the 405B memory-fit configuration, see EXPERIMENTS.md).  All ops are
elementwise pytree maps, so optimizer state inherits the parameters'
sharding (ZeRO-3 for free under pjit).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"

    def init(self, params) -> AdamWState:
        dt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree_util.tree_map(zeros, params),
                          v=jax.tree_util.tree_map(zeros, params))

    def update(self, grads, state: AdamWState, params, lr: jax.Array):
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            gnorm = global_norm(g32)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))
            g32 = jax.tree_util.tree_map(lambda g: g * scale, g32)
        else:
            gnorm = global_norm(g32)
        step = state.step + 1
        bc1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - self.b2 ** step.astype(jnp.float32)
        dt = jnp.dtype(self.moment_dtype)

        def upd(p, g, m, v):
            m32 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g
            v32 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g * g
            mh = m32 / bc1
            vh = v32 / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay and p.ndim >= 2:  # no decay on norms/biases
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

        flat = jax.tree_util.tree_map(upd, params, g32, state.m, state.v)
        new_params = jax.tree_util.tree_map(
            lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(
            lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(
            lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr
