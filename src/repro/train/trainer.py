"""Train-step factory: loss, microbatch accumulation, mixed precision,
optional gradient compression — one jitted function per configuration.

The returned ``train_step(params, opt_state, batch) -> (params, opt_state,
metrics)`` is pjit-ready: all sharding comes from the params/batch
shardings plus the models' internal ``shard_hint`` constraints.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .grad_compress import EFState, compress_grads, ef_init
from .optimizer import AdamW, cosine_schedule


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy in fp32.  Padded-vocab logits carry a -1e30
    mask already (models guarantee it), so the softmax is exact."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moe_aux_coef: float = 0.01
    microbatches: int = 1          # gradient accumulation
    moment_dtype: str = "float32"
    grad_compress: bool = False    # int8 EF compression on the DP axis


def make_loss_fn(model, aux_coef: float):
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        labels = batch["labels"]
        loss = cross_entropy(logits[:, :-1], labels[:, 1:])
        total = loss + aux_coef * aux["moe_aux"]
        return total, {"ce": loss, "moe_aux": aux["moe_aux"]}
    return loss_fn


def make_train_step(model, tcfg: TrainConfig):
    opt = AdamW(weight_decay=tcfg.weight_decay, clip_norm=tcfg.clip_norm,
                moment_dtype=tcfg.moment_dtype)
    lr_fn = cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.total_steps)
    loss_fn = make_loss_fn(model, tcfg.moe_aux_coef)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch, ef_state=None):
        if tcfg.microbatches > 1:
            def micro(carry, mb):
                acc, metr_acc = carry
                (loss, metr), grads = grad_fn(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32) / tcfg.microbatches,
                    acc, grads)
                metr_acc = jax.tree_util.tree_map(
                    lambda a, x: a + x / tcfg.microbatches, metr_acc,
                    {"loss": loss, **metr})
                return (acc, metr_acc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mzero = {"loss": 0.0, "ce": 0.0, "moe_aux": 0.0}
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((tcfg.microbatches,
                                     x.shape[0] // tcfg.microbatches)
                                    + x.shape[1:]), batch)
            (grads, metrics), _ = jax.lax.scan(micro, (zeros, mzero), mbs)
        else:
            (loss, metr), grads = grad_fn(params, batch)
            metrics = {"loss": loss, **metr}

        if tcfg.grad_compress:
            grads, ef_state = compress_grads(grads, ef_state)

        # schedule is indexed from 1 (warmup step 0 would be a zero-lr no-op)
        lr = lr_fn(opt_state.step + 1)
        params, opt_state, gnorm = opt.update(grads, opt_state, params, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        if tcfg.grad_compress:
            return params, opt_state, ef_state, metrics
        return params, opt_state, metrics

    return train_step, opt
