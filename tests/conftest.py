"""Make ``import repro`` work without installation or PYTHONPATH tricks.

``pip install -e .`` also works (pyproject.toml); this keeps a bare
``python -m pytest`` functional in a fresh clone.
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
