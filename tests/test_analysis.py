"""The analysis package analyzed: every rule fires on a known-bad
snippet at the right line, noqa suppresses, the baseline round-trips,
the CLI exit codes hold, and the HLO contract checker rejects a broken
contract (text-level fast; one real lowering under the slow marker)."""
import dataclasses
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import code_line_count, run_lint
from repro.analysis.lint import (apply_baseline, collect_files,
                                 load_baseline, write_baseline)
from repro.analysis.rules import all_rules, rules_by_code

REPO = Path(__file__).resolve().parent.parent


def lint_snippet(tmp_path, rel, text, *codes):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text)
    rules = rules_by_code(*codes) if codes else all_rules()
    return run_lint([str(p)], rules, base=tmp_path)


# ---------------------------------------------------------------------------
# One known-bad snippet per rule, asserting the exact line
# ---------------------------------------------------------------------------

def test_rpr001_raw_jit_in_serve(tmp_path):
    findings = lint_snippet(tmp_path, "repro/serve/x.py", (
        "import jax\n"
        "jf = jax.jit(lambda x: x)\n"), "RPR001")
    assert [(f.rule, f.line) for f in findings] == [("RPR001", 2)]
    # same code outside serve/ is fine (the seam lives elsewhere)
    assert not lint_snippet(tmp_path, "repro/core/x.py", (
        "import jax\n"
        "jf = jax.jit(lambda x: x)\n"), "RPR001")


def test_rpr002_host_sync_in_jitted_body(tmp_path):
    findings = lint_snippet(tmp_path, "repro/core/x.py", (
        "import jax\n"
        "import numpy as np\n"
        "\n"
        "@jax.jit\n"
        "def step(x):\n"
        "    return np.asarray(x)\n"), "RPR002")
    assert [(f.rule, f.line) for f in findings] == [("RPR002", 6)]


def test_rpr002_transitive_and_callsite_rooting(tmp_path):
    # helper() is only jitted transitively, via jax.jit(outer)
    findings = lint_snippet(tmp_path, "repro/core/y.py", (
        "import jax\n"
        "\n"
        "def helper(x):\n"
        "    return x.item()\n"
        "\n"
        "def outer(x):\n"
        "    return helper(x)\n"
        "\n"
        "f = jax.jit(outer)\n"), "RPR002")
    assert [(f.rule, f.line) for f in findings] == [("RPR002", 4)]


def test_rpr002_serve_hot_path_methods(tmp_path):
    # transfer initiators in known per-step serve methods are flagged
    # even outside jit (they run on the host between jitted steps)
    findings = lint_snippet(tmp_path, "repro/serve/eng.py", (
        "import numpy as np\n"
        "\n"
        "class Eng:\n"
        "    def _plain_step(self, st):\n"
        "        return np.asarray(st.slot_last)\n"), "RPR002")
    assert [(f.rule, f.line) for f in findings] == [("RPR002", 5)]


def test_rpr003_scalar_args_without_static(tmp_path):
    findings = lint_snippet(tmp_path, "repro/core/z.py", (
        "import jax\n"
        "\n"
        "def f(x, k: int):\n"
        "    return x\n"
        "\n"
        "g = jax.jit(f)\n"), "RPR003")
    assert [(f.rule, f.line) for f in findings] == [("RPR003", 6)]
    assert "'k'" in findings[0].message or "k" in findings[0].message
    # declaring it static clears the finding
    assert not lint_snippet(tmp_path, "repro/core/z2.py", (
        "import jax\n"
        "\n"
        "def f(x, k: int):\n"
        "    return x\n"
        "\n"
        "g = jax.jit(f, static_argnames=('k',))\n"), "RPR003")


def test_rpr004_kernel_accum_dtype(tmp_path):
    findings = lint_snippet(tmp_path, "repro/kernels/k.py", (
        "import jax.numpy as jnp\n"
        "\n"
        "def _kernel(a, b):\n"
        "    s = jnp.cumsum(a)\n"
        "    return jnp.dot(a, b)\n"), "RPR004")
    assert [(f.rule, f.line) for f in findings] == [("RPR004", 4),
                                                    ("RPR004", 5)]
    assert not lint_snippet(tmp_path, "repro/kernels/k2.py", (
        "import jax.numpy as jnp\n"
        "\n"
        "def _kernel(a, b):\n"
        "    s = jnp.cumsum(a, dtype=jnp.float32)\n"
        "    return jnp.dot(a, b, preferred_element_type=jnp.float32)\n"),
        "RPR004")


def test_rpr005_serve_loop_regrowth(tmp_path):
    findings = lint_snippet(tmp_path, "repro/serve/engine.py", (
        "class ServeEngine:\n"
        "    def serve(self):\n"
        "        if self.paged:\n"
        "            return self._stepper.step()\n"
        "        self._stepper.begin()\n"
        "\n"
        "def _serve_paged(eng):\n"
        "    pass\n"), "RPR005")
    assert [(f.rule, f.line) for f in findings] == [
        ("RPR005", 3),   # self.paged branching in the loop
        ("RPR005", 4),   # stepper internals beyond begin()
        ("RPR005", 7),   # second serve loop
    ]


def test_rpr006_clock_seam(tmp_path):
    findings = lint_snippet(tmp_path, "repro/serve/sched.py", (
        "import time\n"
        "\n"
        "def now(clock=None):\n"
        "    return (clock or time.monotonic)()\n"), "RPR006")
    assert [(f.rule, f.line) for f in findings] == [("RPR006", 4)]
    # time.sleep is not a clock read
    assert not lint_snippet(tmp_path, "repro/serve/sched2.py", (
        "import time\n"
        "time.sleep(0)\n"), "RPR006")


def test_rpr007_bare_tile_assert(tmp_path):
    findings = lint_snippet(tmp_path, "repro/kernels/q.py", (
        "def f(k, bk):\n"
        "    assert k % bk == 0\n"), "RPR007")
    assert [(f.rule, f.line) for f in findings] == [("RPR007", 2)]


def test_rpr008_pool_raise_in_serve(tmp_path):
    findings = lint_snippet(tmp_path, "repro/serve/stepper.py", (
        "from .pages import PoolExhausted\n"
        "\n"
        "def take_page(pool):\n"
        "    p = pool.try_alloc()\n"
        "    if p is None:\n"
        "        raise PoolExhausted('no pages')\n"
        "    if p < 0:\n"
        "        raise RuntimeError('page pool exhausted')\n"), "RPR008")
    assert [(f.rule, f.line) for f in findings] == [("RPR008", 6),
                                                    ("RPR008", 8)]
    # unrelated RuntimeErrors and code outside serve/ are fine
    assert not lint_snippet(tmp_path, "repro/serve/ok.py", (
        "def f(x):\n"
        "    raise RuntimeError('bad dtype')\n"), "RPR008")
    assert not lint_snippet(tmp_path, "repro/core/pool.py", (
        "def f():\n"
        "    raise RuntimeError('pool exhausted')\n"), "RPR008")


def test_rpr008_alloc_terminal_path_is_unreachable_from_serve():
    """The one serve-tree PoolExhausted raise is PagePool.alloc's
    documented terminal path (noqa'd); the serve steppers allocate via
    try_alloc, so the whole serve/ package lints clean under RPR008."""
    serve_dir = REPO / "src" / "repro" / "serve"
    findings = run_lint([str(serve_dir)], rules_by_code("RPR008"),
                        base=REPO)
    assert findings == []
    text = (serve_dir / "pages.py").read_text()
    assert "noqa[RPR008]" in text


def test_rpr009_obs_bypass_in_serve(tmp_path):
    findings = lint_snippet(tmp_path, "repro/serve/x.py", (
        "import logging\n"
        "from datetime import datetime\n"
        "\n"
        "def step(eng):\n"
        "    print('decoded')\n"
        "    t = datetime.now()\n"), "RPR009")
    assert [(f.rule, f.line) for f in findings] == [("RPR009", 1),
                                                    ("RPR009", 5),
                                                    ("RPR009", 6)]
    # printing is the launch scripts' and benches' job — out of scope
    assert not lint_snippet(tmp_path, "repro/launch/x.py", (
        "print('tok/s')\n"), "RPR009")
    # a reasoned noqa keeps a deliberate exception
    assert not lint_snippet(tmp_path, "repro/serve/ok.py", (
        "def dump(eng):\n"
        "    print(eng)  # repro: noqa[RPR009] debug REPL helper\n"),
        "RPR009")


def test_rpr009_serve_tree_is_clean():
    """The serving stack routes all telemetry through repro.obs /
    serve.instrument — no prints, logging, or raw timestamps."""
    serve_dir = REPO / "src" / "repro" / "serve"
    assert run_lint([str(serve_dir)], rules_by_code("RPR009"),
                    base=REPO) == []


# ---------------------------------------------------------------------------
# Suppression + baseline mechanics
# ---------------------------------------------------------------------------

def test_noqa_suppresses_only_named_rule(tmp_path):
    assert not lint_snippet(tmp_path, "repro/kernels/q.py", (
        "def f(k, bk):\n"
        "    assert k % bk == 0  # repro: noqa[RPR007] forced above\n"),
        "RPR007")
    # a noqa for a different code does not suppress
    findings = lint_snippet(tmp_path, "repro/kernels/q2.py", (
        "def f(k, bk):\n"
        "    assert k % bk == 0  # repro: noqa[RPR001] wrong code\n"),
        "RPR007")
    assert len(findings) == 1


def test_baseline_round_trip_and_stale(tmp_path):
    bad = tmp_path / "repro/kernels/q.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("def f(k, bk):\n    assert k % bk == 0\n")
    rules = rules_by_code("RPR007")
    files = collect_files([str(tmp_path)], base=tmp_path)
    findings = run_lint([], rules, files=files)
    assert findings

    bl_path = tmp_path / "baseline.json"
    write_baseline(bl_path, findings, files)
    baseline = load_baseline(bl_path)
    new, old, stale = apply_baseline(findings, files, baseline)
    assert not new and len(old) == len(findings) and not stale

    # an unrelated edit ABOVE the finding must not churn the baseline
    # (keyed on line text, not line number)
    bad.write_text("import math\n\n\ndef f(k, bk):\n"
                   "    assert k % bk == 0\n")
    files = collect_files([str(tmp_path)], base=tmp_path)
    findings = run_lint([], rules, files=files)
    new, old, stale = apply_baseline(findings, files, baseline)
    assert not new and len(old) == 1 and not stale

    # fixing the finding leaves a stale entry — the baseline can shrink
    bad.write_text("def f(k, bk):\n    return k // bk\n")
    files = collect_files([str(tmp_path)], base=tmp_path)
    findings = run_lint([], rules, files=files)
    new, old, stale = apply_baseline(findings, files, baseline)
    assert not new and not old and len(stale) == 1


def test_code_line_count_insensitive_to_comments():
    base = "def f(x):\n    y = x + 1\n    return y\n"
    noisy = ('"""Module doc.\n\nspanning lines\n"""\n'
             "# a comment\n\n"
             "def f(x):\n"
             '    """docstring"""\n'
             "    # inline note\n"
             "    y = x + 1\n\n"
             "    return y  # trailing\n")
    assert code_line_count(base) == 3
    assert code_line_count(noisy) == 3


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _cli(args, cwd):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    return subprocess.run([sys.executable, "-m", "repro.analysis", *args],
                          cwd=cwd, env=env, capture_output=True,
                          text=True, timeout=300)


def test_cli_repo_is_clean():
    out = _cli([], cwd=REPO)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "lint clean" in out.stdout


def test_cli_exit_codes_and_baseline_flow(tmp_path):
    bad = tmp_path / "repro/serve/x.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import jax\njf = jax.jit(lambda x: x)\n")

    out = _cli(["repro"], cwd=tmp_path)
    assert out.returncode == 1
    assert "RPR001" in out.stdout

    out = _cli(["repro", "--write-baseline", "--baseline", "bl.json"],
               cwd=tmp_path)
    assert out.returncode == 0
    assert json.loads((tmp_path / "bl.json").read_text())["findings"]

    out = _cli(["repro", "--baseline", "bl.json"], cwd=tmp_path)
    assert out.returncode == 0
    assert "baselined" in out.stdout

    # --no-baseline reports everything again
    out = _cli(["repro", "--baseline", "bl.json", "--no-baseline"],
               cwd=tmp_path)
    assert out.returncode == 1

    out = _cli(["no/such/dir"], cwd=tmp_path)
    assert out.returncode == 2


# ---------------------------------------------------------------------------
# HLO contract checking (text-level fast; real lowering under slow)
# ---------------------------------------------------------------------------

def test_hlo_check_module_counts_and_sizes():
    from repro.analysis import hlo_audit

    txt = ("  x = f32[2,1,512]{2,1,0} all-gather(y), dims={2}\n"
           "  r = f32[2,64]{1,0} all-reduce(z)\n")
    c = hlo_audit.CONTRACTS[0]          # decode/dense
    assert c.op == "decode" and not c.paged
    # the layout suffix {1,0} must not zero the element product (the
    # bug that made the old inline ceiling check vacuous)
    assert hlo_audit.type_elems("f32[2,64]{1,0}") == 128
    assert hlo_audit.type_elems("f32[]") == 1
    assert not hlo_audit.check_module(txt, c, d_model=128, vocab_pad=512)

    # a vocab-free gather breaks the logits-gather requirement
    bad = txt.replace("f32[2,1,512]{2,1,0}", "f32[2,1,64]{2,1,0}")
    vios = hlo_audit.check_module(bad, c, d_model=128, vocab_pad=512)
    assert any("vocab" in v.message for v in vios)

    # an oversized all-reduce operand trips the elem ceiling
    big = txt.replace("f32[2,64]{1,0}", "f32[2,512]{1,0}")
    vios = hlo_audit.check_module(big, c, d_model=128, vocab_pad=512)
    assert any(v.kind == "all-reduce" and "ceiling" in v.message
               for v in vios)

    # forbidden kinds default to max_count=0
    a2a = txt + "  t = f32[2,64]{1,0} all-to-all(w)\n"
    vios = hlo_audit.check_module(a2a, c, d_model=128, vocab_pad=512)
    assert any(v.kind == "all-to-all" for v in vios)

    # host transfers are violations regardless of collective budgets
    host = txt + "  send(q), is_host_transfer=true\n"
    vios = hlo_audit.check_module(host, c, d_model=128, vocab_pad=512)
    assert any(v.kind == "host-transfer" for v in vios)


def test_broken_contract_table_fails():
    """A deliberately broken table entry must produce violations from
    check_module — the auditor reads the table, not inline constants."""
    from repro.analysis import hlo_audit

    txt = "  x = f32[2,1,512]{2,1,0} all-gather(y)\n"
    broken = dataclasses.replace(
        hlo_audit.CONTRACTS[0], name="decode/dense/broken",
        bounds={"all-gather": hlo_audit.Bound(max_count=0)})
    vios = hlo_audit.check_module(txt, broken, d_model=128, vocab_pad=512)
    assert [v.kind for v in vios] == ["all-gather"]
    assert "allows 0" in vios[0].message


@pytest.mark.slow
def test_hlo_audit_real_lowering_mesh_1x2():
    """The full matrix audits clean at mesh (1, 2) — one all-gather per
    decode step for dense AND paged, spec on — and a broken contract
    row fails against the same lowered HLO (subprocess: the virtual
    device count must be set before jax initializes)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
from repro.analysis import hlo_audit

broken = dataclasses.replace(
    hlo_audit.CONTRACTS[0], name="decode/dense/broken",
    bounds={"all-gather": hlo_audit.Bound(max_count=0)})
vios = hlo_audit.audit(mesh_shape=(1, 2),
                       contracts=hlo_audit.CONTRACTS + (broken,))
real = [v for v in vios if v.contract != "decode/dense/broken"]
fake = [v for v in vios if v.contract == "decode/dense/broken"]
assert not real, [v.render() for v in real]
assert fake, "broken contract produced no violations"
assert any(v.kind == "all-gather" for v in fake)
print("HLO-AUDIT-OK")
"""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"),
               JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "HLO-AUDIT-OK" in out.stdout
