"""Chaos tests: the serve loop survives deterministic injected faults
(DESIGN.md §16).  Under seeded allocation failures, stalls, forced
preemptions, and checkpoint write errors, serve() never raises,
survivors stay bit-identical to the uninterrupted run, and every
injected fault is counted in ``metrics()["faults"]``."""
import os

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.dist import checkpoint as ckpt
from repro.models.registry import build_model
from repro.serve import (FaultConfig, FaultInjector, Request, Scheduler,
                         ServeEngine, SLOConfig, TrafficConfig, make_trace)
from repro.serve.faults import burstify


@pytest.fixture(scope="module")
def fp_setup():
    cfg = ARCHS["llama3-8b"].tiny()
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _ticker(dt=0.001):
    tick = {"t": 0.0}

    def clock():
        tick["t"] += dt
        return tick["t"]
    return tick, clock


def _reqs(cfg, n=4, new_tokens=8):
    rng = np.random.default_rng(7)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        6 + 3 * i).astype(np.int32),
                    max_new_tokens=new_tokens) for i in range(n)]


def _audit_pool(pool):
    """Every held page is exactly the set of index-registered pages and
    each carries refcount 1; everything else is on the free list."""
    held = int((np.asarray(pool.ref[1:]) > 0).sum())
    assert held == len(set(pool.index.values()))
    assert all(pool.ref[p] == 1 for p in pool.index.values())
    assert len(pool.free) == pool.n_pages - 1 - held


# -- page-allocation faults ---------------------------------------------------

def test_alloc_fault_storm_survivors_bit_identical(fp_setup):
    """Vetoed allocations look like pool exhaustion and route through
    backpressure (preempt -> retry); greedy outputs match the fault-free
    run bit-for-bit and every veto is counted."""
    cfg, m, params = fp_setup
    mk = lambda: dict(n_slots=2, max_len=64, paged=True, page_size=8,
                      n_pages=24)
    ref = ServeEngine(m, params, **mk()).serve(_reqs(cfg))
    inj = FaultInjector(FaultConfig(alloc_fail_at=(0, 2, 5),
                                    alloc_fail_every=4, alloc_fail_max=8))
    eng = ServeEngine(m, params, **mk(), faults=inj)
    out = eng.serve(_reqs(cfg))
    met = eng.metrics()
    assert met["faults"]["alloc_failures"] >= 4
    assert met["pressure_events"] >= 1
    assert met["completed"] == len(ref)
    for rid in ref:
        np.testing.assert_array_equal(out[rid], ref[rid])
    _audit_pool(eng._stepper.pool)


def test_alloc_fail_every_liveness_cap(fp_setup):
    """alloc_fail_every=1 vetoes *every* allocation; the alloc_fail_max
    cap guarantees the storm ends and all requests still finish."""
    cfg, m, params = fp_setup
    inj = FaultInjector(FaultConfig(alloc_fail_every=1, alloc_fail_max=6))
    eng = ServeEngine(m, params, n_slots=2, max_len=64, paged=True,
                      page_size=8, n_pages=24, faults=inj)
    out = eng.serve(_reqs(cfg, n=3))
    met = eng.metrics()
    assert met["faults"]["alloc_failures"] == 6
    assert met["completed"] == 3
    assert all(len(out[r]) == 8 for r in out)


def test_pool_exhausted_unreachable_under_chaos(fp_setup):
    """Tiny pool + allocation storm + forced preemptions: serve() never
    raises; every request reaches exactly one terminal outcome and the
    pool's refcounts reconcile afterwards."""
    cfg, m, params = fp_setup
    inj = FaultInjector(FaultConfig(alloc_fail_at=(1, 3, 4),
                                    alloc_fail_every=3, alloc_fail_max=12,
                                    preempt_at=tuple(range(2, 30, 5))))
    eng = ServeEngine(m, params, n_slots=3, max_len=64, paged=True,
                      page_size=8, n_pages=8, faults=inj)
    n = 5
    out = eng.serve(_reqs(cfg, n=n))
    met = eng.metrics()
    terminal = (met["completed"] + met["shed"] + met["expired"]
                + met["truncated"])
    assert terminal == n == len(out)
    assert met["faults"]["alloc_failures"] >= 3
    _audit_pool(eng._stepper.pool)


# -- stalls -------------------------------------------------------------------

def test_stall_burns_fake_clock_and_is_counted(fp_setup):
    """Scheduled stalls burn injected time through ``advance`` (the
    fake clock's, not a real sleep) and surface in the fault counts and
    serve_time_s."""
    cfg, m, params = fp_setup
    tick, clock = _ticker(dt=0.001)

    def advance(dt):
        tick["t"] += dt

    inj = FaultInjector(FaultConfig(stall_at=(1, 3), stall_s=0.5),
                        advance=advance)
    eng = ServeEngine(m, params, n_slots=2, max_len=64, clock=clock,
                      faults=inj)
    eng.serve(_reqs(cfg, n=2, new_tokens=4))
    met = eng.metrics()
    assert met["faults"]["stalls"] == 2
    assert met["serve_time_s"] >= 1.0       # two 0.5 s stalls landed


def test_stalled_run_expires_requests_against_deadline(fp_setup):
    """A hung step pushes the clock past per-request deadlines: the
    affected requests expire (or truncate mid-decode), the loop keeps
    going, and accounting stays exact."""
    cfg, m, params = fp_setup
    tick, clock = _ticker(dt=0.001)
    inj = FaultInjector(FaultConfig(stall_at=(2,), stall_s=60.0),
                        advance=lambda dt: tick.__setitem__(
                            "t", tick["t"] + dt))
    eng = ServeEngine(m, params, n_slots=1, max_len=64, clock=clock,
                      faults=inj)
    reqs = _reqs(cfg, n=3, new_tokens=4)
    for r in reqs:
        r.deadline = 30.0                   # < the 60 s injected hang
    out = eng.serve(reqs)
    met = eng.metrics()
    assert met["faults"]["stalls"] == 1
    assert met["expired"] + met["truncated"] >= 1
    assert (met["completed"] + met["expired"] + met["truncated"]
            + met["shed"]) == 3 == len(out)


# -- forced preemption + bursts ----------------------------------------------

def test_bursty_chaos_traffic_accounting(fp_setup):
    """burstify() collapses seeded arrival spans to simultaneous
    bursts; under bursts + forced preemptions the open-loop run still
    accounts for every request."""
    cfg, m, params = fp_setup
    _, clock = _ticker(dt=0.002)
    fcfg = FaultConfig(seed=3, burst_every=3, burst_span=4,
                       preempt_at=tuple(range(4, 40, 7)))
    inj = FaultInjector(fcfg)
    eng = ServeEngine(m, params, n_slots=2, max_len=64, paged=True,
                      page_size=8, n_pages=24, clock=clock,
                      slo=SLOConfig(seed=1), faults=inj)
    tcfg = TrafficConfig(n_requests=10, rate=200.0, max_new_tokens=4,
                         prompt_len_median=8, prompt_len_max=24,
                         vocab_size=cfg.vocab_size, seed=5)
    trace = burstify(make_trace(tcfg), fcfg)
    res = Scheduler(eng).run_traffic(trace)
    s = res.summary
    assert (s["completed"] + s["shed"] + s["expired"] + s["truncated"]
            == res.traffic["submitted"] == 10)
    assert s["preempted"] == s["resumed"]
    _audit_pool(eng._stepper.pool)


def test_burstify_deterministic_and_order_preserving():
    fcfg = FaultConfig(seed=9, burst_every=3, burst_span=4)
    tcfg = TrafficConfig(n_requests=16, rate=50.0, seed=2)
    a = burstify(make_trace(tcfg), fcfg)
    b = burstify(make_trace(tcfg), fcfg)
    assert [t for t, _ in a] == [t for t, _ in b]        # seeded: same spans
    assert [r.rid for _, r in a] == [r.rid for _, r in b]
    base = make_trace(tcfg)
    assert len(a) == len(base)
    assert sorted(r.rid for _, r in a) == sorted(r.rid for _, r in base)
    times = [t for t, _ in a]
    assert times == sorted(times)                        # still a valid trace
    assert any(t1 == t2 for t1, t2 in zip(times, times[1:]))  # bursts landed


# -- checkpoint write faults --------------------------------------------------

def test_ckpt_fault_leaves_no_partial_step(tmp_path):
    """An injected write error in the atomicity window (payload synced,
    manifest not yet promoted) must leave no half-written step dir and
    latest_step untouched; the next attempt succeeds."""
    d = str(tmp_path / "ckpts")
    tree = {"w": np.arange(8, dtype=np.float32), "step": np.int32(1)}
    inj = FaultInjector(FaultConfig(ckpt_fail_at=(1,)))
    ckpt.save(d, 1, tree, fault_hook=inj.ckpt_hook)      # write #0: clean
    assert ckpt.latest_step(d) == 1
    with pytest.raises(OSError, match="injected checkpoint"):
        ckpt.save(d, 2, tree, fault_hook=inj.ckpt_hook)  # write #1: faulted
    assert inj.counts["ckpt_failures"] == 1
    assert ckpt.latest_step(d) == 1                      # promotion never ran
    entries = sorted(os.listdir(d))
    assert entries == ["step_00000001"]                  # no tmp, no partial
    ckpt.save(d, 2, tree, fault_hook=inj.ckpt_hook)      # write #2: clean
    assert ckpt.latest_step(d) == 2
    restored = ckpt.restore(d, 2, tree)
    np.testing.assert_array_equal(restored["w"], tree["w"])


# -- metrics surface ----------------------------------------------------------

def test_fault_metrics_surface_in_engine_metrics(fp_setup):
    cfg, m, params = fp_setup
    inj = FaultInjector(FaultConfig(alloc_fail_at=(0,), preempt_at=(2,)))
    eng = ServeEngine(m, params, n_slots=2, max_len=64, paged=True,
                      page_size=8, n_pages=24, faults=inj)
    eng.serve(_reqs(cfg, n=2, new_tokens=4))
    f = eng.metrics()["faults"]
    for key in ("alloc_failures", "stalls", "forced_preempts",
                "ckpt_failures", "alloc_calls", "loop_iters",
                "ckpt_writes"):
        assert key in f
    assert f["alloc_calls"] > 0 and f["loop_iters"] > 0
    assert f["alloc_failures"] == 1
