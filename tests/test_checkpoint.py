"""Checkpointing: atomicity, roundtrip, retention, async."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import checkpoint as ckpt
from repro.train.optimizer import AdamW


def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "blocks": {"x": jnp.ones((2, 2), jnp.bfloat16)}},
            "step": jnp.asarray(7, jnp.int32),
            "opt": AdamW().init({"w": jnp.zeros((3, 4))})}


def test_roundtrip(tmp_path):
    tree = _tree()
    path = ckpt.save(str(tmp_path), 7, tree)
    assert os.path.basename(path) == "step_00000007"
    restored = ckpt.restore(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    assert restored["opt"].step.dtype == tree["opt"].step.dtype
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["blocks"]["x"], np.float32),
        np.asarray(tree["params"]["blocks"]["x"], np.float32))


def test_no_tmp_left_behind(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_latest_and_gc(tmp_path):
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, _tree(), keep=3)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000003", "step_00000004", "step_00000005"]


def test_async_save(tmp_path):
    t = ckpt.save_async(str(tmp_path), 9, _tree())
    ckpt.wait_pending()
    assert ckpt.latest_step(str(tmp_path)) == 9
    restored = ckpt.restore(str(tmp_path), 9, _tree())
    assert int(restored["step"]) == 7  # the saved tree's value


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path), 1, _tree())
