"""Chunked prefill: serve == generate token-for-token across the
fp16/kv8 × dense/paged × spec on/off matrix, with a forced-small chunk
so every long prompt actually takes the chunked path, plus TraceCounter
assertions that chunking adds no compiles beyond the bucket grid."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.registry import build_model
from repro.serve import Request, ServeEngine, SpecConfig, self_int8_draft


@pytest.fixture(scope="module")
def fp_setup():
    cfg = ARCHS["llama3-8b"].tiny()
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def kv8_setup():
    cfg = dataclasses.replace(ARCHS["llama3-8b"].tiny(), kv_cache_bits=8)
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _requests(cfg, seed=0):
    # prompt lengths straddle the forced chunk (8): 5 (unchunked), and
    # 17/26/31 (chunked, crossing several bucket boundaries)
    rng = np.random.default_rng(seed)
    lens = [5, 17, 26, 31]
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, n)
                    .astype(np.int32),
                    max_new_tokens=5)
            for i, n in enumerate(lens)]


@pytest.mark.parametrize("cache", ["fp16", "kv8"])
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
@pytest.mark.parametrize("spec", [False, True], ids=["plain", "spec"])
def test_chunked_serve_matches_generate(cache, paged, spec, fp_setup,
                                        kv8_setup):
    cfg, m, params = fp_setup if cache == "fp16" else kv8_setup
    kw = dict(n_slots=2, max_len=48, buckets=(8, 24), prefill_chunk=8)
    if paged:
        kw.update(paged=True, page_size=8)
    if spec:
        kw.update(spec=SpecConfig(k=2, draft=self_int8_draft(m, params)))
    eng = ServeEngine(m, params, **kw)
    assert eng.prefill_chunk == 8
    reqs = _requests(cfg)
    res = eng.serve(reqs)
    mm = eng.metrics()       # snapshot before generate() pollutes counters
    assert mm["chunked_admissions"] == 3
    assert mm["fill_steps"] >= (17 - 8) + (26 - 8) + (31 - 8)
    assert mm["completed"] == len(reqs)
    # chunking rounds to the bucket grid: no compiles beyond it, and the
    # plain decode step keeps its single shape signature
    assert mm["prefill_traces"] <= len(eng.buckets)
    if paged:
        assert eng._decode_paged.traces <= 1
    else:
        assert eng._decode.traces == 1
    ref = ServeEngine(m, params, n_slots=2, max_len=48)
    for r in reqs:
        g = ref.generate(Request(rid=100 + r.rid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens))
        np.testing.assert_array_equal(res[r.rid], g)


def test_chunk_auto_default_and_rounding(fp_setup):
    cfg, m, params = fp_setup
    # auto: second-largest bucket
    eng = ServeEngine(m, params, max_len=64)
    assert eng.buckets == (16, 32, 64) and eng.prefill_chunk == 32
    # single-bucket grid: nothing to chunk to
    eng1 = ServeEngine(m, params, max_len=16)
    assert eng1.prefill_chunk is None
    # explicit chunk rounds *up* to the bucket grid
    eng2 = ServeEngine(m, params, max_len=64, prefill_chunk=20)
    assert eng2.prefill_chunk == 32
    # 0 / None disable
    assert ServeEngine(m, params, max_len=64,
                       prefill_chunk=0).prefill_chunk is None
    assert ServeEngine(m, params, max_len=64,
                       prefill_chunk=None).prefill_chunk is None


def test_chunked_vs_monolithic_identical(fp_setup):
    """The chunk size is a latency knob, never a sampling knob: greedy
    outputs are bit-identical for monolithic, auto, and tiny chunks."""
    cfg, m, params = fp_setup
    reqs = _requests(cfg)
    outs = []
    for chunk in (0, "auto", 8):
        eng = ServeEngine(m, params, n_slots=2, max_len=48,
                          buckets=(8, 24), prefill_chunk=chunk)
        res = eng.serve([Request(rid=r.rid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens)
                         for r in reqs])
        outs.append([res[r.rid] for r in reqs])
    for a, b in zip(outs[0], outs[1]):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(outs[0], outs[2]):
        np.testing.assert_array_equal(a, b)
