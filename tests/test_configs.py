"""Assigned-architecture configs must match the assignment exactly."""
import pytest

from repro.configs import ARCHS

EXPECT = {
    # name: (L, d_model, H, kv, d_ff, vocab)
    "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
    "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
    "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
    "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
    "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    "whisper-small": (12, 768, 12, 12, 3072, 51865),
    "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
    "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
}


@pytest.mark.parametrize("name", sorted(EXPECT))
def test_exact_assigned_config(name):
    cfg = ARCHS[name]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == EXPECT[name]


def test_family_specifics():
    assert ARCHS["llama4-maverick-400b-a17b"].n_experts == 128
    assert ARCHS["llama4-maverick-400b-a17b"].experts_per_token == 1
    assert ARCHS["qwen2-moe-a2.7b"].n_experts == 60
    assert ARCHS["qwen2-moe-a2.7b"].experts_per_token == 4
    assert ARCHS["hymba-1.5b"].ssm_state == 16
    assert ARCHS["hymba-1.5b"].sliding_window == 1024
    assert ARCHS["whisper-small"].n_encoder_layers == 12
    assert ARCHS["qwen2-vl-2b"].mrope_sections == (16, 24, 24)
    assert ARCHS["xlstm-350m"].slstm_every > 0


def test_tiny_configs_build():
    from repro.models.registry import build_model
    for name, cfg in ARCHS.items():
        build_model(cfg.tiny())  # no exceptions
