"""Synthetic data pipeline: determinism, sharding, resume, bias knob."""
import numpy as np

from repro.data.synthetic import (DataConfig, SyntheticLM,
                                  calibration_batches)
from repro.dist.elastic import plan_mesh, resume_batch_indices


def test_deterministic_by_index():
    d1 = SyntheticLM(DataConfig(seed=7))
    d2 = SyntheticLM(DataConfig(seed=7))
    np.testing.assert_array_equal(d1.sequence(42, 64), d2.sequence(42, 64))
    assert not np.array_equal(d1.sequence(42, 64), d1.sequence(43, 64))


def test_host_shards_disjoint_and_complete():
    d = SyntheticLM(DataConfig())
    b0 = d.batch(step=3, batch_size=4, length=8, host=0, n_hosts=2)
    b1 = d.batch(step=3, batch_size=4, length=8, host=1, n_hosts=2)
    all_rows = np.concatenate([b0["tokens"], b1["tokens"]])
    # global single-host batch of 8 covers the same indices
    bg = d.batch(step=3, batch_size=8, length=8, host=0, n_hosts=1)
    assert sorted(map(tuple, all_rows)) == sorted(map(tuple, bg["tokens"]))


def test_resume_indices_match_pipeline():
    idx = resume_batch_indices(step=5, batch_per_host=4, host=1, n_hosts=2)
    assert idx == (41, 43, 45, 47)


def test_bias_knob_changes_distribution():
    d = SyntheticLM(DataConfig())
    fair = calibration_batches(d, 8, 32, biased=False)
    biased = calibration_batches(d, 8, 32, biased=True)
    first_fair = np.concatenate([b["tokens"][:, 0] for b in fair])
    first_biased = np.concatenate([b["tokens"][:, 0] for b in biased])
    assert first_biased.max() < d.cfg.vocab_size // 32
    assert first_fair.max() > first_biased.max()


def test_learnable_structure():
    """The bigram process must be far from uniform (else PPL benchmarks
    are meaningless)."""
    d = SyntheticLM(DataConfig(vocab_size=512))
    assert d.perplexity_upper_bound() < 64  # uniform would be 512


def test_plan_mesh():
    p = plan_mesh(256, model=16, old_data=16)
    assert (p.data, p.idle_chips) == (16, 0)
    p = plan_mesh(252, model=16, old_data=16)  # one host (4 chips) died
    assert p.data == 15 and p.used_chips == 240 and p.idle_chips == 12
    p = plan_mesh(512, model=16, old_data=16, pods=2)
    assert p.data == 16 and p.pods == 2
