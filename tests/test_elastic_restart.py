"""Elastic-restart integration: train, checkpoint, 'lose a host', resume
with a different host count — loss continues from where it left off and
the data pipeline hands out exactly the right indices.  Also covers
restart of a *sharded serve* (DESIGN.md §13): replan the mesh after host
loss and resume in-flight requests without output divergence."""
import os
import subprocess
import sys

import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.dist import checkpoint as ckpt
from repro.dist.elastic import plan_mesh
from repro.launch.quantize import quantize_distributed
from repro.models.registry import build_model
from repro.core import QuantSpec, run_calibration, quantize_model
from repro.train.trainer import TrainConfig, make_train_step


def test_elastic_resume_loss_continuity(tmp_path):
    cfg = ARCHS["llama3-8b"].tiny()
    m = build_model(cfg)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size))
    train_step, opt = make_train_step(m, TrainConfig(lr=3e-3, warmup=5,
                                                     total_steps=40))
    train_step = jax.jit(train_step)
    params = m.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)

    # phase 1: "2 hosts" — each materializes its shard; we emulate both
    for step in range(10):
        shards = [data.batch(step, 4, 32, host=h, n_hosts=2) for h in (0, 1)]
        batch = {k: jnp.asarray(np.concatenate([s[k] for s in shards]))
                 for k in shards[0]}
        params, opt_state, metrics = train_step(params, opt_state, batch)
    loss_before = float(metrics["loss"])
    ckpt.save(str(tmp_path), 10, {"params": params, "opt": opt_state})

    # a host dies: re-plan (16 chips -> 12 usable with model=4)
    plan = plan_mesh(12, model=4, old_data=4)
    assert plan.data == 3 and plan.used_chips == 12

    # phase 2: restore onto "1 host" and continue — data indices differ in
    # layout but training stays stable and loss keeps decreasing
    restored = ckpt.restore(str(tmp_path), 10,
                            {"params": params, "opt": opt_state})
    p2, o2 = restored["params"], restored["opt"]
    losses = []
    for step in range(10, 25):
        batch = {k: jnp.asarray(v)
                 for k, v in data.batch(step, 8, 32, host=0,
                                        n_hosts=1).items()}
        p2, o2, metrics = train_step(p2, o2, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-3:]) < loss_before + 0.1  # no regression spike
    assert int(o2.step) == 25


def test_distributed_quantization_partition_union():
    """Layer-parallel PTQ (launch/quantize.py): the per-process unit
    partitions are disjoint, complete, and each unit's output matches the
    single-process quantize_model result exactly."""
    cfg = ARCHS["llama3-8b"].tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, cfg.vocab_size)}
    stats = run_calibration(m.forward, params, [batch])
    spec = QuantSpec(bits=4, group_size=64)

    owned_all = []
    merged = params
    for pi in range(3):  # emulate 3 processes
        part, _, owned = quantize_distributed(
            m, params, stats, spec=spec, mode="fake",
            process_index=pi, process_count=3)
        owned_all.extend(owned)
        for path_str in owned:
            path = tuple(path_str.split("/"))
            node = part
            for k in path:
                node = node[k]
            # splice into merged
            from repro.core.apply import _set_path
            merged = _set_path(merged, path, node)
    assert sorted(owned_all) == sorted(
        "/".join(p) for p in m.quant_site_map())

    ref, _ = quantize_model(params, m.quant_site_map(), stats,
                            method="faq", spec=spec, mode="fake")
    for a, b in zip(jax.tree_util.tree_leaves(merged),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


@pytest.mark.slow
def test_sharded_serve_elastic_restart():
    """Host loss mid-serve: a (4,2)-mesh engine loses two hosts after 4
    decoded tokens; ``plan_mesh`` replans to (2,2), ``resume_batch_
    indices`` splits the in-flight slots across the survivors (disjoint
    and complete), and each survivor resumes its requests with prompt =
    original prompt + tokens already emitted.  Greedy determinism plus
    mesh-shape identity make the stitched outputs exactly equal the
    uninterrupted single-device serve.  Subprocess: needs 8 virtual
    devices before jax initializes."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = """
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import ARCHS
from repro.core import QuantSpec, quantize_model, run_calibration
from repro.data.synthetic import DataConfig, SyntheticLM, calibration_batches
from repro.dist.elastic import plan_mesh, resume_batch_indices
from repro.launch.mesh import make_local_mesh
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine

cfg = ARCHS["llama3-8b"].tiny()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size))
calib = calibration_batches(data, 4, 32)
stats = run_calibration(model.forward, params,
                        [{k: jnp.asarray(v) for k, v in b.items()}
                         for b in calib])
qp, _ = quantize_model(params, model.quant_site_map(), stats, method="faq",
                       spec=QuantSpec(bits=4, group_size=64), mode="packed")

N_REQ, TOTAL, PRE = 4, 10, 4
prompts = [data.sequence(500 + i, 8 + i) for i in range(N_REQ)]

def serve(idx, prompt_of, budget, **kw):
    eng = ServeEngine(model, qp, n_slots=len(idx), max_len=64, **kw)
    return eng.serve([Request(rid=i, prompt=prompt_of(i),
                              max_new_tokens=budget) for i in idx])

# uninterrupted single-device reference
ref = serve(range(N_REQ), lambda i: prompts[i], TOTAL)

# phase 1: 4 hosts x 2 chips, dies after PRE tokens per request
partial = serve(range(N_REQ), lambda i: prompts[i], PRE,
                mesh=make_local_mesh(4, 2))

# two hosts lost: replan 8 -> 4 chips at fixed model=2
plan = plan_mesh(4, model=2, old_data=4)
assert plan.data == 2 and plan.used_chips == 4, plan
mesh1 = make_local_mesh(plan.data, plan.model)

# survivors split the in-flight slots: disjoint and complete
per_host = N_REQ // plan.data
hosts = [resume_batch_indices(0, per_host, h, plan.data)
         for h in range(plan.data)]
assert sorted(i for hs in hosts for i in hs) == list(range(N_REQ)), hosts

# phase 2: each survivor resumes its share with the emitted prefix
final = {}
for idx in hosts:
    res = serve(idx, lambda i: np.concatenate(
        [np.asarray(prompts[i], np.int32), partial[i]]), TOTAL - PRE,
        mesh=mesh1)
    for i in idx:
        final[i] = np.concatenate([partial[i], res[i]])

for i in range(N_REQ):
    assert final[i].tolist() == ref[i].tolist(), i
print("RESTART-OK")
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"),
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "RESTART-OK" in out.stdout
