"""Additional system invariants (seeded property sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.methods import fuse_stats, window_preview
from repro.core.stats import merge_stats, site_stat
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.dist.elastic import plan_mesh


@pytest.mark.parametrize("seed", range(8))
def test_fuse_is_convex_combination(seed):
    """Fused statistic lies between current and preview pointwise."""
    rng = np.random.default_rng(seed)
    stats = jnp.asarray(np.abs(rng.normal(size=(6, 12))) + 0.01)
    pvw = np.asarray(window_preview(stats, 3))
    fused = np.asarray(fuse_stats(stats, 0.7, 3))
    lo = np.minimum(np.asarray(stats), pvw)
    hi = np.maximum(np.asarray(stats), pvw)
    assert (fused >= lo - 1e-6).all() and (fused <= hi + 1e-6).all()


@pytest.mark.parametrize("seed", range(8))
def test_merge_stats_weighted_mean(seed):
    """Running merge equals the all-at-once mean."""
    rng = np.random.default_rng(seed)
    xs = [jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
          for _ in range(3)]
    parts = [{"s": site_stat(x)} for x in xs]
    acc = parts[0]
    n = 16.0
    for p in parts[1:]:
        acc = merge_stats(acc, p, n, 16.0)
        n += 16.0
    full = {"s": site_stat(jnp.concatenate(xs, axis=0))}
    np.testing.assert_allclose(np.asarray(acc["s"]["mean_abs"]),
                               np.asarray(full["s"]["mean_abs"]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(acc["s"]["mean_sq"]),
                               np.asarray(full["s"]["mean_sq"]), rtol=1e-5)


@pytest.mark.parametrize("chips", [256, 255, 240, 128, 17, 512])
def test_plan_mesh_properties(chips):
    p = plan_mesh(chips, model=16, old_data=16)
    assert p.used_chips <= chips
    assert p.used_chips == p.pods * p.data * p.model
    assert p.idle_chips == chips - p.used_chips
    assert p.idle_chips < p.model  # never waste a full replica row


def test_plan_mesh_too_small():
    with pytest.raises(RuntimeError):
        plan_mesh(8, model=16)


def test_data_step_disjointness():
    """Consecutive steps never reuse a sequence index."""
    d = SyntheticLM(DataConfig())
    seen = set()
    for step in range(5):
        for h in range(2):
            b = d.batch(step, 4, 8, host=h, n_hosts=2)
            rows = {tuple(r) for r in b["tokens"]}
            assert not (rows & seen), "index reuse across steps/hosts"
            seen |= rows


@pytest.mark.parametrize("gamma", [0.0, 0.5, 1.0])
def test_fuse_extremes_window_any(gamma):
    stats = jnp.asarray(np.abs(np.random.default_rng(0).normal(
        size=(5, 8))) + 0.1)
    for w in (1, 2, 4):
        fused = fuse_stats(stats, gamma, w)
        assert fused.shape == stats.shape
        assert bool(jnp.all(fused > 0))
        # last layer has no future: fused == stats regardless of gamma
        np.testing.assert_allclose(np.asarray(fused[-1]),
                                   np.asarray(stats[-1]), rtol=1e-6)


def test_quantized_tensor_tree_roundtrip():
    """QuantizedTensor survives pytree flatten/unflatten and scan slicing."""
    from repro.core import QuantSpec, quantize_groupwise
    w = jax.random.normal(jax.random.PRNGKey(0), (3, 64, 16))
    spec = QuantSpec(bits=4, group_size=32)
    qt = jax.vmap(lambda x: quantize_groupwise(x, spec, pack=True))(w)
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    qt2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert qt2.spec == spec and qt2.n_in == 64 and qt2.packed
    # scan over the leading axis slices every leaf consistently
    def body(c, q):
        from repro.core.quantizer import dequantize_groupwise
        return c, dequantize_groupwise(q).sum()
    _, sums = jax.lax.scan(body, 0, qt)
    assert sums.shape == (3,)
