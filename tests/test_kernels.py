"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantSpec, quantize_groupwise
from repro.kernels import ref
from repro.kernels import ops
from repro.kernels.quant_error import quant_error_pallas
from repro.kernels.quant_matmul import quant_matmul_pallas
from repro.kernels.ops import quant_matmul, quant_matmul_experts


@pytest.mark.parametrize("m,k,n", [(64, 256, 128), (128, 512, 256),
                                   (32, 128, 384)])
@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_kernel_vs_oracle(m, k, n, xdtype):
    ks = jax.random.split(jax.random.PRNGKey(m + k + n), 2)
    w = jax.random.normal(ks[0], (k, n))
    x = jax.random.normal(ks[1], (m, k)).astype(xdtype)
    spec = QuantSpec(bits=4, group_size=128)
    qt = quantize_groupwise(w, spec, pack=True)
    out = quant_matmul_pallas(x.astype(jnp.float32), qt.codes, qt.scale,
                              qt.zero, bm=min(64, m))
    expect = ref.quant_matmul_ref(x.astype(jnp.float32), qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("blocks", [(128, 128), (256, 128), (128, 64)])
def test_quant_matmul_block_shapes(blocks):
    bk, bn = blocks
    w = jax.random.normal(jax.random.PRNGKey(0), (512, 128))
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 512))
    spec = QuantSpec(bits=4, group_size=128)
    qt = quantize_groupwise(w, spec, pack=True)
    out = quant_matmul_pallas(x, qt.codes, qt.scale, qt.zero, bk=bk, bn=bn)
    expect = ref.quant_matmul_ref(x, qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-3)


@pytest.mark.parametrize("a", [1, 5, 21])
@pytest.mark.parametrize("sym", [False, True])
def test_quant_error_kernel_vs_oracle(a, sym):
    k, n = 256, 128
    w = jax.random.normal(jax.random.PRNGKey(a), (k, n))
    scales = jnp.abs(jax.random.normal(jax.random.PRNGKey(a + 1), (a, k))) + 0.5
    msq = jnp.abs(jax.random.normal(jax.random.PRNGKey(a + 2), (k,)))
    spec = QuantSpec(bits=4, group_size=128, symmetric=sym)
    got = quant_error_pallas(w, scales, msq, spec, bk=128, bn=64)
    expect = ref.quant_error_ref(w, scales, msq, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=1e-4)


@pytest.mark.parametrize("k,n,g", [(320, 100, 64), (256, 100, 128),
                                   (320, 128, 64)])
def test_quant_error_kernel_non_tile_shapes(k, n, g):
    """Tile-divisibility regression for the error kernel (RPR007 fix):
    n not a multiple of the column tile pads with zero columns (which
    contribute exactly zero error), and k=320 with the default bk=256
    falls back to bk=g instead of tripping an assert."""
    a = 3
    w = jax.random.normal(jax.random.PRNGKey(k + n), (k, n))
    scales = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (a, k))) + 0.5
    msq = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (k,)))
    spec = QuantSpec(bits=4, group_size=g)
    got = quant_error_pallas(w, scales, msq, spec)
    expect = ref.quant_error_ref(w, scales, msq, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=1e-4)


@pytest.mark.parametrize("m", [1, 3, 130, 192])
@pytest.mark.parametrize("k,n,g", [(128, 1600, 64), (1600, 128, 100),
                                   (1600, 1600, 100)])
def test_quant_matmul_kernel_non_tile_shapes(m, k, n, g):
    """Tile-divisibility regression (hymba d_model=1600: 1600 % 128 = 64
    used to trip the kernel's assert; non-multiple-of-128 m tripped the
    dispatch's wrong row padding).  m/n pad to the tile inside the
    kernel wrapper; k falls back to the group-size tile."""
    w = jax.random.normal(jax.random.PRNGKey(m + n), (k, n))
    x = jax.random.normal(jax.random.PRNGKey(m), (m, k))
    qt = quantize_groupwise(w, QuantSpec(bits=4, group_size=g), pack=True)
    out = quant_matmul_pallas(x, qt.codes, qt.scale, qt.zero)
    assert out.shape == (m, n)
    expect = ref.quant_matmul_ref(x, qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-3)


@pytest.mark.parametrize("m", [1, 3, 130, 192])
def test_ops_dispatch_kernel_path_non_tile_m(m, monkeypatch):
    """The dispatch must pad to the tile the kernel actually uses —
    forced onto the kernel path (interpret mode) so this is exercised
    off-TPU, where the CPU "ref" default used to hide it."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    k, n = 128, 1600            # hymba-shaped n_out
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n))
    x = jax.random.normal(jax.random.PRNGKey(m), (m, k))
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (k,))) + 0.5
    spec = QuantSpec(bits=4, group_size=64)
    qt = quantize_groupwise(w, spec, act_scale=s, pack=True)
    out = quant_matmul(x, qt)
    assert out.shape == (m, n)
    expect = ref.quant_matmul_ref(x, qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-3)


def test_ops_dispatch_leading_dims():
    """quant_matmul handles (B, T, k) activations."""
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 64))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 128))
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (128,))) + 0.5
    spec = QuantSpec(bits=4, group_size=64)
    qt = quantize_groupwise(w, spec, act_scale=s, pack=True)
    out = quant_matmul(x, qt)
    expect = ref.quant_matmul_ref(x, qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4)
    assert out.shape == (2, 8, 64)


def test_expert_quant_matmul():
    e, c, d, f = 4, 8, 64, 32
    w = jax.random.normal(jax.random.PRNGKey(0), (e, d, f))
    x = jax.random.normal(jax.random.PRNGKey(1), (e, c, d))
    spec = QuantSpec(bits=4, group_size=32)
    qt = jax.vmap(lambda ww: quantize_groupwise(ww, spec, pack=True))(w)
    out = quant_matmul_experts(x, qt)
    for i in range(e):
        sub = jax.tree_util.tree_map(lambda a: a[i], qt)
        np.testing.assert_allclose(np.asarray(out[i]),
                                   np.asarray(ref.quant_matmul_ref(x[i], sub)),
                                   atol=1e-4)


@pytest.mark.parametrize("shape", [(4, 256, 64), (2, 128, 128), (3, 384, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_oracle(shape, causal):
    from repro.kernels.flash_attention import (flash_attention_pallas,
                                               flash_attention_ref)
    bh, t, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(t + hd), 3)
    q = jax.random.normal(ks[0], (bh, t, hd))
    k = jax.random.normal(ks[1], (bh, t, hd))
    v = jax.random.normal(ks[2], (bh, t, hd))
    out = flash_attention_pallas(q, k, v, causal=causal)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("t", [37, 150])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_non_tile_seq_len(t, causal):
    """Sequence lengths that don't divide the (bq, bk) tiles pad to the
    tile grid with masked-out keys (RPR007 fix: the kernel used to
    assert divisibility instead of padding)."""
    from repro.kernels.flash_attention import (flash_attention_pallas,
                                               flash_attention_ref)
    bh, hd = 3, 64
    ks = jax.random.split(jax.random.PRNGKey(t), 3)
    q = jax.random.normal(ks[0], (bh, t, hd))
    k = jax.random.normal(ks[1], (bh, t, hd))
    v = jax.random.normal(ks[2], (bh, t, hd))
    out = flash_attention_pallas(q, k, v, causal=causal)
    expect = flash_attention_ref(q, k, v, causal=causal)
    assert out.shape == (bh, t, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5)


def test_flash_attention_gqa_grouped_vs_chunked():
    """Grouped-GQA prefill layout: 4-D q (BKH, G, T, hd) against the
    *unrepeated* k/v must reproduce the model-side chunked attention —
    the wrapper no longer repeats KV to q-heads before the kernel."""
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.common import chunked_attention
    b, t, h, kh, hd = 2, 256, 8, 2, 64
    g = h // kh
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, t, h, hd))
    k = jax.random.normal(ks[1], (b, t, kh, hd))
    v = jax.random.normal(ks[2], (b, t, kh, hd))
    expect = chunked_attention(q, k, v, causal=True, chunk=64)
    qr = q.reshape(b, t, kh, g, hd).transpose(0, 2, 3, 1, 4) \
         .reshape(b * kh, g, t, hd)
    out = flash_attention_pallas(
        qr, k.transpose(0, 2, 1, 3).reshape(b * kh, t, hd),
        v.transpose(0, 2, 1, 3).reshape(b * kh, t, hd), causal=True)
    assert out.shape == (b * kh, g, t, hd)
    out = out.reshape(b, kh, g, t, hd).transpose(0, 3, 1, 2, 4) \
             .reshape(b, t, h, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# Flash-decode kernel family vs the jnp oracles (forced onto the kernel
# path through the ops dispatch: GQA ratios, per-slot cache_len
# including 1 and full, window masking, non-tile head dims).
# ---------------------------------------------------------------------------

def _decode_inputs(b, h, kh, hd, s, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k = jax.random.normal(ks[1], (b, kh, s, hd))     # native (B, KH, S, hd)
    v = jax.random.normal(ks[2], (b, kh, s, hd))
    lens = jnp.array([1, s, 2 * s // 3], jnp.int32)  # 1, full, mid
    return q, k, v, lens


def _q8_caches(k, v):
    """int8-quantize native-layout caches; returns native codes/scales."""
    from repro.models.common import quantize_kv
    kc, ks = quantize_kv(k.transpose(0, 2, 1, 3))
    vc, vs = quantize_kv(v.transpose(0, 2, 1, 3))
    return (kc.transpose(0, 2, 1, 3), ks.transpose(0, 2, 1, 3),
            vc.transpose(0, 2, 1, 3), vs.transpose(0, 2, 1, 3))


def _paged_store(k, v, ps, shuffle_seed=0):
    """Cut native caches into ps-token pages behind a shuffled page
    table with the trash page pinned at physical id 0."""
    b, kh, s, hd = k.shape
    n_per = s // ps
    perm = np.random.RandomState(shuffle_seed).permutation(b * n_per) + 1

    def paged(x):
        pages = x.reshape(b, kh, n_per, ps, x.shape[-1]) \
                 .transpose(0, 2, 1, 3, 4).reshape(b * n_per, kh, ps,
                                                   x.shape[-1])
        store = jnp.zeros((1 + b * n_per,) + pages.shape[1:], pages.dtype)
        return store.at[perm].set(pages)

    table = jnp.asarray(perm.reshape(b, n_per), jnp.int32)
    return paged(k), paged(v), table, paged


@pytest.mark.parametrize("h,kh", [(4, 4), (4, 2), (8, 2)])
@pytest.mark.parametrize("hd", [64, 48])
@pytest.mark.parametrize("window", [None, 32])
def test_flash_decode_dense_vs_ref(h, kh, hd, window, monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    q, k, v, lens = _decode_inputs(3, h, kh, hd, 160)
    out = ops.decode_attention(q, k, v, lens, window=window)
    expect = ref.decode_attention_ref(
        q, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), lens,
        window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5)


@pytest.mark.parametrize("h,kh", [(4, 4), (8, 2)])
@pytest.mark.parametrize("window", [None, 32])
def test_flash_decode_q8_vs_ref(h, kh, window, monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    q, k, v, lens = _decode_inputs(3, h, kh, 64, 160, seed=2)
    kc, ksc, vc, vsc = _q8_caches(k, v)
    out = ops.decode_attention_q8(q, kc, ksc, vc, vsc, lens, window=window)
    expect = ref.decode_attention_q8_ref(
        q, kc.transpose(0, 2, 1, 3), ksc.transpose(0, 2, 1, 3),
        vc.transpose(0, 2, 1, 3), vsc.transpose(0, 2, 1, 3), lens,
        window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("h,kh,hd", [(4, 4, 48), (8, 2, 64)])
@pytest.mark.parametrize("window", [None, 24])
def test_flash_decode_paged_vs_ref(h, kh, hd, window, monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    q, k, v, lens = _decode_inputs(3, h, kh, hd, 128, seed=3)
    k_st, v_st, table, _ = _paged_store(k, v, ps=16)
    out = ops.paged_decode_attention(q, k_st, v_st, table, lens,
                                     window=window)
    expect = ref.paged_decode_attention_ref(q, k_st, v_st, table, lens,
                                            window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5)


@pytest.mark.parametrize("window", [None, 24])
def test_flash_decode_paged_q8_vs_ref(window, monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    q, k, v, lens = _decode_inputs(3, 8, 2, 64, 128, seed=4)
    kc, ksc, vc, vsc = _q8_caches(k, v)
    _, _, table, paged = _paged_store(k, v, ps=16)
    k_st, ks_st = paged(kc), paged(ksc)
    v_st, vs_st = paged(vc), paged(vsc)
    out = ops.paged_decode_attention_q8(q, k_st, ks_st, v_st, vs_st, table,
                                        lens, window=window)
    expect = ref.paged_decode_attention_q8_ref(q, k_st, ks_st, v_st, vs_st,
                                               table, lens, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-4, rtol=1e-4)


def test_flash_decode_ref_mode_dispatch(monkeypatch):
    """mode=ref must bypass the kernel and hit the oracle bit-exactly."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "ref")
    q, k, v, lens = _decode_inputs(3, 8, 2, 64, 96, seed=5)
    out = ops.decode_attention(q, k, v, lens)
    expect = ref.decode_attention_ref(
        q, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), lens)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_expert_quant_matmul_kernel_path(monkeypatch):
    """quant_matmul_experts must honor _mode(): forced onto the kernel
    path, every expert goes through quant_matmul_pallas and still
    matches the vmapped ref."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    e, c, d, f = 4, 8, 64, 32
    w = jax.random.normal(jax.random.PRNGKey(0), (e, d, f))
    x = jax.random.normal(jax.random.PRNGKey(1), (e, c, d))
    spec = QuantSpec(bits=4, group_size=32)
    qt = jax.vmap(lambda ww: quantize_groupwise(ww, spec, pack=True))(w)
    out = quant_matmul_experts(x, qt)
    for i in range(e):
        sub = jax.tree_util.tree_map(lambda a: a[i], qt)
        np.testing.assert_allclose(np.asarray(out[i]),
                                   np.asarray(ref.quant_matmul_ref(x[i], sub)),
                                   atol=1e-3)


def test_flash_attention_matches_chunked_model_path():
    """Kernel agrees with the model-side chunked attention (GQA layout)."""
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.common import chunked_attention, _repeat_kv
    b, t, h, kh, hd = 2, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, t, h, hd))
    k = jax.random.normal(ks[1], (b, t, kh, hd))
    v = jax.random.normal(ks[2], (b, t, kh, hd))
    ref = chunked_attention(q, k, v, causal=True, chunk=64)
    kr = _repeat_kv(k, h // kh)
    vr = _repeat_kv(v, h // kh)
    out = flash_attention_pallas(
        q.transpose(0, 2, 1, 3).reshape(b * h, t, hd),
        kr.transpose(0, 2, 1, 3).reshape(b * h, t, hd),
        vr.transpose(0, 2, 1, 3).reshape(b * h, t, hd), causal=True)
    out = out.reshape(b, h, t, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
