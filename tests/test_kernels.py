"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantSpec, quantize_groupwise
from repro.kernels import ref
from repro.kernels.quant_error import quant_error_pallas
from repro.kernels.quant_matmul import quant_matmul_pallas
from repro.kernels.ops import quant_matmul, quant_matmul_experts


@pytest.mark.parametrize("m,k,n", [(64, 256, 128), (128, 512, 256),
                                   (32, 128, 384)])
@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_kernel_vs_oracle(m, k, n, xdtype):
    ks = jax.random.split(jax.random.PRNGKey(m + k + n), 2)
    w = jax.random.normal(ks[0], (k, n))
    x = jax.random.normal(ks[1], (m, k)).astype(xdtype)
    spec = QuantSpec(bits=4, group_size=128)
    qt = quantize_groupwise(w, spec, pack=True)
    out = quant_matmul_pallas(x.astype(jnp.float32), qt.codes, qt.scale,
                              qt.zero, bm=min(64, m))
    expect = ref.quant_matmul_ref(x.astype(jnp.float32), qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("blocks", [(128, 128), (256, 128), (128, 64)])
def test_quant_matmul_block_shapes(blocks):
    bk, bn = blocks
    w = jax.random.normal(jax.random.PRNGKey(0), (512, 128))
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 512))
    spec = QuantSpec(bits=4, group_size=128)
    qt = quantize_groupwise(w, spec, pack=True)
    out = quant_matmul_pallas(x, qt.codes, qt.scale, qt.zero, bk=bk, bn=bn)
    expect = ref.quant_matmul_ref(x, qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-3)


@pytest.mark.parametrize("a", [1, 5, 21])
@pytest.mark.parametrize("sym", [False, True])
def test_quant_error_kernel_vs_oracle(a, sym):
    k, n = 256, 128
    w = jax.random.normal(jax.random.PRNGKey(a), (k, n))
    scales = jnp.abs(jax.random.normal(jax.random.PRNGKey(a + 1), (a, k))) + 0.5
    msq = jnp.abs(jax.random.normal(jax.random.PRNGKey(a + 2), (k,)))
    spec = QuantSpec(bits=4, group_size=128, symmetric=sym)
    got = quant_error_pallas(w, scales, msq, spec, bk=128, bn=64)
    expect = ref.quant_error_ref(w, scales, msq, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=1e-4)


@pytest.mark.parametrize("m", [1, 3, 130, 192])
@pytest.mark.parametrize("k,n,g", [(128, 1600, 64), (1600, 128, 100),
                                   (1600, 1600, 100)])
def test_quant_matmul_kernel_non_tile_shapes(m, k, n, g):
    """Tile-divisibility regression (hymba d_model=1600: 1600 % 128 = 64
    used to trip the kernel's assert; non-multiple-of-128 m tripped the
    dispatch's wrong row padding).  m/n pad to the tile inside the
    kernel wrapper; k falls back to the group-size tile."""
    w = jax.random.normal(jax.random.PRNGKey(m + n), (k, n))
    x = jax.random.normal(jax.random.PRNGKey(m), (m, k))
    qt = quantize_groupwise(w, QuantSpec(bits=4, group_size=g), pack=True)
    out = quant_matmul_pallas(x, qt.codes, qt.scale, qt.zero)
    assert out.shape == (m, n)
    expect = ref.quant_matmul_ref(x, qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-3)


@pytest.mark.parametrize("m", [1, 3, 130, 192])
def test_ops_dispatch_kernel_path_non_tile_m(m, monkeypatch):
    """The dispatch must pad to the tile the kernel actually uses —
    forced onto the kernel path (interpret mode) so this is exercised
    off-TPU, where the CPU "ref" default used to hide it."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    k, n = 128, 1600            # hymba-shaped n_out
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n))
    x = jax.random.normal(jax.random.PRNGKey(m), (m, k))
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (k,))) + 0.5
    spec = QuantSpec(bits=4, group_size=64)
    qt = quantize_groupwise(w, spec, act_scale=s, pack=True)
    out = quant_matmul(x, qt)
    assert out.shape == (m, n)
    expect = ref.quant_matmul_ref(x, qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-3)


def test_ops_dispatch_leading_dims():
    """quant_matmul handles (B, T, k) activations."""
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 64))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 128))
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (128,))) + 0.5
    spec = QuantSpec(bits=4, group_size=64)
    qt = quantize_groupwise(w, spec, act_scale=s, pack=True)
    out = quant_matmul(x, qt)
    expect = ref.quant_matmul_ref(x, qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4)
    assert out.shape == (2, 8, 64)


def test_expert_quant_matmul():
    e, c, d, f = 4, 8, 64, 32
    w = jax.random.normal(jax.random.PRNGKey(0), (e, d, f))
    x = jax.random.normal(jax.random.PRNGKey(1), (e, c, d))
    spec = QuantSpec(bits=4, group_size=32)
    qt = jax.vmap(lambda ww: quantize_groupwise(ww, spec, pack=True))(w)
    out = quant_matmul_experts(x, qt)
    for i in range(e):
        sub = jax.tree_util.tree_map(lambda a: a[i], qt)
        np.testing.assert_allclose(np.asarray(out[i]),
                                   np.asarray(ref.quant_matmul_ref(x[i], sub)),
                                   atol=1e-4)


@pytest.mark.parametrize("shape", [(4, 256, 64), (2, 128, 128), (3, 384, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_oracle(shape, causal):
    from repro.kernels.flash_attention import (flash_attention_pallas,
                                               flash_attention_ref)
    bh, t, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(t + hd), 3)
    q = jax.random.normal(ks[0], (bh, t, hd))
    k = jax.random.normal(ks[1], (bh, t, hd))
    v = jax.random.normal(ks[2], (bh, t, hd))
    out = flash_attention_pallas(q, k, v, causal=causal)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_attention_matches_chunked_model_path():
    """Kernel agrees with the model-side chunked attention (GQA layout)."""
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.common import chunked_attention, _repeat_kv
    b, t, h, kh, hd = 2, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, t, h, hd))
    k = jax.random.normal(ks[1], (b, t, kh, hd))
    v = jax.random.normal(ks[2], (b, t, kh, hd))
    ref = chunked_attention(q, k, v, causal=True, chunk=64)
    kr = _repeat_kv(k, h // kh)
    vr = _repeat_kv(v, h // kh)
    out = flash_attention_pallas(
        q.transpose(0, 2, 1, 3).reshape(b * h, t, hd),
        kr.transpose(0, 2, 1, 3).reshape(b * h, t, hd),
        vr.transpose(0, 2, 1, 3).reshape(b * h, t, hd), causal=True)
    out = out.reshape(b, h, t, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
