"""RTN/AWQ/FAQ method-level tests (paper Eq. 4, 5, 8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantSpec
from repro.core.methods import (candidate_scale, full_search_faq, fuse_stats,
                                normalize_scale, search_alpha,
                                site_stat_for_method, window_preview)


def test_window_preview_exact():
    stats = jnp.arange(20, dtype=jnp.float32).reshape(5, 4)
    pvw = window_preview(stats, 3)
    # layer 0: mean(rows 1..3); layer 3: row 4; layer 4 (last): itself
    np.testing.assert_allclose(pvw[0], np.mean(np.arange(20).reshape(5, 4)[1:4], 0))
    np.testing.assert_allclose(pvw[3], stats[4])
    np.testing.assert_allclose(pvw[4], stats[4])


def test_window_clamps_at_end():
    stats = jax.random.uniform(jax.random.PRNGKey(0), (6, 8)) + 0.1
    for w in (1, 2, 3, 10):
        pvw = window_preview(stats, w)
        assert pvw.shape == stats.shape
        np.testing.assert_allclose(pvw[-1], stats[-1], rtol=1e-6)


def test_fuse_gamma_limits():
    stats = jax.random.uniform(jax.random.PRNGKey(1), (4, 8)) + 0.1
    # gamma=1 -> pure current-layer (AWQ limit)
    np.testing.assert_allclose(fuse_stats(stats, 1.0, 3), stats, rtol=1e-6)
    pvw = window_preview(stats, 3)
    np.testing.assert_allclose(fuse_stats(stats, 0.0, 3), pvw, rtol=1e-6)


def test_normalize_scale_invariance():
    """Scaling the statistic by a constant must not change the search."""
    a = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (64,))) + 0.1
    s1 = candidate_scale(a, 0.5)
    s2 = candidate_scale(a * 17.0, 0.5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4)


def test_search_alpha_beats_or_ties_rtn():
    """The searched scale's loss can never exceed the RTN loss, since
    alpha=0 (s=1) is in the grid."""
    for seed in range(6):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        w = jax.random.normal(ks[0], (128, 64))
        chan = jnp.exp(jax.random.normal(ks[1], (128,)))
        sample = jax.random.normal(ks[2], (32, 128)) * chan
        a = jnp.mean(jnp.abs(sample), axis=0)
        res = search_alpha(w, a, QuantSpec(bits=3, group_size=64),
                           sample=sample)
        assert float(res.loss) <= float(res.rtn_loss) + 1e-6


def test_method_stats_dispatch():
    stats = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (4, 16))) + 0.1
    assert site_stat_for_method("rtn", stats) is None
    np.testing.assert_allclose(site_stat_for_method("awq", stats), stats)
    faq = site_stat_for_method("faq", stats, gamma=0.85, window=3)
    assert faq.shape == stats.shape
    assert not np.allclose(np.asarray(faq)[:-1], np.asarray(stats)[:-1])
    with pytest.raises(ValueError):
        site_stat_for_method("gptq", stats)


def test_full_search_no_worse_than_presearched():
    """Eq. 8's joint search must achieve <= the pre-searched config loss."""
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    L, n, m = 3, 128, 32
    w = jax.random.normal(ks[0], (L, n, m))
    stats = jnp.abs(jax.random.normal(ks[1], (L, n))) + 0.1
    msq = stats ** 2
    spec = QuantSpec(bits=3, group_size=64)
    best = full_search_faq(w, stats, spec, mean_sq=msq)
    # presearched config loss per layer
    fused = fuse_stats(stats, 0.85, 3)
    pre = jax.vmap(lambda ww, aa, mm: search_alpha(ww, aa, spec, mean_sq=mm)
                   )(w, fused, msq)
    assert np.all(np.asarray(best["loss"]) <= np.asarray(pre.loss) + 1e-6)
