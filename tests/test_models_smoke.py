"""Per-architecture smoke tests: reduced config, forward + train step on CPU,
shape/NaN assertions, prefill/decode consistency (deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models.registry import build_model
from repro.train.trainer import TrainConfig, make_train_step


def make_batch(cfg, key, B=2, T=16, train=False):
    batch = {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab_size)}
    if train:
        batch["labels"] = batch["tokens"]
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_len, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.patch_len, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg = ARCHS[arch].tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = jax.jit(
        lambda p, b: m.forward(p, b, collect_stats=True))(params, batch)
    assert logits.shape[:2] == (2, 16)
    assert logits.shape[2] >= cfg.vocab_size
    assert not bool(jnp.isnan(logits).any())
    assert aux["stats"], "no calibration sites collected"
    for site, st in aux["stats"].items():
        assert st["mean_abs"].ndim == 2, site
        assert not bool(jnp.isnan(st["mean_abs"]).any()), site


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_one_train_step(arch):
    cfg = ARCHS[arch].tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1), train=True)
    train_step, opt = make_train_step(m, TrainConfig(total_steps=10))
    opt_state = opt.init(params)
    params2, opt_state, metrics = jax.jit(train_step)(params, opt_state, batch)
    assert float(metrics["loss"]) > 0 and not jnp.isnan(metrics["loss"])
    # params actually changed
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, params2)
    assert max(jax.tree_util.tree_leaves(diffs)) > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_consistency(arch):
    cfg = ARCHS[arch].tiny()
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, moe_capacity_factor=16.0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    B, T = batch["tokens"].shape
    extra = T + (cfg.patch_len if cfg.family == "vlm" else 0)
    cache = m.init_cache(B, extra + 8)
    kw = {}
    if cfg.family == "audio":
        kw["frames"] = batch["frames"]
    if cfg.family == "vlm":
        kw["patches"] = batch["patches"]
    logits, _ = jax.jit(lambda p, b: m.forward(p, b))(params, batch)
    lp, cache = jax.jit(lambda p, t, c: m.prefill(p, t, c, **kw))(
        params, batch["tokens"], cache)
    assert float(jnp.max(jnp.abs(lp[:, 0] - logits[:, -1]))) < 1e-4
    nxt = jnp.argmax(lp[:, 0, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    ld, cache = jax.jit(m.decode_step)(params, cache, nxt)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nxt], 1)
    lf, _ = jax.jit(lambda p, b: m.forward(p, b))(params, batch2)
    assert float(jnp.max(jnp.abs(ld[:, 0] - lf[:, -1]))) < 1e-3, arch
