"""Observability layer (DESIGN.md §17): metrics registry semantics, the
frozen engine-metrics surface, span tracing determinism, and the
bounded trace ring under overload."""
import json

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.registry import build_model
from repro.obs import (DEFAULT_MS_EDGES, Histogram, MetricsRegistry,
                       Tracer, check_span_nesting, dist_ms,
                       never_nan_percentile, validate_trace)
from repro.serve import (FaultConfig, FaultInjector, Request, Scheduler,
                         ServeEngine, TrafficConfig, make_trace)


@pytest.fixture(scope="module")
def fp_setup():
    cfg = ARCHS["llama3-8b"].tiny()
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _ticker(dt=0.001):
    tick = {"t": 0.0}

    def clock():
        tick["t"] += dt
        return tick["t"]
    return clock


# -- registry primitives ------------------------------------------------------

def test_counter_gauge_histogram_snapshot_delta():
    r = MetricsRegistry()
    c = r.counter("serve.tokens")
    c.inc(5)
    r.gauge("pool.in_use").set(7)
    h = r.histogram("serve.step_ms")
    for x in (0.5, 3.0, 30.0, 3000.0):
        h.observe(x)
    snap = r.snapshot()
    assert snap["serve.tokens"] == 5
    assert snap["pool.in_use"] == 7
    assert snap["serve.step_ms"]["count"] == 4
    c.inc(2)
    h.observe(1.0)
    r.gauge("pool.in_use").set(3)
    d = r.delta(snap)
    # counters and histograms subtract; gauges report current
    assert d["serve.tokens"] == 2
    assert d["pool.in_use"] == 3
    assert d["serve.step_ms"]["count"] == 1
    assert sum(d["serve.step_ms"]["counts"]) == 1


def test_labels_qualify_names_and_kinds_clash():
    r = MetricsRegistry()
    r.counter("serve.shed_by_tenant", tenant="a").inc()
    r.counter("serve.shed_by_tenant", tenant="b").inc(2)
    snap = r.snapshot()
    assert snap["serve.shed_by_tenant{tenant=a}"] == 1
    assert snap["serve.shed_by_tenant{tenant=b}"] == 2
    with pytest.raises(TypeError):
        r.gauge("serve.shed_by_tenant", tenant="a")


def test_metric_group_mapping_protocol_and_rebind():
    r1, r2 = MetricsRegistry(), MetricsRegistry()
    g = r1.group("faults").init(stalls=0, preempts=0)
    g["stalls"] += 3
    assert dict(g) == {"stalls": 3, "preempts": 0}
    assert "stalls" in g and len(g) == 2
    assert sorted(g.keys()) == ["preempts", "stalls"]
    g.rebind(r2)
    g["preempts"] += 1
    assert r2.snapshot()["faults.preempts"] == 1
    assert r2.snapshot()["faults.stalls"] == 3  # value survives the move


def test_counter_preserves_value_type():
    r = MetricsRegistry()
    g = r.group("serve").init(steps=0, serve_time_s=0.0)
    g["steps"] += 1
    g["serve_time_s"] += 0.25
    assert isinstance(g["steps"], int)
    assert isinstance(g["serve_time_s"], float)


# -- shared percentile math ---------------------------------------------------

def test_never_nan_percentile_hardening():
    assert never_nan_percentile([], 99) == 0.0
    assert never_nan_percentile([float("nan"), float("inf")], 50) == 0.0
    xs = list(range(1, 101))
    assert never_nan_percentile(xs, 50) == float(np.percentile(xs, 50))


def test_dist_ms_frozen_shape():
    # the exact shape loadgen.summarize always reported
    assert dist_ms([]) == dict(p50=0.0, p95=0.0, p99=0.0, mean=0.0, n=0)
    d = dist_ms([0.1, 0.2, 0.3])
    assert set(d) == {"p50", "p95", "p99", "mean", "n"} and d["n"] == 3
    assert d["p50"] == pytest.approx(200.0)


def test_histogram_buckets_and_percentile():
    h = Histogram.from_samples([0.5, 2.0, 8.0, 40.0, 999.0, 50_000.0])
    s = h.snapshot()
    assert s["count"] == 6 and s["counts"][-1] == 1     # overflow bucket
    assert len(s["counts"]) == len(DEFAULT_MS_EDGES) + 1
    assert 0.0 < h.percentile(50) <= 1000.0
    assert h.percentile(0) >= 0.0
    with pytest.raises(ValueError):
        Histogram(edges=(5.0, 1.0))


# -- frozen metrics surface ---------------------------------------------------

FROZEN_SUMMARY_KEYS = {
    "requests", "completed", "expired", "truncated", "shed", "preempted",
    "resumed", "tokens_generated", "tokens_per_s", "tokens_per_step",
    "tokens_per_step_by_request", "spec",
}

FROZEN_METRIC_KEYS = {
    "tokens_generated", "decode_steps", "prefill_batches", "completed",
    "expired", "truncated", "shed", "shed_retried", "preempted", "resumed",
    "admitted", "pressure_events", "serve_time_s", "prefill_calls",
    "prefill_traces", "decode_traces", "retrace_count", "paged", "buckets",
    "spec", "faults", "prefill_chunk", "chunked_admissions",
    "tokens_per_step", "tokens_per_s",
}


def test_engine_metrics_keys_and_summary_frozen(fp_setup):
    cfg, m, params = fp_setup
    eng = ServeEngine(m, params, n_slots=2, max_len=64)
    sched = Scheduler(eng)
    for i in range(3):
        sched.submit(Request(rid=i, prompt=np.arange(1, 7, dtype=np.int32),
                             max_new_tokens=4))
    res = sched.run()
    mm = eng.metrics()
    missing = FROZEN_METRIC_KEYS - set(mm)
    assert not missing, f"frozen metrics keys went missing: {missing}"
    assert FROZEN_SUMMARY_KEYS == set(res.summary)
    assert res.summary["completed"] == 3
    assert res.summary["tokens_generated"] == 12
    # the registry delta rides along, qualified-name keyed
    assert res.registry_delta["serve.completed"] == 3
    assert res.registry_delta["serve.tokens_generated"] == 12
    # per-entry retrace breakdown sums to the old opaque counter
    assert sum(mm["retrace_by_entry"].values()) == mm["retrace_count"]


def test_summary_is_delta_not_lifetime(fp_setup):
    cfg, m, params = fp_setup
    eng = ServeEngine(m, params, n_slots=2, max_len=64)
    sched = Scheduler(eng)
    for run in range(2):
        sched.submit(Request(rid=run, prompt=np.arange(1, 5, dtype=np.int32),
                             max_new_tokens=3))
        s = sched.run().summary
        assert s["completed"] == 1 and s["tokens_generated"] == 3


# -- span tracing -------------------------------------------------------------

def _traced_run(cfg, m, params, *, capacity=8192):
    tracer = Tracer(capacity=capacity)
    eng = ServeEngine(m, params, n_slots=2, max_len=64,
                      clock=_ticker(), tracer=tracer)
    tcfg = TrafficConfig(n_requests=8, rate=100.0, max_new_tokens=4,
                         prompt_len_median=6, prompt_len_max=20,
                         vocab_size=cfg.vocab_size, seed=7)
    Scheduler(eng).run_traffic(make_trace(tcfg))
    return eng, tracer


def test_trace_export_deterministic_bytes(tmp_path, fp_setup):
    cfg, m, params = fp_setup
    paths = []
    for i in range(2):
        eng, _ = _traced_run(cfg, m, params)
        p = tmp_path / f"trace{i}.json"
        eng.export_trace(p)
        paths.append(p)
    b0, b1 = paths[0].read_bytes(), paths[1].read_bytes()
    assert b0 == b1, "fake-clock trace export must be byte-identical"
    obj = json.loads(b0)
    assert validate_trace(obj) == []
    names = {e["name"] for e in obj["traceEvents"]}
    assert {"queue", "prefill", "decode", "arrival", "retire"} <= names


def test_spans_nest_across_preempt_resume(fp_setup):
    """A forced preemption closes the decode span and the resume opens a
    fresh queue/prefill/decode triple; all spans stay balanced."""
    cfg, m, params = fp_setup
    tracer = Tracer()
    faults = FaultInjector(FaultConfig(preempt_at=(3,)))
    eng = ServeEngine(m, params, n_slots=2, max_len=64, clock=_ticker(),
                      tracer=tracer, faults=faults)
    reqs = [Request(rid=i, prompt=np.arange(1, 8, dtype=np.int32),
                    max_new_tokens=6) for i in range(3)]
    eng.serve(reqs)
    assert eng.metrics()["preempted"] >= 1
    events = tracer.events()
    assert check_span_nesting(events) == []
    names = [e["name"] for e in events]
    assert "preempt" in names
    # the preempted request's row shows two queue spans (original +
    # resume) and its decode span carries the preempt outcome
    pre = [e for e in events if e["name"] == "preempt"][0]
    rid = pre["tid"]
    row = [e for e in events if e.get("tid") == rid]
    assert sum(1 for e in row if e["name"] == "queue") == 2
    outcomes = [e.get("args", {}).get("outcome")
                for e in row if e["name"] == "decode"]
    assert "preempt" in outcomes


def test_spans_cover_chunked_prefill(fp_setup):
    cfg, m, params = fp_setup
    tracer = Tracer()
    eng = ServeEngine(m, params, n_slots=2, max_len=64, clock=_ticker(),
                      tracer=tracer, prefill_chunk=8)
    long_prompt = (np.arange(40) % cfg.vocab_size + 1).astype(np.int32)
    eng.serve([Request(rid=0, prompt=long_prompt, max_new_tokens=4)])
    assert eng.metrics()["chunked_admissions"] == 1
    events = tracer.events()
    assert check_span_nesting(events) == []
    names = [e["name"] for e in events]
    assert "chunked_admit" in names and "fill_done" in names
    # the prefill span covers the teacher-forced fill: it ends at the
    # first emitted token, after fill_done
    fill_done = [e for e in events if e["name"] == "fill_done"][0]
    prefill = [e for e in events if e["name"] == "prefill"][0]
    assert prefill["ts"] + prefill["dur"] >= fill_done["ts"]


def test_trace_ring_bounded_under_storm(fp_setup):
    cfg, m, params = fp_setup
    eng, tracer = _traced_run(cfg, m, params, capacity=64)
    assert len(tracer.events()) <= 64
    assert tracer.dropped > 0
    obj = tracer.to_json()
    assert validate_trace(obj) == []
    assert obj["otherData"]["dropped"] == tracer.dropped
    # ring eviction drops whole complete events, never halves: nesting
    # of what remains is still balanced
    assert check_span_nesting(tracer.events()) == []


def test_step_spans_and_histogram(fp_setup):
    cfg, m, params = fp_setup
    tracer = Tracer()
    eng = ServeEngine(m, params, n_slots=2, max_len=64, clock=_ticker(),
                      tracer=tracer)
    eng.serve([Request(rid=0, prompt=np.arange(1, 6, dtype=np.int32),
                       max_new_tokens=4)])
    phases = {e["name"] for e in tracer.events() if e.get("cat") == "step"}
    assert {"admit", "decode_step", "sampler_sync"} <= phases
    snap = eng.registry.snapshot()
    assert snap["serve.step_ms{phase=decode_step}"]["count"] \
        == eng.metrics()["decode_steps"]


def test_untraced_engine_has_no_trace_key(fp_setup):
    cfg, m, params = fp_setup
    eng = ServeEngine(m, params, n_slots=1, max_len=64)
    eng.serve([Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32),
                       max_new_tokens=2)])
    assert "trace" not in eng.metrics()
    with pytest.raises(ValueError):
        eng.export_trace("/tmp/never-written.json")


def test_compile_events_and_retrace_by_entry(fp_setup):
    cfg, m, params = fp_setup
    tracer = Tracer()
    eng = ServeEngine(m, params, n_slots=2, max_len=64, clock=_ticker(),
                      tracer=tracer)
    eng.serve([Request(rid=i, prompt=np.arange(1, 6 + i, dtype=np.int32),
                       max_new_tokens=3) for i in range(2)])
    jit_events = [e for e in tracer.events() if e.get("cat") == "jit"]
    assert any(e["name"] == "compile" for e in jit_events)
    entries = {e["args"]["entry"] for e in jit_events}
    assert "decode" in entries
    snap = eng.registry.snapshot()
    assert snap["serve.jit_traces{entry=decode}"] \
        == eng._decode.traces
