"""Overload response: SLO-aware admission (shed/quota/fairness),
page-pool backpressure with preemption, and resume-by-recompute
bit-identity (DESIGN.md §16).  Everything timing-sensitive runs on the
injected fake clock."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.registry import build_model
from repro.serve import (FaultConfig, FaultInjector, Request, Scheduler,
                         ServeEngine, SLOAdmission, SLOConfig, request_tokens)
from repro.serve.overload import pick_victim
from repro.serve.slots import SlotTable, effective_prompt


@pytest.fixture(scope="module")
def fp_setup():
    cfg = ARCHS["llama3-8b"].tiny()
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _ticker(dt=0.001):
    tick = {"t": 0.0}

    def clock():
        tick["t"] += dt
        return tick["t"]
    return tick, clock


def _prompt(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, n).astype(np.int32)


# -- SLOAdmission unit behavior -----------------------------------------------

def test_request_tokens_constant_across_progress():
    """The admission cost never changes as a request emits tokens, so
    quota acquire/release stays symmetric through preempt/resume."""
    r = Request(rid=0, prompt=np.ones(10, np.int32), max_new_tokens=6)
    before = request_tokens(r)
    r.out_tokens = [1, 2, 3]
    assert request_tokens(r) == before == 16


def test_slo_estimate_and_shed_gate():
    slo = SLOAdmission(SLOConfig(margin=1.0, window=8, pct=100.0))
    assert slo.estimate() == 0.0
    for d in (0.1, 0.2, 0.3):
        slo.observe(d)
    assert slo.estimate() == pytest.approx(0.3)
    req = Request(rid=0, prompt=np.ones(4, np.int32))
    req.deadline = 10.0
    assert not slo.should_shed(req, now=9.5)    # 9.5 + 0.3 <= 10
    assert slo.should_shed(req, now=9.8)        # 9.8 + 0.3 > 10
    req.deadline = None
    assert not slo.should_shed(req, now=1e9)    # no SLO, never shed


def test_slo_retry_after_seeded_and_exponential():
    a, b = (SLOAdmission(SLOConfig(retry_base_s=0.1, seed=5))
            for _ in range(2))
    req = Request(rid=0, prompt=np.ones(4, np.int32))
    req.retries = 1
    r1, r2 = a.retry_after(req), a.retry_after(req)
    # same seed -> same jitter sequence (and jitter actually moves)
    assert r1 != r2
    assert [r1, r2] == [b.retry_after(req), b.retry_after(req)]
    # backoff doubles per retry (jitter in [0.5, 1.5) of the base)
    req.retries = 3
    assert 0.2 <= a.retry_after(req) < 0.6


def test_slo_quota_and_fairness():
    slo = SLOAdmission(SLOConfig(quota_tokens=40, quotas={"vip": 200},
                                 weights={"heavy": 4.0}))
    small = Request(rid=0, prompt=np.ones(10, np.int32), max_new_tokens=6,
                    tenant="t1")
    assert slo.quota_ok(small)
    slo.acquire(small)
    assert slo.quota_ok(small)          # 16 + 16 = 32 <= 40
    slo.acquire(small)
    assert not slo.quota_ok(small)      # 32 + 16 > 40
    slo.release(small)
    assert slo.quota_ok(small)
    vip = Request(rid=1, prompt=np.ones(100, np.int32), max_new_tokens=6,
                  tenant="vip")
    assert slo.quota_ok(vip)            # per-tenant override
    # start-time fairness: a heavy-weight tenant's vtime advances slower
    heavy = Request(rid=2, prompt=np.ones(10, np.int32), max_new_tokens=6,
                    tenant="heavy")
    light = Request(rid=3, prompt=np.ones(10, np.int32), max_new_tokens=6,
                    tenant="light")
    keys = [(slo.fair_key(heavy), "h") for _ in range(4)]
    keys += [(slo.fair_key(light), "l") for _ in range(4)]
    ordered = [tag for _, tag in sorted(keys, key=lambda kv: kv[0])]
    # at equal deadlines the light tenant's later submissions interleave
    # ahead of the heavy tenant's backlog tail
    assert ordered.index("l") < len(keys) - 1
    assert ordered[-1] == "l"           # light's vtime grows 4x faster


def test_pick_victim_excludes_pressure_slot():
    st = SlotTable(3)
    for s in range(3):
        st.bind(Request(rid=s, prompt=np.ones(2, np.int32),
                        max_new_tokens=4), s)
    st.req[0].deadline = 5.0
    st.req[1].deadline = 9.0
    st.req[2].deadline = None           # latest (inf) -> victim
    assert pick_victim(st) == 2
    assert pick_victim(st, exclude=2) == 1
    st.clear(1)
    st.clear(0)
    assert pick_victim(st, exclude=2) == 2      # sole slot stays eligible


# -- scheduler integration ----------------------------------------------------

def test_submit_rejects_duplicate_rid(fp_setup):
    cfg, m, params = fp_setup
    sch = Scheduler(ServeEngine(m, params, n_slots=1, max_len=32))
    sch.submit(Request(rid=7, prompt=_prompt(cfg, 4), max_new_tokens=1))
    with pytest.raises(ValueError, match="rid 7 is already queued"):
        sch.submit(Request(rid=7, prompt=_prompt(cfg, 4), max_new_tokens=1))
    # draining the queue clears the guard: the rid may be reused after
    res = sch.run()
    assert len(res[7]) == 1
    sch.submit(Request(rid=7, prompt=_prompt(cfg, 4), max_new_tokens=1))


def test_deadline_exactly_at_admit_boundary(fp_setup):
    """Expiry is strict `>`: a request whose deadline equals the clock
    at the admission check still admits; past the deadline it expires
    before any work.  A frozen clock pins the boundary exactly
    regardless of how many times the admission path reads it."""
    cfg, m, params = fp_setup
    box = {"t": 5.0}
    eng = ServeEngine(m, params, n_slots=1, max_len=32,
                      clock=lambda: box["t"])
    out = eng.serve([Request(rid=0, prompt=_prompt(cfg, 4),
                             max_new_tokens=2, deadline=5.0)])
    m1 = eng.metrics()
    assert m1["expired"] == 0 and m1["completed"] == 1
    assert len(out[0]) == 2
    box["t"] = 5.0 + 1e-6
    out = eng.serve([Request(rid=1, prompt=_prompt(cfg, 4),
                             max_new_tokens=2, deadline=5.0)])
    m2 = eng.metrics()
    assert m2["expired"] == 1 and len(out[1]) == 0


def test_slo_sheds_doomed_request(fp_setup):
    """A request whose deadline cannot be met given the queue-delay
    estimate is shed at admission time — before it wastes a slot."""
    cfg, m, params = fp_setup
    _, clock = _ticker(dt=0.01)
    slo = SLOAdmission(SLOConfig(margin=1.0))
    for _ in range(8):
        slo.observe(5.0)                # queue-delay estimate: 5 s
    eng = ServeEngine(m, params, n_slots=1, max_len=32, clock=clock,
                      slo=slo)
    req = Request(rid=0, prompt=_prompt(cfg, 4), max_new_tokens=2)
    req.deadline = 2.0                  # < now + 5s estimate: doomed
    res = eng.serve([req])
    m1 = eng.metrics()
    assert m1["shed"] == 1 and m1["completed"] == 0
    assert res[0].size == 0 and req.outcome == "shed"


def test_run_traffic_overload_accounting_and_retries(fp_setup):
    """Open-loop overload on the fake clock: every submitted request
    reaches exactly one terminal outcome, shed retries re-enter through
    the feed, and the percentile report stays finite."""
    from repro.serve import TrafficConfig, make_trace
    cfg, m, params = fp_setup
    _, clock = _ticker(dt=0.004)
    eng = ServeEngine(m, params, n_slots=1, max_len=64, clock=clock,
                      slo=SLOConfig(retry_base_s=0.02))
    tcfg = TrafficConfig(n_requests=12, rate=500.0, max_new_tokens=4,
                         prompt_len_median=6, prompt_len_max=20,
                         vocab_size=cfg.vocab_size, deadline_s=0.25,
                         seed=11)
    res = Scheduler(eng).run_traffic(make_trace(tcfg))
    s, rep = res.summary, res.traffic
    assert (s["completed"] + s["shed"] + s["expired"] + s["truncated"]
            == rep["submitted"] == 12)
    assert sum(rep["outcomes"].values()) == 12
    assert s["expired"] + s["shed"] >= 1        # the overload actually bit
    for key in ("ttft_ms", "queue_delay_ms", "survivor_ttft_ms"):
        assert all(np.isfinite(list(rep[key].values())))


def test_quota_defers_tenant_but_completes_everyone(fp_setup):
    """A tenant over its in-flight quota is *deferred*, not starved:
    its queued requests bind as earlier ones finish, and all complete."""
    cfg, m, params = fp_setup
    slo = SLOConfig(quotas={"bulk": 20})   # one 4+6-token request at a time
    eng = ServeEngine(m, params, n_slots=2, max_len=32, slo=slo)
    reqs = [Request(rid=i, prompt=_prompt(cfg, 4, seed=i),
                    max_new_tokens=6, tenant="bulk") for i in range(3)]
    res = eng.serve(reqs)
    assert all(len(res[i]) == 6 for i in range(3))
    assert eng.metrics()["completed"] == 3
    assert eng.slo._inflight["bulk"] == 0   # symmetric acquire/release


def test_oversized_tenant_request_sheds_terminally(fp_setup):
    """A request bigger than its tenant's whole quota can never bind;
    the no-progress guard sheds it instead of spinning forever."""
    cfg, m, params = fp_setup
    eng = ServeEngine(m, params, n_slots=1, max_len=64,
                      slo=SLOConfig(quota_tokens=8))
    req = Request(rid=0, prompt=_prompt(cfg, 10), max_new_tokens=4)
    res = eng.serve([req])
    assert res[0].size == 0 and req.outcome == "shed"
    assert eng.metrics()["shed"] == 1


# -- preempt + resume bit-identity --------------------------------------------

@pytest.mark.parametrize("paged", [False, True])
def test_preempt_resume_bit_identical(fp_setup, paged):
    """Forced preemptions (the dense cache has no page pressure of its
    own) re-queue and resume requests; greedy outputs match the
    uninterrupted run bit-for-bit on both cache kinds."""
    cfg, m, params = fp_setup
    reqs = lambda: [Request(rid=i, prompt=_prompt(cfg, 6 + 3 * i, seed=i),
                            max_new_tokens=10) for i in range(3)]
    ref = ServeEngine(m, params, n_slots=2, max_len=64,
                      paged=paged).serve(reqs())
    faults = FaultInjector(FaultConfig(preempt_at=(2, 5, 9, 14)))
    eng = ServeEngine(m, params, n_slots=2, max_len=64, paged=paged,
                      faults=faults)
    out = eng.serve(reqs())
    met = eng.metrics()
    assert met["preempted"] >= 1 and met["resumed"] == met["preempted"]
    assert met["faults"]["forced_preempts"] == met["preempted"]
    for i in range(3):
        np.testing.assert_array_equal(out[i], ref[i])


@pytest.mark.parametrize("paged", [False, True])
def test_preempt_resume_bit_identical_spec(fp_setup, paged):
    """Same bit-identity under speculative decoding: preemption resets
    the victim's draft state; the resumed slot re-prefills the draft
    from the effective prompt."""
    from repro.serve.draft import self_int8_draft
    from repro.serve.spec import SpecConfig
    cfg, m, params = fp_setup
    from repro.core import QuantSpec, quantize_model, run_calibration
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, cfg.vocab_size)}
    stats = run_calibration(m.forward, params, [batch])
    qp, _ = quantize_model(params, m.quant_site_map(), stats, method="faq",
                           spec=QuantSpec(bits=4, group_size=64),
                           mode="packed")
    mk_spec = lambda: SpecConfig(k=3, draft=self_int8_draft(m, qp, stats))
    reqs = lambda: [Request(rid=i, prompt=_prompt(cfg, 5 + 2 * i, seed=i),
                            max_new_tokens=8) for i in range(2)]
    ref = ServeEngine(m, qp, n_slots=2, max_len=64, paged=paged,
                      spec=mk_spec()).serve(reqs())
    eng = ServeEngine(m, qp, n_slots=2, max_len=64, paged=paged,
                      spec=mk_spec(),
                      faults=FaultInjector(FaultConfig(preempt_at=(1, 4))))
    out = eng.serve(reqs())
    assert eng.metrics()["preempted"] >= 1
    for i in range(2):
        np.testing.assert_array_equal(out[i], ref[i])


def test_refcount_audit_after_preempt_storm(fp_setup):
    """After a forced-preemption storm on a paged engine every page is
    either free, index-owned (ref 1), or trash — no leaked refs."""
    cfg, m, params = fp_setup
    faults = FaultInjector(FaultConfig(preempt_at=tuple(range(1, 40, 2))))
    eng = ServeEngine(m, params, n_slots=3, max_len=64, paged=True,
                      page_size=8, faults=faults)
    reqs = [Request(rid=i, prompt=_prompt(cfg, 4 + 5 * i, seed=i),
                    max_new_tokens=9) for i in range(5)]
    res = eng.serve(reqs)
    assert all(len(res[i]) == 9 for i in range(5))
    assert eng.metrics()["preempted"] >= 5
    pool = eng._stepper.pool
    assert pool.ref[pool.TRASH] == 1
    held = {p for p in range(1, pool.n_pages) if pool.ref[p] > 0}
    assert held == set(pool.index.values())
    assert all(pool.ref[p] == 1 for p in held)
    assert len(pool.free) == pool.n_pages - 1 - len(held)


def test_effective_prompt_resume_semantics():
    r = Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32),
                max_new_tokens=8)
    np.testing.assert_array_equal(effective_prompt(r), r.prompt)
    r.out_tokens = [9, 8]
    np.testing.assert_array_equal(effective_prompt(r), r.prompt)
    r.resume = True
    np.testing.assert_array_equal(effective_prompt(r),
                                  np.array([1, 2, 3, 4, 9, 8], np.int32))


# -- summarize hardening ------------------------------------------------------

def test_summarize_empty_and_zero_completion_records():
    from repro.serve import summarize
    rep = summarize({})
    assert rep["submitted"] == rep["completed"] == 0
    assert rep["tokens_per_s"] == 0.0
    for key in ("ttft_ms", "queue_delay_ms", "per_token_ms",
                "survivor_ttft_ms"):
        assert rep[key] == dict(p50=0.0, p95=0.0, p99=0.0, mean=0.0, n=0)
    # records exist but nothing completed (all shed before first token)
    rep = summarize({0: dict(arrival=1.0, admit=None, first=None, end=2.0,
                             tokens=0, outcome="shed"),
                     1: dict(arrival=1.0, admit=None, first=None, end=None,
                             tokens=0, outcome=None)})
    assert rep["submitted"] == 2 and rep["completed"] == 1
    assert rep["outcomes"] == {"shed": 1}
    vals = [v for d in (rep["ttft_ms"], rep["per_token_ms"],
                        rep["survivor_ttft_ms"]) for v in d.values()]
    assert all(np.isfinite(vals))
