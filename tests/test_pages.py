"""Paged KV cache: pool allocator units, COW, prefix sharing, and
serve() == generate() equivalence on paged fp16 / int8-KV caches."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import QuantSpec, quantize_model, run_calibration
from repro.models.registry import build_model
from repro.serve import PagePool, Request, ServeEngine, block_hashes


# -- pool units --------------------------------------------------------------

def test_pool_alloc_free_refcount():
    pool = PagePool(5, 8)          # trash + 4 allocatable
    a = pool.alloc()
    b = pool.alloc()
    assert a != b and PagePool.TRASH not in (a, b)
    assert pool.pages_in_use() == 2
    pool.incref(a)
    pool.decref(a)
    assert pool.pages_in_use() == 2     # still one owner left
    pool.decref(a)
    assert pool.pages_in_use() == 1     # refcount 0 -> freed
    c = pool.alloc()
    assert pool.pages_in_use() == 2
    pool.decref(b)
    pool.decref(c)
    assert pool.pages_in_use() == 0
    assert pool.in_use_peak == 2


def test_pool_exhaustion_and_eviction():
    pool = PagePool(3, 8)          # 2 allocatable pages
    a = pool.alloc()
    b = pool.alloc()
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc()
    # a page whose only owner is the prefix index is evictable
    pool.register(b"h", a)
    pool.decref(a)                 # slot retires; index keeps it alive
    assert pool.pages_in_use() == 2 and b"h" in pool.index
    c = pool.alloc()               # forces eviction of the index entry
    assert c == a and b"h" not in pool.index
    assert pool.evictions == 1
    pool.decref(b)
    pool.decref(c)


def test_pool_match_walks_prefix_chain():
    pool = PagePool(8, 4)
    toks = np.arange(12)
    hashes = block_hashes(toks, 4)
    assert len(hashes) == 3
    # chained hashes: same block content at a different depth differs
    assert len(set(hashes)) == 3
    p0, p1 = pool.alloc(), pool.alloc()
    pool.register(hashes[0], p0)
    pool.register(hashes[1], p1)
    got = pool.match(hashes)       # third block unregistered -> stop
    assert got == [p0, p1]
    assert pool.ref[p0] == 3 and pool.ref[p1] == 3  # slot+index+match
    # divergent prefix matches nothing past the divergence
    other = block_hashes(np.concatenate([toks[:4], toks[:8]]), 4)
    assert other[0] == hashes[0] and other[1] != hashes[1]
    assert pool.lookup_blocks(other) == 1


def test_block_hashes_full_blocks_only():
    assert len(block_hashes(np.arange(7), 4)) == 1
    assert len(block_hashes(np.arange(3), 4)) == 0
    a = block_hashes(np.arange(8), 4)
    b = block_hashes(np.arange(8), 4)
    assert a == b                  # deterministic across calls


# -- engine integration ------------------------------------------------------

@pytest.fixture(scope="module")
def quantized_setup():
    cfg = ARCHS["llama3-8b"].tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, cfg.vocab_size)}
    stats = run_calibration(m.forward, params, [batch])
    qp, _ = quantize_model(params, m.quant_site_map(), stats, method="faq",
                           spec=QuantSpec(bits=4, group_size=64),
                           mode="packed")
    return cfg, m, qp


def _mixed_shared_requests(cfg, n, prefix_len, seed=0, max_new=(1, 8)):
    rng = np.random.default_rng(seed)
    sysp = rng.integers(0, cfg.vocab_size, size=prefix_len)
    return [Request(rid=i,
                    prompt=np.concatenate(
                        [sysp, rng.integers(0, cfg.vocab_size,
                                            size=int(rng.integers(3, 20)))]),
                    max_new_tokens=int(rng.integers(*max_new)))
            for i in range(n)]


def test_paged_serve_matches_generate(quantized_setup):
    """Token-for-token: paged mixed-length continuous batching must
    reproduce the single-request dense-cache greedy outputs exactly."""
    cfg, m, qp = quantized_setup
    eng = ServeEngine(m, qp, n_slots=3, max_len=64, paged=True, page_size=8)
    assert eng.paged
    reqs = _mixed_shared_requests(cfg, 6, prefix_len=16, seed=0)
    batched = eng.serve([Request(rid=r.rid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens)
                         for r in reqs])
    for r in reqs:
        np.testing.assert_array_equal(batched[r.rid], eng.generate(r))
    mm = eng.metrics()
    assert mm["prefix_hits"] >= 1
    assert mm["pages_peak"] <= mm["pages_total"]


def test_paged_serve_matches_generate_kv8():
    """Same equivalence on the int8 KV cache: scales page alongside
    codes, so the int8 fold survives paging."""
    cfg = dataclasses.replace(ARCHS["llama3-8b"].tiny(), kv_cache_bits=8)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(m, params, n_slots=2, max_len=48, paged=True,
                      page_size=8)
    assert eng.paged and eng._store["k"].dtype == np.int8
    reqs = _mixed_shared_requests(cfg, 4, prefix_len=16, seed=1)
    batched = eng.serve([Request(rid=r.rid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens)
                         for r in reqs])
    for r in reqs:
        np.testing.assert_array_equal(batched[r.rid], eng.generate(r))
    assert eng.metrics()["prefix_hits"] >= 1


def test_prefix_sharing_refcounts_and_skipped_prefill(quantized_setup):
    """Two requests sharing a 2-block prefix must map the same physical
    pages (refcounted: index + both slots) and only the second request's
    tail goes through prefill work."""
    cfg, m, qp = quantized_setup
    ps = 8
    eng = ServeEngine(m, qp, n_slots=2, max_len=64, paged=True, page_size=ps)
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, cfg.vocab_size, size=2 * ps)   # 2 full blocks
    pa = np.concatenate([prefix, rng.integers(0, cfg.vocab_size, size=5)])
    pb = np.concatenate([prefix, rng.integers(0, cfg.vocab_size, size=9)])
    hashes = block_hashes(prefix, ps)

    seen_refs = []

    def snapshot(rid, tok):
        # rid 1's first token lands after its fill completes, while
        # rid 0 (bigger budget) is still resident in the other slot
        if rid == 1 and not seen_refs:
            phys = [eng.pool.index.get(h) for h in hashes]
            seen_refs.append([None if p is None else int(eng.pool.ref[p])
                              for p in phys])

    ra = Request(rid=0, prompt=pa, max_new_tokens=15, on_token=snapshot)
    rb = Request(rid=1, prompt=pb, max_new_tokens=6, on_token=snapshot)
    res = eng.serve([ra, rb])
    mm = eng.metrics()
    # the second request's leading 2 blocks came from the index
    assert mm["prefix_hits"] == 1
    assert mm["prefix_hit_tokens"] == 2 * ps
    # while both slots were resident, each shared page had 3 owners:
    # the prefix index plus both slots
    assert seen_refs and seen_refs[0] == [3, 3]
    # after retirement the index keeps one ref per shared block
    for h in hashes:
        assert int(eng.pool.ref[eng.pool.index[h]]) == 1
    for r in (ra, rb):
        np.testing.assert_array_equal(
            res[r.rid],
            eng.generate(Request(rid=r.rid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens)))


def test_cow_on_fully_cached_prompt(quantized_setup):
    """A prompt whose every block is cached re-feeds its last token; the
    write into the shared final page must copy-on-write, never mutate
    the shared block."""
    cfg, m, qp = quantized_setup
    eng = ServeEngine(m, qp, n_slots=2, max_len=32, paged=True, page_size=8)
    prompt = (np.arange(16) % cfg.vocab_size).astype(np.int32)  # 2 pages
    r1 = eng.serve([Request(rid=0, prompt=prompt, max_new_tokens=3)])
    r2 = eng.serve([Request(rid=1, prompt=prompt, max_new_tokens=3)])
    mm = eng.metrics()
    assert mm["cow_copies"] == 1
    assert mm["prefix_hit_tokens"] == 15          # n-1 of 16
    np.testing.assert_array_equal(r1[0], r2[1])
    np.testing.assert_array_equal(
        r2[1], eng.generate(Request(rid=9, prompt=prompt, max_new_tokens=3)))


def test_paged_peak_memory_below_dense(quantized_setup):
    """16 mixed-length shared-prefix requests: peak pinned page bytes
    must undercut the dense n_slots*max_len allocation."""
    cfg, m, qp = quantized_setup
    max_len, n_slots = 128, 4
    eng = ServeEngine(m, qp, n_slots=n_slots, max_len=max_len, paged=True,
                      page_size=16)
    reqs = _mixed_shared_requests(cfg, 16, prefix_len=32, seed=5,
                                  max_new=(4, 12))
    eng.serve(reqs)
    dense_bytes = sum(
        leaf.size * leaf.dtype.itemsize
        for leaf in jax.tree_util.tree_leaves(
            jax.eval_shape(lambda: m.init_cache(n_slots, max_len))))
    mm = eng.metrics()
    assert mm["peak_cache_bytes"] < dense_bytes
    assert mm["prefix_hits"] >= 10


def test_paged_capacity_truncation(quantized_setup):
    """Capacity semantics survive paging: a request that fills its
    max_len cache truncates exactly like the dense engine."""
    cfg, m, qp = quantized_setup
    max_len = 24
    eng = ServeEngine(m, qp, n_slots=2, max_len=max_len, buckets=(8, 24),
                      paged=True, page_size=8)
    prompt = (np.arange(8) % cfg.vocab_size).astype(np.int32)
    res = eng.serve([
        Request(rid=0, prompt=prompt, max_new_tokens=2),
        Request(rid=1, prompt=prompt, max_new_tokens=100),
    ])
    assert res[0].shape == (2,)
    assert res[1].shape == (1 + max_len - len(prompt),)
    assert eng.metrics()["truncated"] == 1
    big = ServeEngine(m, qp, n_slots=2, max_len=64)
    ref = big.generate(Request(rid=9, prompt=prompt, max_new_tokens=100))
    np.testing.assert_array_equal(res[1], ref[:len(res[1])])
    # all transient pages returned; only index-registered blocks persist
    assert eng.pool.pages_in_use() == len(eng.pool.index)


def test_paged_falls_back_for_unsupported_models():
    """hymba's ring-buffer cache can't page; the engine silently serves
    from the dense path."""
    cfg = ARCHS["hymba-1.5b"].tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(m, params, n_slots=2, max_len=48, paged=True)
    assert not eng.paged
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=6),
                    max_new_tokens=2) for i in range(2)]
    res = eng.serve(reqs)
    assert all(res[i].shape == (2,) for i in range(2))
