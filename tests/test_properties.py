"""Seeded randomized property sweeps (hypothesis is not installed in this
environment; these are explicit-seed property tests over the same
invariants)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantSpec, quant_dequant, quantize_groupwise
from repro.core.methods import (candidate_scale, fuse_stats, normalize_scale,
                                window_preview)
from repro.core.quantizer import dequantize_groupwise, numpy_quant_reference

SEEDS = range(12)


@pytest.mark.parametrize("seed", SEEDS)
def test_quant_idempotent(seed):
    """Quantizing an already-quantized weight is a fixed point."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    spec = QuantSpec(bits=4, group_size=32)
    once = quant_dequant(w, spec)
    twice = quant_dequant(once, spec)
    np.testing.assert_allclose(np.asarray(twice), np.asarray(once), atol=2e-5)


@pytest.mark.parametrize("seed", SEEDS)
def test_scale_invariance_of_fused_search_stat(seed):
    """Global rescaling of activations must not change candidate scales."""
    rng = np.random.default_rng(seed)
    stats = jnp.asarray(np.abs(rng.normal(size=(5, 32))) + 0.05)
    fused = fuse_stats(stats, 0.85, 3)
    s1 = candidate_scale(fused[2], 0.45)
    s2 = candidate_scale(fused[2] * 123.0, 0.45)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4)


@pytest.mark.parametrize("seed", SEEDS)
def test_window_mean_within_bounds(seed):
    """Preview is a mean -> bounded by min/max of the window."""
    rng = np.random.default_rng(seed)
    stats = jnp.asarray(np.abs(rng.normal(size=(8, 16))) + 0.01)
    pvw = np.asarray(window_preview(stats, 3))
    s = np.asarray(stats)
    for l in range(7):
        hi = min(l + 3, 7)
        w = s[l + 1: hi + 1]
        assert (pvw[l] >= w.min(0) - 1e-6).all()
        assert (pvw[l] <= w.max(0) + 1e-6).all()


@pytest.mark.parametrize("seed", SEEDS)
def test_monotone_bits(seed):
    """More bits can only reduce (weighted) reconstruction error."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(128, 32)), jnp.float32)
    errs = []
    for bits in (2, 3, 4, 8):
        wh = quant_dequant(w, QuantSpec(bits=bits, group_size=64))
        errs.append(float(jnp.linalg.norm(wh - w)))
    assert errs == sorted(errs, reverse=True)


@pytest.mark.parametrize("seed", SEEDS)
def test_smaller_groups_no_worse(seed):
    """Finer groups can only reduce quantization error (more params)."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(256, 16)) *
                    np.exp(rng.normal(size=(256, 1))), jnp.float32)
    e_small = float(jnp.linalg.norm(
        quant_dequant(w, QuantSpec(bits=3, group_size=32)) - w))
    e_big = float(jnp.linalg.norm(
        quant_dequant(w, QuantSpec(bits=3, group_size=256)) - w))
    assert e_small <= e_big + 1e-5


@pytest.mark.parametrize("seed", SEEDS)
def test_normalize_scale_geo_mean_one(seed):
    rng = np.random.default_rng(seed)
    s = normalize_scale(jnp.asarray(np.abs(rng.normal(size=(64,))) + 0.01))
    geo = float(jnp.exp(jnp.mean(jnp.log(s))))
    assert abs(geo - 1.0) < 1e-3


@pytest.mark.parametrize("seed", SEEDS)
def test_jnp_numpy_agree_random_specs(seed):
    rng = np.random.default_rng(seed)
    bits = int(rng.choice([3, 4, 8]))
    group = int(rng.choice([16, 32, 64]))
    sym = bool(rng.choice([True, False]))
    w = rng.normal(size=(128, 8)).astype(np.float32)
    spec = QuantSpec(bits=bits, group_size=group, symmetric=sym)
    np.testing.assert_allclose(
        np.asarray(quant_dequant(jnp.asarray(w), spec)),
        numpy_quant_reference(w, spec), atol=1e-4)
