"""End-to-end quantization: calibrate -> RTN/AWQ/FAQ -> evaluate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import (QuantSpec, quantize_model, report_summary,
                        run_calibration)
from repro.models.registry import build_model


@pytest.fixture(scope="module")
def calibrated_dense():
    cfg = ARCHS["llama3-8b"].tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(i), (2, 32),
                                             0, cfg.vocab_size)}
               for i in range(3)]
    stats = run_calibration(m.forward, params, batches)
    return cfg, m, params, batches, stats


def test_calibration_sites_match_map(calibrated_dense):
    cfg, m, params, batches, stats = calibrated_dense
    needed = set(m.quant_site_map().values())
    assert needed <= set(stats), (needed, set(stats))


@pytest.mark.parametrize("method", ["rtn", "awq", "faq"])
def test_fake_quant_runs_and_degrades_gracefully(calibrated_dense, method):
    cfg, m, params, batches, stats = calibrated_dense
    spec = QuantSpec(bits=4, group_size=64)
    qp, rep = quantize_model(params, m.quant_site_map(), stats,
                             method=method, spec=spec, mode="fake")
    lq, _ = jax.jit(lambda p, b: m.forward(p, b))(qp, batches[0])
    lf, _ = jax.jit(lambda p, b: m.forward(p, b))(params, batches[0])
    rmse = float(jnp.sqrt(jnp.mean((lq - lf) ** 2)))
    assert rmse < 1.0  # 4-bit on a tiny random-init model stays close
    if method != "rtn":
        summ = report_summary(rep)
        assert all(v["mean_loss"] <= v["mean_rtn_loss"] + 1e-9
                   for v in summ.values())


def test_faq_layer_loss_leq_awq_with_shared_alpha(calibrated_dense):
    """Search-loss comparison on identical footing (same grid, same data)."""
    cfg, m, params, batches, stats = calibrated_dense
    spec = QuantSpec(bits=3, group_size=64)
    _, rep_a = quantize_model(params, m.quant_site_map(), stats,
                              method="awq", spec=spec, mode="fake")
    _, rep_f = quantize_model(params, m.quant_site_map(), stats,
                              method="faq", spec=spec, mode="fake")
    sa = report_summary(rep_a)
    sf = report_summary(rep_f)
    # FAQ doesn't dominate per-site by construction, but mean improvement
    # over RTN should be at least comparable (>= 90% of AWQ's) on average
    imp_a = np.mean([v["improvement_vs_rtn"] for v in sa.values()])
    imp_f = np.mean([v["improvement_vs_rtn"] for v in sf.values()])
    assert imp_f >= 0.9 * imp_a


def test_packed_matches_fake(calibrated_dense):
    cfg, m, params, batches, stats = calibrated_dense
    spec = QuantSpec(bits=4, group_size=64)
    qp_f, _ = quantize_model(params, m.quant_site_map(), stats,
                             method="faq", spec=spec, mode="fake")
    qp_p, _ = quantize_model(params, m.quant_site_map(), stats,
                             method="faq", spec=spec, mode="packed")
    lf, _ = jax.jit(lambda p, b: m.forward(p, b))(qp_f, batches[0])
    lp, _ = jax.jit(lambda p, b: m.forward(p, b))(qp_p, batches[0])
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lf), atol=1e-4)


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "hymba-1.5b",
                                  "xlstm-350m", "whisper-small"])
def test_quantize_other_families(arch):
    """FAQ applies across families (DESIGN.md §4: no arch is skipped)."""
    cfg = ARCHS[arch].tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (2, cfg.encoder_len, cfg.d_model)) * 0.1
    stats = run_calibration(m.forward, params, [batch])
    qp, rep = quantize_model(params, m.quant_site_map(), stats,
                             method="faq", spec=QuantSpec(bits=4, group_size=32),
                             mode="fake")
    lq, _ = jax.jit(lambda p, b: m.forward(p, b))(qp, batch)
    assert not bool(jnp.isnan(lq).any())
    assert rep  # every mapped site produced a report
