"""Unit + property tests for the group-wise quantizer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizer import (QuantSpec, dequantize_groupwise,
                                  effective_group_size, numpy_quant_reference,
                                  pack_codes, quant_dequant,
                                  quantize_groupwise, storage_bits,
                                  unpack_codes)


@pytest.mark.parametrize("bits", [3, 4, 8])
@pytest.mark.parametrize("symmetric", [False, True])
@pytest.mark.parametrize("group", [32, 128, -1])
def test_roundtrip_error_bound(bits, symmetric, group):
    """Reconstruction error per element is bounded by half a step."""
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 64))
    spec = QuantSpec(bits=bits, group_size=group, symmetric=symmetric)
    qt = quantize_groupwise(w, spec)
    w_hat = dequantize_groupwise(qt)
    g = 256 // qt.scale.shape[0]
    step = jnp.repeat(qt.scale, g, axis=0)
    # away from clip boundaries the error is <= step/2 (+fp slack)
    err = jnp.abs(w_hat - w)
    assert float(jnp.mean(err <= step * 0.5 + 1e-6)) > 0.99


@pytest.mark.parametrize("seed", range(5))
def test_matches_numpy_oracle(seed):
    w = np.random.default_rng(seed).normal(size=(128, 32)).astype(np.float32)
    spec = QuantSpec(bits=4, group_size=64)
    ref = numpy_quant_reference(w, spec)
    got = np.asarray(quant_dequant(jnp.asarray(w), spec))
    np.testing.assert_allclose(got, ref, atol=1e-5)


@pytest.mark.parametrize("seed", range(5))
def test_oracle_with_act_scale(seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(128, 32)).astype(np.float32)
    s = np.abs(rng.normal(size=(128,))).astype(np.float32) + 0.3
    spec = QuantSpec(bits=3, group_size=32)
    ref = numpy_quant_reference(w, spec, act_scale=s)
    got = np.asarray(quant_dequant(jnp.asarray(w), spec,
                                   act_scale=jnp.asarray(s)))
    np.testing.assert_allclose(got, ref, atol=1e-4)


def test_pack_unpack_roundtrip():
    for seed in range(8):
        codes = jax.random.randint(jax.random.PRNGKey(seed), (64, 16),
                                   0, 16).astype(jnp.uint8)
        packed = pack_codes(codes, 4)
        assert packed.shape == (32, 16)
        un = unpack_codes(packed, 4, 64)
        assert jnp.array_equal(un, codes)


def test_packed_equals_unpacked_dequant():
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 64))
    spec = QuantSpec(bits=4, group_size=128)
    a = dequantize_groupwise(quantize_groupwise(w, spec, pack=False))
    b = dequantize_groupwise(quantize_groupwise(w, spec, pack=True))
    assert jnp.array_equal(a, b)


def test_effective_group_size():
    assert effective_group_size(1600, 128) == 100
    assert effective_group_size(4096, 128) == 128
    assert effective_group_size(100, 128) == 100
    assert effective_group_size(7, 128) == 7
    assert effective_group_size(128, -1) == 128


def test_exact_zero_preserved_asymmetric():
    """Asymmetric quantization must represent 0 exactly (zero-point)."""
    w = jax.random.normal(jax.random.PRNGKey(2), (128, 8))
    w = w.at[3].set(0.0)
    got = quant_dequant(w, QuantSpec(bits=4, group_size=64))
    assert float(jnp.max(jnp.abs(got[3]))) < 1e-6


def test_storage_bits_packed():
    w = jax.random.normal(jax.random.PRNGKey(3), (1024, 1024))
    qt = quantize_groupwise(w, QuantSpec(bits=4, group_size=128), pack=True)
    bits = storage_bits(qt)
    assert 4.0 < bits < 5.0  # 4 bits + group metadata overhead
