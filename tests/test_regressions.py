"""Targeted regression tests.

1. ``window_preview`` precision: the original float32 cumsum-difference
   implementation suffered catastrophic cancellation, letting the
   windowed "mean" exceed the window max.  The shift-and-mask rewrite is
   exact for window=1 and bounded for all windows.
2. Checkpoint atomicity: a crash mid-save must never corrupt the
   directory — no ``.tmp`` survives the failure path, and
   ``latest_step`` keeps returning the last *complete* step.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.methods import window_preview
from repro.dist import checkpoint as ckpt


# ---------------------------------------------------------------------------
# window_preview
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_window_one_is_exact_next_layer(seed):
    """window=1: pvw[l] must be bit-exactly stats[l+1] (no arithmetic may
    intervene — this is the degenerate case the cumsum version broke)."""
    rng = np.random.default_rng(seed)
    stats = jnp.asarray(np.abs(rng.normal(size=(9, 24))) + 0.01,
                        jnp.float32)
    pvw = np.asarray(window_preview(stats, 1))
    s = np.asarray(stats)
    np.testing.assert_array_equal(pvw[:-1], s[1:])


@pytest.mark.parametrize("window", [1, 2, 3, 4])
def test_last_layer_returns_own_stat(window):
    stats = jnp.asarray(np.abs(np.random.default_rng(0).normal(
        size=(7, 16))) + 0.01, jnp.float32)
    pvw = np.asarray(window_preview(stats, window))
    np.testing.assert_array_equal(pvw[-1], np.asarray(stats)[-1])


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("window", [2, 3, 4])
def test_window_mean_matches_numpy_reference(seed, window):
    """Full-precision numpy reference, all (layer, window) clamp cases."""
    rng = np.random.default_rng(seed)
    s = np.abs(rng.normal(size=(8, 12))).astype(np.float32) + 0.01
    pvw = np.asarray(window_preview(jnp.asarray(s), window))
    L = s.shape[0]
    for l in range(L - 1):
        ref = s[l + 1: min(l + window, L - 1) + 1].mean(0)
        np.testing.assert_allclose(pvw[l], ref, rtol=1e-6)


# ---------------------------------------------------------------------------
# checkpoint atomicity
# ---------------------------------------------------------------------------

def _tree():
    return {"w": jnp.arange(6.0).reshape(2, 3),
            "step": jnp.asarray(3, jnp.int32)}


def test_crash_mid_save_leaves_no_tmp(tmp_path, monkeypatch):
    """A failure before the rename must clean its .tmp and keep the
    previous step as the newest complete checkpoint."""
    ckpt.save(str(tmp_path), 1, _tree())

    def boom(src, dst):
        raise OSError("simulated crash before rename")

    monkeypatch.setattr(ckpt.os, "replace", boom)
    with pytest.raises(OSError, match="simulated crash"):
        ckpt.save(str(tmp_path), 2, _tree())
    monkeypatch.undo()

    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_stale_tmp_from_hard_kill_is_ignored_and_reclaimed(tmp_path):
    """A .tmp left by a SIGKILL (no cleanup ran) is invisible to
    latest_step and silently reclaimed by the next save of that step."""
    ckpt.save(str(tmp_path), 4, _tree())
    stale = tmp_path / "step_00000005.tmp"
    stale.mkdir()
    (stale / "data.bin").write_bytes(b"\x00" * 8)  # partial write

    assert ckpt.latest_step(str(tmp_path)) == 4
    ckpt.save(str(tmp_path), 5, _tree())
    assert ckpt.latest_step(str(tmp_path)) == 5
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
    restored = ckpt.restore(str(tmp_path), 5, _tree())
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(_tree()["w"]))


def test_concurrent_same_step_saves_promote_whole_checkpoint(tmp_path):
    """An async save racing a sync save of the same step must end with a
    complete, restorable checkpoint (writers use distinct .tmp dirs; one
    writer's rename wins wholesale — never a mix of both)."""
    ckpt.save_async(str(tmp_path), 7, _tree())
    ckpt.save(str(tmp_path), 7, _tree())
    ckpt.wait_pending()
    assert ckpt.latest_step(str(tmp_path)) == 7
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
    restored = ckpt.restore(str(tmp_path), 7, _tree())
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(_tree()["w"]))


def test_incomplete_dir_without_manifest_not_latest(tmp_path):
    """Even a non-.tmp directory missing its manifest (truncated disk)
    must not be reported as the latest step."""
    ckpt.save(str(tmp_path), 6, _tree())
    (tmp_path / "step_00000009").mkdir()   # no manifest.json inside
    assert ckpt.latest_step(str(tmp_path)) == 6
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path), 9, _tree())


def test_dead_writer_tmps_swept_live_writer_tmps_kept(tmp_path):
    """A crashed writer's tmp (dead pid of this host) for *any* step is
    swept by the next save; live-pid and foreign-host tmps are kept."""
    dead_pid = 4194304  # == kernel max pid_max; real pids are < this
    assert not ckpt._pid_alive(dead_pid)
    dead = f"step_00000003.{ckpt._HOST}-{dead_pid}-0.tmp"
    live = f"step_00000004.{ckpt._HOST}-1-0.tmp"   # pid 1: alive, not ours
    foreign = f"step_00000005.otherhost-{dead_pid}-0.tmp"
    for d in (dead, live, foreign):
        (tmp_path / d).mkdir()
    ckpt.save(str(tmp_path), 9, _tree())
    names = sorted(os.listdir(tmp_path))
    assert dead not in names
    assert live in names and foreign in names
    assert ckpt.latest_step(str(tmp_path)) == 9


def test_resave_same_step_survives_promote_failure(tmp_path, monkeypatch):
    """Re-saving an existing step must not destroy the old complete
    checkpoint when promotion fails — it is retired aside and rolled
    back, never rmtree'd first."""
    ckpt.save(str(tmp_path), 2, _tree())
    real_replace = ckpt.os.replace
    state = {"i": 0}

    def fail_promote(src, dst):
        # retire-aside renames (dst is a .tmp) pass through; of the
        # .tmp -> final renames, promotes (odd) fail and the interleaved
        # rollbacks (even) succeed
        if src.endswith(".tmp") and not dst.endswith(".tmp"):
            state["i"] += 1
            if state["i"] % 2 == 1:
                raise OSError("simulated promote failure")
        return real_replace(src, dst)

    monkeypatch.setattr(ckpt.os, "replace", fail_promote)
    with pytest.raises(OSError, match="simulated promote"):
        ckpt.save(str(tmp_path), 2, _tree())
    monkeypatch.undo()
    assert ckpt.latest_step(str(tmp_path)) == 2     # old step intact
    ckpt.restore(str(tmp_path), 2, _tree())          # and restorable


def test_retired_complete_tmp_recovered_not_swept(tmp_path):
    """Crash between the two renames of a same-step re-save: the only
    complete copy of the step lives in a dead-writer .tmp.  The restart
    path (latest_step) must recover (promote) it, not report an older
    lineage — and a subsequent save must not sweep it."""
    dead_pid = 4194304
    ckpt.save(str(tmp_path), 2, _tree())
    # simulate the crash window: final dir retired aside, writer died
    os.rename(tmp_path / "step_00000002",
              tmp_path / f"step_00000002.{ckpt._HOST}-{dead_pid}-0.tmp")
    # a restart consults latest_step first — recovery happens right there,
    # so training resumes from step 2, never from scratch
    assert ckpt.latest_step(str(tmp_path)) == 2
    ckpt.restore(str(tmp_path), 2, _tree())
    ckpt.save(str(tmp_path), 3, _tree())
    assert ckpt.latest_step(str(tmp_path)) == 3
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_gc_counts_only_complete_checkpoints(tmp_path):
    """A manifest-less junk dir must neither consume a keep= slot nor be
    deleted by GC; keep= always refers to complete, restorable steps."""
    ckpt.save(str(tmp_path), 1, _tree())
    (tmp_path / "step_00000009").mkdir()   # incomplete, no manifest
    ckpt.save(str(tmp_path), 2, _tree())
    ckpt.save(str(tmp_path), 3, _tree(), keep=2)
    kept = sorted(d for d in os.listdir(tmp_path))
    assert kept == ["step_00000002", "step_00000003", "step_00000009"]
    for s in (2, 3):
        ckpt.restore(str(tmp_path), s, _tree())  # both survivors complete


def test_truncated_manifest_tmp_swept_not_promoted(tmp_path):
    """A dead writer killed mid-manifest-write leaves unparseable JSON;
    recovery must sweep that tmp, never promote it as a complete step."""
    dead_pid = 4194304
    ckpt.save(str(tmp_path), 1, _tree())
    bad = tmp_path / f"step_00000002.{ckpt._HOST}-{dead_pid}-0.tmp"
    bad.mkdir()
    (bad / "data.bin").write_bytes(b"\x00" * 16)
    (bad / "manifest.json").write_text('{"step": 2, "leaves": [')  # truncated
    assert ckpt.latest_step(str(tmp_path)) == 1   # not promoted
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))  # swept


def test_resave_older_step_survives_gc(tmp_path):
    """Rollback case: re-saving a step older than on-disk steps with
    keep= must never GC the checkpoint just written (retention is scoped
    to steps <= the written one; newer steps are left for the caller)."""
    ckpt.save(str(tmp_path), 4, _tree())
    ckpt.save(str(tmp_path), 5, _tree())
    path = ckpt.save(str(tmp_path), 3, _tree(), keep=2)
    assert os.path.isdir(path)                      # just-written survives
    ckpt.restore(str(tmp_path), 3, _tree())
    assert sorted(os.listdir(tmp_path)) == \
        ["step_00000003", "step_00000004", "step_00000005"]


def test_steps_beyond_eight_digits(tmp_path):
    """Steps >= 1e8 grow past the zero-padded width; they must stay
    visible to latest_step, GC, and restore."""
    ckpt.save(str(tmp_path), 99_999_999, _tree())
    path = ckpt.save(str(tmp_path), 100_000_001, _tree(), keep=1)
    assert os.path.basename(path) == "step_100000001"
    assert ckpt.latest_step(str(tmp_path)) == 100_000_001
    assert sorted(os.listdir(tmp_path)) == ["step_100000001"]  # GC saw both
    ckpt.restore(str(tmp_path), 100_000_001, _tree())


def test_keep_zero_rejected(tmp_path):
    with pytest.raises(ValueError, match="keep"):
        ckpt.save(str(tmp_path), 1, _tree(), keep=0)
    assert ckpt.latest_step(str(tmp_path)) is None  # rejected before write


# ---------------------------------------------------------------------------
# merge_stats: the (K, d) "sample" subsample must mix rows from every
# calibration batch (round-robin), not keep only batch 0's rows — keeping
# only the first batch biased the exact search loss to batch 0.
# ---------------------------------------------------------------------------

def test_merge_stats_sample_round_robin():
    from repro.core.stats import merge_stats

    K, d = 8, 4

    def batch_stats(val):
        return {"site": {"mean_abs": np.full((d,), val, np.float32),
                         "mean_sq": np.full((d,), val, np.float32),
                         "sample": np.full((K, d), val, np.float32)}}

    acc = batch_stats(0.0)
    for t in range(1, 4):                      # merge batches 1, 2, 3
        acc = merge_stats(acc, batch_stats(float(t)), float(t), 1.0,
                          batch_index=t)
    sample = np.asarray(acc["site"]["sample"])
    row_vals = set(np.unique(sample[:, 0]).tolist())
    assert 3.0 in row_vals, "latest batch's rows must appear"
    assert len(row_vals) >= 3, f"expected a mix of batches, got {row_vals}"
    # moments stay exact weighted means
    np.testing.assert_allclose(acc["site"]["mean_abs"],
                               np.full((d,), (1 + 2 + 3) / 4.0), rtol=1e-6)


def test_run_calibration_samples_span_batches():
    """End-to-end: a later batch's activation rows reach the final
    subsample through run_calibration."""
    from repro.core.calibration import run_calibration

    K = 4

    def apply_fn(params, batch, collect_stats=False):
        x = batch["tokens"].astype(jnp.float32)
        val = x[0, 0]
        stats = {"site": {"mean_abs": jnp.full((2,), val),
                          "mean_sq": jnp.full((2,), val),
                          "sample": jnp.full((K, 2), val)}}
        return None, {"stats": stats}

    batches = [{"tokens": jnp.full((2, 3), float(i))} for i in range(4)]
    out = run_calibration(apply_fn, None, batches)
    vals = set(np.unique(np.asarray(out["site"]["sample"])).tolist())
    assert vals & {1.0, 2.0, 3.0}, f"later batches missing: {vals}"
    assert 0.0 in vals
