"""Serving engine: quantized-weight generation + continuous batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import QuantSpec, quantize_model, run_calibration
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def quantized_setup():
    cfg = ARCHS["llama3-8b"].tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, cfg.vocab_size)}
    stats = run_calibration(m.forward, params, [batch])
    qp, _ = quantize_model(params, m.quant_site_map(), stats, method="faq",
                           spec=QuantSpec(bits=4, group_size=64),
                           mode="packed")
    return cfg, m, qp


def test_generate_deterministic(quantized_setup):
    cfg, m, qp = quantized_setup
    eng = ServeEngine(m, qp, max_len=64)
    prompt = np.arange(10) % cfg.vocab_size
    out1 = eng.generate(Request(rid=0, prompt=prompt, max_new_tokens=8))
    out2 = eng.generate(Request(rid=1, prompt=prompt, max_new_tokens=8))
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (8,)
    assert out1.max() < cfg.vocab_size  # vocab-padding never sampled


def test_batched_serve_matches_single(quantized_setup):
    """Continuous batching (different prompt lengths sharing slots) must
    reproduce the single-request greedy outputs exactly."""
    cfg, m, qp = quantized_setup
    eng = ServeEngine(m, qp, n_slots=3, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=5 + 3 * i),
                    max_new_tokens=6) for i in range(5)]
    batched = eng.serve([Request(rid=r.rid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens)
                         for r in reqs])
    for r in reqs:
        single = eng.generate(r)
        np.testing.assert_array_equal(batched[r.rid], single)


def test_int8_kv_cache_decode():
    """Beyond-paper feature: int8 KV cache halves cache bytes with near-
    lossless decode (argmax agreement with the fp-cache path)."""
    import dataclasses
    cfg = dataclasses.replace(ARCHS["llama3-8b"].tiny(), kv_cache_bits=8)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    cache = m.init_cache(2, 24)
    assert cache["k"].dtype == jnp.int8
    lp, cache = jax.jit(m.prefill)(params, tokens, cache)
    nxt = jnp.argmax(lp[:, 0, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    ld, cache = jax.jit(m.decode_step)(params, cache, nxt)
    lf, _ = jax.jit(lambda p, b: m.forward(p, b))(
        params, {"tokens": jnp.concatenate([tokens, nxt], 1)})
    rmse = float(jnp.sqrt(jnp.mean((ld[:, 0] - lf[:, -1]) ** 2)))
    assert rmse < 0.05
    assert bool(jnp.all(jnp.argmax(ld[:, 0, :cfg.vocab_size], -1)
                        == jnp.argmax(lf[:, -1, :cfg.vocab_size], -1)))
