"""Serving engine: quantized-weight generation + bucketed continuous
batching (engine, sampler, cache ops, scheduler)."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import QuantSpec, quantize_model, run_calibration
from repro.models.registry import build_model
from repro.serve import (Request, Scheduler, ServeEngine, default_buckets,
                         sample_tokens, write_slot)


@pytest.fixture(scope="module")
def quantized_setup():
    cfg = ARCHS["llama3-8b"].tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, cfg.vocab_size)}
    stats = run_calibration(m.forward, params, [batch])
    qp, _ = quantize_model(params, m.quant_site_map(), stats, method="faq",
                           spec=QuantSpec(bits=4, group_size=64),
                           mode="packed")
    return cfg, m, qp


@pytest.fixture(scope="module")
def kv8_setup():
    cfg = dataclasses.replace(ARCHS["llama3-8b"].tiny(), kv_cache_bits=8)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _mixed_requests(cfg, n, seed=0, max_new=(1, 9)):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(3, 40))),
                    max_new_tokens=int(rng.integers(*max_new)))
            for i in range(n)]


def test_generate_deterministic(quantized_setup):
    cfg, m, qp = quantized_setup
    eng = ServeEngine(m, qp, max_len=64)
    prompt = np.arange(10) % cfg.vocab_size
    out1 = eng.generate(Request(rid=0, prompt=prompt, max_new_tokens=8))
    out2 = eng.generate(Request(rid=1, prompt=prompt, max_new_tokens=8))
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (8,)
    assert out1.max() < cfg.vocab_size  # vocab-padding never sampled


def test_batched_serve_matches_single(quantized_setup):
    """Continuous batching (different prompt lengths and budgets sharing
    slots) must reproduce the single-request greedy outputs exactly."""
    cfg, m, qp = quantized_setup
    eng = ServeEngine(m, qp, n_slots=3, max_len=64)
    reqs = _mixed_requests(cfg, 6, seed=0)
    batched = eng.serve([Request(rid=r.rid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens)
                         for r in reqs])
    for r in reqs:
        single = eng.generate(r)
        np.testing.assert_array_equal(batched[r.rid], single)


def test_bucketed_prefill_compiles_once_per_bucket(quantized_setup):
    """16 mixed-length requests: prefill compiles at most once per
    length bucket (asserted via the trace-counting jit wrapper), and the
    batched greedy output matches generate() token-for-token."""
    cfg, m, qp = quantized_setup
    eng = ServeEngine(m, qp, n_slots=4, max_len=64)
    reqs = _mixed_requests(cfg, 16, seed=1, max_new=(1, 7))
    batched = eng.serve([Request(rid=r.rid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens)
                         for r in reqs])
    metrics = eng.metrics()
    assert metrics["prefill_traces"] <= len(eng.buckets)
    assert metrics["prefill_batches"] >= metrics["prefill_traces"]
    assert metrics["admitted"] == 16
    assert metrics["completed"] == 16
    for r in reqs:
        np.testing.assert_array_equal(batched[r.rid], eng.generate(r))


def test_batched_serve_matches_single_kv8(kv8_setup):
    """Serving invariants hold on the int8 KV cache too."""
    cfg, m, params = kv8_setup
    eng = ServeEngine(m, params, n_slots=3, max_len=48)
    reqs = _mixed_requests(cfg, 5, seed=2)
    batched = eng.serve([Request(rid=r.rid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens)
                         for r in reqs])
    assert eng.metrics()["prefill_traces"] <= len(eng.buckets)
    for r in reqs:
        np.testing.assert_array_equal(batched[r.rid], eng.generate(r))


def test_max_new_tokens_zero(quantized_setup):
    """max_new_tokens=0 returns an empty sequence (no token is sampled
    from the prefill logits), in both generate() and serve()."""
    cfg, m, qp = quantized_setup
    eng = ServeEngine(m, qp, n_slots=2, max_len=64)
    prompt = np.arange(6) % cfg.vocab_size
    assert eng.generate(Request(rid=0, prompt=prompt,
                                max_new_tokens=0)).shape == (0,)
    res = eng.serve([Request(rid=0, prompt=prompt, max_new_tokens=0),
                     Request(rid=1, prompt=prompt, max_new_tokens=3)])
    assert res[0].shape == (0,)
    assert res[1].shape == (3,)


def test_finished_slots_never_overrun_cache(quantized_setup):
    """A short request finishing early must not keep advancing its
    slot's cache length while a long request drains: the inactive slot
    is masked and every live slot obeys len <= max_len (capacity-limited
    requests are truncated, not clamp-corrupted)."""
    cfg, m, qp = quantized_setup
    max_len = 24
    eng = ServeEngine(m, qp, n_slots=2, max_len=max_len, buckets=(8, 24))
    prompt = (np.arange(8) % cfg.vocab_size).astype(np.int32)
    res = eng.serve([
        Request(rid=0, prompt=prompt, max_new_tokens=2),
        Request(rid=1, prompt=prompt, max_new_tokens=100),  # wants > capacity
    ])
    assert res[0].shape == (2,)
    # rid 1 truncates at capacity: prefill token + (max_len - prompt) decodes
    assert res[1].shape == (1 + max_len - len(prompt),)
    assert eng.metrics()["truncated"] == 1
    # the truncated prefix must equal an unconstrained run's prefix
    big = ServeEngine(m, qp, n_slots=2, max_len=64)
    ref = big.generate(Request(rid=9, prompt=prompt, max_new_tokens=100))
    np.testing.assert_array_equal(res[1], ref[:len(res[1])])


def test_prompt_filling_cache_exactly(quantized_setup):
    """A prompt of exactly max_len still yields the prefill token (the
    cache has no room to decode further — truncated, never clamped)."""
    cfg, m, qp = quantized_setup
    eng = ServeEngine(m, qp, n_slots=2, max_len=16)
    prompt = (np.arange(16) % cfg.vocab_size).astype(np.int32)
    res = eng.serve([Request(rid=0, prompt=prompt, max_new_tokens=5)])
    assert res[0].shape == (1,)
    assert eng.metrics()["truncated"] == 1
    single = eng.generate(Request(rid=1, prompt=prompt, max_new_tokens=5))
    np.testing.assert_array_equal(res[0], single[:1])
    assert single.shape == (1,)


def test_int8_kv_cache_decode():
    """Beyond-paper feature: int8 KV cache halves cache bytes with near-
    lossless decode (argmax agreement with the fp-cache path)."""
    cfg = dataclasses.replace(ARCHS["llama3-8b"].tiny(), kv_cache_bits=8)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    cache = m.init_cache(2, 24)
    assert cache["k"].dtype == jnp.int8
    lp, cache = jax.jit(m.prefill)(params, tokens, cache)
    nxt = jnp.argmax(lp[:, 0, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    ld, cache = jax.jit(m.decode_step)(params, cache, nxt)
    lf, _ = jax.jit(lambda p, b: m.forward(p, b))(
        params, {"tokens": jnp.concatenate([tokens, nxt], 1)})
    rmse = float(jnp.sqrt(jnp.mean((ld[:, 0] - lf[:, -1]) ** 2)))
    assert rmse < 0.05
    assert bool(jnp.all(jnp.argmax(ld[:, 0, :cfg.vocab_size], -1)
                        == jnp.argmax(lf[:, -1, :cfg.vocab_size], -1)))


# -- unit pieces -------------------------------------------------------------

def test_default_buckets():
    assert default_buckets(64) == (16, 32, 64)
    assert default_buckets(48) == (16, 32, 48)
    assert default_buckets(8) == (8,)


def test_sampler_greedy_topk_temperature():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[0.1, 3.0, 1.0, -1e30],
                          [2.0, 0.5, 1.5, -1e30]], jnp.float32)
    # greedy rows: argmax regardless of key
    out = sample_tokens(logits, jnp.zeros(2), jnp.zeros(2, jnp.int32), key)
    np.testing.assert_array_equal(np.asarray(out), [1, 0])
    # temperature rows never pick the -1e30 padded column; top_k=1 is greedy
    temps = jnp.asarray([0.7, 1.3])
    for i in range(20):
        k = jax.random.fold_in(key, i)
        out = sample_tokens(logits, temps, jnp.zeros(2, jnp.int32), k)
        assert int(out.max()) < 3
        out1 = sample_tokens(logits, temps, jnp.ones(2, jnp.int32), k)
        np.testing.assert_array_equal(np.asarray(out1), [1, 0])
    # top_k=2 restricts to the two highest logits per row
    for i in range(20):
        k = jax.random.fold_in(key, 100 + i)
        out = sample_tokens(logits, temps, jnp.full(2, 2, jnp.int32), k)
        assert int(out[0]) in (1, 2) and int(out[1]) in (0, 2)


def test_write_slot_traced_index(quantized_setup):
    """The jitted per-slot admission op writes one batch-1 cache row into
    the batched cache, with the slot index traced (single compile)."""
    cfg, m, _ = quantized_setup
    batched = m.init_cache(3, 16)
    single = m.init_cache(1, 16)
    single = {k: jnp.ones_like(v) for k, v in single.items()}
    jitted = jax.jit(write_slot)
    out = jitted(batched, single, jnp.asarray(1, jnp.int32))
    assert bool(jnp.all(out["k"][:, 1] == 1)) and bool(out["len"][1] == 1)
    assert bool(jnp.all(out["k"][:, 0] == 0)) and bool(jnp.all(out["k"][:, 2] == 0))
    out2 = jitted(out, single, jnp.asarray(2, jnp.int32))
    assert bool(jnp.all(out2["k"][:, 2] == 1))
    assert jitted._cache_size() == 1  # slot index is traced, not static


def test_scheduler_deadlines_and_streaming(quantized_setup):
    cfg, m, qp = quantized_setup
    eng = ServeEngine(m, qp, n_slots=2, max_len=64)
    sched = Scheduler(eng)
    prompt = np.arange(5) % cfg.vocab_size
    streamed = {0: [], 1: [], 2: []}
    finished = []
    sched.submit(Request(rid=0, prompt=prompt, max_new_tokens=4),
                 on_token=lambda rid, t: streamed[rid].append(t),
                 on_finish=lambda rid, out: finished.append(rid))
    sched.submit(Request(rid=1, prompt=prompt, max_new_tokens=4),
                 deadline=time.time() - 1.0,   # already expired
                 on_finish=lambda rid, out: finished.append(rid))
    sched.submit(Request(rid=2, prompt=prompt, max_new_tokens=2),
                 deadline=time.time() + 300.0,
                 on_token=lambda rid, t: streamed[rid].append(t))
    res = sched.run()
    assert res[1].shape == (0,)                     # expired before admission
    assert sched.metrics()["expired"] == 1
    assert res[0].tolist() == streamed[0]           # stream == final output
    assert res[2].tolist() == streamed[2]
    assert len(res[0]) == 4 and len(res[2]) == 2
    assert sorted(finished) == [0, 1]
    # EDF: the deadline-bearing request is admitted first
    assert sched.pending() == 0


@pytest.mark.parametrize("kv_bits", [None, 8])
def test_serve_matches_generate_interpret_flash_decode(kv_bits, monkeypatch):
    """With the flash-decode kernels engaged (interpret mode), batched
    serve() must stay token-for-token identical to generate() on both
    fp16 and int8-KV dense caches — the decode hot loop now runs the
    split-KV Pallas kernel in both paths.  Weights stay fp so the run
    isolates the decode-attention kernels (the quant-matmul kernel has
    its own interpret coverage above)."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    cfg = ARCHS["llama3-8b"].tiny()
    if kv_bits:
        cfg = dataclasses.replace(cfg, kv_cache_bits=kv_bits)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(m, params, n_slots=2, max_len=64)
    reqs = _mixed_requests(cfg, 3, seed=7, max_new=(2, 5))
    batched = eng.serve([Request(rid=r.rid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens)
                         for r in reqs])
    for r in reqs:
        np.testing.assert_array_equal(batched[r.rid], eng.generate(r))


def test_serve_smoke_interpret_kernel_path(monkeypatch):
    """Minimal serve smoke forced onto the Pallas kernel path
    (interpret mode), paged cache on: the CI interpret-mode job runs
    this so tile-divisibility regressions in the serving hot path can
    never again hide behind the CPU "ref" dispatch default.  RTN keeps
    quantization itself cheap — the point is serving over the kernel."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    cfg = ARCHS["llama3-8b"].tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    stats = run_calibration(m.forward, params, [
        {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 16),
                                      0, cfg.vocab_size)}])
    qp, _ = quantize_model(params, m.quant_site_map(), stats, method="rtn",
                           spec=QuantSpec(bits=4, group_size=64),
                           mode="packed")
    eng = ServeEngine(m, qp, n_slots=2, max_len=16, paged=True, page_size=8)
    assert eng.paged
    prompt = np.arange(6) % cfg.vocab_size
    res = eng.serve([Request(rid=0, prompt=prompt, max_new_tokens=2),
                     Request(rid=1, prompt=prompt[:4], max_new_tokens=2)])
    np.testing.assert_array_equal(
        res[0], eng.generate(Request(rid=2, prompt=prompt,
                                     max_new_tokens=2)))
    assert res[1].shape == (2,)


def test_hymba_fallback_serve_matches_generate():
    """Models without prompt_len support (hymba ring-buffer prefill) use
    the per-request write_slot fallback and still serve correctly."""
    cfg = ARCHS["hymba-1.5b"].tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(m, params, n_slots=2, max_len=48)
    assert not eng._supports_plen
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               size=5 + 4 * i),
                    max_new_tokens=3 + i) for i in range(3)]
    batched = eng.serve([Request(rid=r.rid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens)
                         for r in reqs])
    for r in reqs:
        np.testing.assert_array_equal(batched[r.rid], eng.generate(r))
