"""Guards that the serving-core decomposition sticks: no serve module
regrows into a monolith, and dense/paged share one serve loop."""
import inspect
from pathlib import Path

import repro.serve as serve_pkg
from repro.serve import ServeEngine

MAX_MODULE_LINES = 600


def test_no_serve_module_exceeds_line_budget():
    pkg_dir = Path(serve_pkg.__file__).parent
    oversized = {}
    for path in sorted(pkg_dir.glob("*.py")):
        n = len(path.read_text().splitlines())
        if n > MAX_MODULE_LINES:
            oversized[path.name] = n
    assert not oversized, (
        f"serve modules over {MAX_MODULE_LINES} lines: {oversized} — "
        "split along the SlotTable/AdmissionPipeline/stepper seams "
        "(DESIGN.md §14) instead of growing the monolith back")


def test_single_serve_loop_for_both_cache_kinds():
    # the paged path is a stepper plugged into ServeEngine.serve, not a
    # second loop
    assert not hasattr(ServeEngine, "_serve_paged")
    sig = inspect.signature(ServeEngine.serve)
    assert "feed" in sig.parameters          # open-loop entry, same loop
    # the loop delegates cache-kind specifics through the stepper hooks:
    # no cache-kind branching inside the loop body
    src = inspect.getsource(ServeEngine.serve)
    assert "self.paged" not in src and "self._stepper." not in src.replace(
        "self._stepper.begin", "")
