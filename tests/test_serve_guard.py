"""Guards that the serving-core decomposition sticks: no serve module
regrows into a monolith, and dense/paged share one serve loop.

The structural checks are thin wrappers over :mod:`repro.analysis` —
the loop-unity invariant is rule RPR005 and the line budget uses the
comment/docstring-insensitive counter, so reformatting or documenting a
module never trips the guard but new code does.
"""
import inspect
from pathlib import Path

import repro.serve as serve_pkg
from repro.analysis import code_line_count, run_lint
from repro.analysis.rules import rules_by_code
from repro.serve import ServeEngine

MAX_MODULE_CODE_LINES = 450

SERVE_DIR = Path(serve_pkg.__file__).parent
REPO_ROOT = SERVE_DIR.parents[3]


def test_no_serve_module_exceeds_line_budget():
    oversized = {}
    for path in sorted(SERVE_DIR.glob("*.py")):
        n = code_line_count(path.read_text())
        if n > MAX_MODULE_CODE_LINES:
            oversized[path.name] = n
    assert not oversized, (
        f"serve modules over {MAX_MODULE_CODE_LINES} code lines: "
        f"{oversized} — split along the SlotTable/AdmissionPipeline/"
        "stepper seams (DESIGN.md §14) instead of growing the monolith "
        "back")


def test_single_serve_loop_for_both_cache_kinds():
    # the paged path is a stepper plugged into ServeEngine.serve, not a
    # second loop; RPR005 flags cache-kind branching or stepper
    # internals inside the loop body, and a regrown _serve_* entry
    assert not hasattr(ServeEngine, "_serve_paged")
    sig = inspect.signature(ServeEngine.serve)
    assert "feed" in sig.parameters          # open-loop entry, same loop
    findings = run_lint([str(SERVE_DIR)], rules_by_code("RPR005"),
                        base=REPO_ROOT)
    assert not findings, "\n".join(f.render() for f in findings)


def test_serve_package_lint_clean():
    # the full rule set over serve/ (noqa-suppressed sites excluded):
    # raw jax.jit outside the seam, host syncs in jitted bodies, clock
    # calls outside the seam, etc. all stay out
    from repro.analysis.rules import all_rules
    findings = run_lint([str(SERVE_DIR)], all_rules(), base=REPO_ROOT)
    assert not findings, "\n".join(f.render() for f in findings)
