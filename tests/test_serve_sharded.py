"""Tensor-parallel serving (DESIGN.md §13): greedy identity across mesh
shapes, the one-logits-all-gather decode invariant, device-count errors,
and divisibility warnings.

Multi-device tests run in subprocesses (the virtual device count must be
set before jax initializes) so the plain single-device test run stays
valid — same idiom as test_sharding.py.
"""
import logging
import os
import subprocess
import sys

import pytest

from repro.dist.sharding import (DEFAULT_RULES, SERVE_DECODE_RULES,
                                 active_rule, axis_rules, logical_to_spec,
                                 row_parallel)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def _run(code: str) -> subprocess.CompletedProcess:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)


# shared preamble: tiny target, FAQ-packed int4 weights, synthetic prompts
_SETUP = """
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import ARCHS
from repro.core import QuantSpec, quantize_model, run_calibration
from repro.data.synthetic import DataConfig, SyntheticLM, calibration_batches
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine
from repro.launch.mesh import make_local_mesh

def build(cfg):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size))
    calib = calibration_batches(data, 4, 32)
    stats = run_calibration(model.forward, params,
                            [{k: jnp.asarray(v) for k, v in b.items()}
                             for b in calib])
    qp, _ = quantize_model(params, model.quant_site_map(), stats,
                           method="faq",
                           spec=QuantSpec(bits=4, group_size=64),
                           mode="packed")
    return model, qp, stats, data

def reqs(data):
    return [Request(rid=i, prompt=data.sequence(77 + i, 9 + i),
                    max_new_tokens=8) for i in range(3)]
"""


# ---------------------------------------------------------------------------
# Fast single-device tests (run in the plain tier-1 suite)
# ---------------------------------------------------------------------------

def test_mesh_device_count_error():
    """make_local_mesh / make_production_mesh must refuse — naming the
    required vs available counts — instead of silently slicing a too-small
    jax.devices()."""
    import jax

    from repro.launch.mesh import make_local_mesh, make_production_mesh
    avail = len(jax.devices())
    with pytest.raises(ValueError, match=r"requires 16 devices"):
        make_local_mesh(4, 4)        # 16 > both 1 and the CI's 8
    with pytest.raises(ValueError, match=str(avail)):
        make_local_mesh(4, 4)
    with pytest.raises(ValueError, match=r"requires 256 devices"):
        make_production_mesh()


def test_divisibility_warn_once(caplog):
    """A dropped shard axis warns exactly once per unique site."""
    mesh = FakeMesh({"data": 16, "model": 16})
    args = dict(mesh=mesh, rules=DEFAULT_RULES)
    with caplog.at_level(logging.WARNING, logger="repro.dist.sharding"):
        for _ in range(3):   # identical site: one warning total
            logical_to_spec(["batch", None, "kv_heads", None],
                            shape=(256, 4, 10, 128), **args)
        warns = [r for r in caplog.records if "NOT sharded" in r.message]
        assert len(warns) == 1
        assert "kv_heads" in warns[0].message and "10" in warns[0].message
        # a different shape is a different site: warns again
        logical_to_spec(["batch", None, "kv_heads", None],
                        shape=(256, 4, 12, 128), **args)
        warns = [r for r in caplog.records if "NOT sharded" in r.message]
        assert len(warns) == 2
        # singleton dims replicate silently (nothing to lose)
        logical_to_spec(["batch", "kv_heads"], shape=(256, 1), **args)
        warns = [r for r in caplog.records if "NOT sharded" in r.message]
        assert len(warns) == 2


def test_row_parallel_rebinds_qin():
    """row_parallel() disarms the packed-domain constraint exactly in the
    decode regime (qin None -> "model") and is a no-op elsewhere."""
    mesh = FakeMesh({"data": 1, "model": 4})
    with axis_rules(mesh, SERVE_DECODE_RULES):
        assert active_rule("qin") is None
        with row_parallel():
            assert active_rule("qin") == "model"
            assert active_rule("heads") == "model"   # rest of table intact
        assert active_rule("qin") is None
    # default rules: qin already bound, context changes nothing
    with axis_rules(mesh, DEFAULT_RULES):
        with row_parallel():
            assert active_rule("qin") == DEFAULT_RULES["qin"]
    # no active mesh: no-op
    with row_parallel():
        assert active_rule("qin") == DEFAULT_RULES["qin"]


# ---------------------------------------------------------------------------
# Multi-device subprocess tests
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_identity_matrix():
    """Greedy outputs are token-for-token identical to the single-device
    engine for dense and paged serving, with and without speculative
    decoding, at mesh shapes (1,2) and (1,4) — plus the non-dividing
    head-count fallback (KH=2 on model=4, GSPMD path, no shard_map)."""
    code = _SETUP + """
from repro.serve.draft import self_int8_draft
from repro.serve.spec import SpecConfig

cfg = dataclasses.replace(ARCHS["llama3-8b"].tiny(), n_kv_heads=4)
model, qp, stats, data = build(cfg)

def run(**kw):
    sc = (SpecConfig(k=2, draft=self_int8_draft(model, qp, stats))
          if kw.pop("spec", False) else None)
    eng = ServeEngine(model, qp, n_slots=2, max_len=64, spec=sc, **kw)
    return eng.serve(reqs(data))

modes = [{}, {"paged": True}, {"spec": True}, {"paged": True, "spec": True}]
refs = [run(**dict(m)) for m in modes]
for r in refs[0]:
    assert all(refs[0][r].tolist() == ref[r].tolist() for ref in refs[1:])
for shape in [(1, 2), (1, 4)]:
    mesh = make_local_mesh(*shape)
    for m, ref in zip(modes, refs):
        got = run(mesh=mesh, **dict(m))
        for r in ref:
            assert got[r].tolist() == ref[r].tolist(), (shape, m, r)

# head count (KH=2) not dividing model=4: the shard_map guard must skip
# cleanly and GSPMD still reproduce the reference bit-for-bit
cfg2 = ARCHS["llama3-8b"].tiny()
model2, qp2, _, data2 = build(cfg2)
ref = ServeEngine(model2, qp2, n_slots=2, max_len=64).serve(reqs(data2))
got = ServeEngine(model2, qp2, n_slots=2, max_len=64,
                  mesh=make_local_mesh(1, 4)).serve(reqs(data2))
for r in ref:
    assert got[r].tolist() == ref[r].tolist()
print("IDENTITY-OK")
"""
    out = _run(code)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "IDENTITY-OK" in out.stdout


@pytest.mark.slow
def test_decode_collective_invariant():
    """The compiled sharded decode step contains exactly one all-gather
    (the logits) and no KV-cache collectives: zero all-to-all /
    collective-permute, and every all-reduce is activation-sized
    (B * d_model partial sums), never cache-sized.  Also checks the TP
    placement of quantized leaves (codes and scales split on the same
    axis) and that steady-state decode compiles exactly once."""
    code = _SETUP + """
import re
from repro.dist.sharding import SERVE_DECODE_RULES, axis_rules

cfg = ARCHS["llama3-8b"].tiny()        # KH=2 shards on model=2
model, qp, stats, data = build(cfg)
mesh = make_local_mesh(1, 2)
eng = ServeEngine(model, qp, n_slots=2, max_len=64, mesh=mesh)

# quantized TP layout: wq column-parallel — codes and scale both split
# their output dim on "model"; wo row-parallel — codes split the input
# (head) dim instead
wq, wo = eng.params["blocks"]["wq"], eng.params["blocks"]["wo"]
assert wq.codes.sharding.spec[2] == "model", wq.codes.sharding.spec
assert wq.scale.sharding.spec[2] == "model", wq.scale.sharding.spec
assert wo.codes.sharding.spec[1] == "model", wo.codes.sharding.spec
k_shard = eng._place(model.init_cache(2, 64), eng._cache_axes)
assert k_shard["k"].sharding.spec[2] == "model"   # head-sharded KV

args = (eng.params, k_shard, jnp.zeros((2,), jnp.int32),
        jnp.ones((2,), bool), jnp.zeros((2,), jnp.float32), None, None,
        jax.random.PRNGKey(0))
with axis_rules(mesh, SERVE_DECODE_RULES):
    txt = eng._decode.fn.jitted.lower(*args).compile().as_text()

def defs(kind):
    return re.findall(r"= (\\S+) %s\\(" % kind, txt)

assert len(defs("all-gather")) == 1, txt.count("all-gather")
v_pad = eng.params["lm_head"].shape[-1]   # padded vocab (fp16/fp32 head)
(ag_ty,) = defs("all-gather")
assert str(v_pad) in ag_ty            # it IS the logits gather
assert len(defs("all-to-all")) == 0
assert len(defs("collective-permute")) == 0
for ty in defs("all-reduce"):
    dims = [int(d) for d in re.findall(r"\\d+", ty.split("[")[1])]
    n = 1
    for d in dims:
        n *= d
    assert n <= 2 * cfg.d_model, ty   # activation-sized, never KV-sized

# steady-state: the greedy decode step compiles exactly once end to end
out = eng.serve(reqs(data))
assert eng._decode.traces == 1, eng._decode.traces
assert eng._decode.calls > 1
print("INVARIANT-OK")
"""
    out = _run(code)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "INVARIANT-OK" in out.stdout
