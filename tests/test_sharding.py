"""Sharding rules + distributed-path equivalence (virtual devices)."""
import os
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import DEFAULT_RULES, logical_to_spec

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    # 8 kv heads don't divide 16 -> replicated
    spec = logical_to_spec(["batch", None, "kv_heads", None],
                           shape=(256, 1, 8, 128), mesh=mesh,
                           rules=DEFAULT_RULES)
    assert spec == P(("data",), None, None, None) or spec == P("data", None, None, None)
    # 32 heads divide -> sharded
    spec = logical_to_spec(["batch", None, "heads", None],
                           shape=(256, 1, 32, 128), mesh=mesh,
                           rules=DEFAULT_RULES)
    assert spec[2] == "model"


def test_axis_used_once_priority():
    """kv_heads (earlier dim) wins 'model'; kv_seq then falls back."""
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = logical_to_spec([None, "batch", "kv_heads", "kv_seq", None],
                           shape=(4, 256, 16, 4096, 128), mesh=mesh,
                           rules=DEFAULT_RULES)
    assert spec[2] == "model" and spec[3] is None
    # 5 kv heads -> heads replicated, sequence takes model
    spec = logical_to_spec([None, "batch", "kv_heads", "kv_seq", None],
                           shape=(4, 256, 5, 4096, 128), mesh=mesh,
                           rules=DEFAULT_RULES)
    assert spec[2] is None and spec[3] == "model"


def test_warn_dropped_keyed_on_logical_name(caplog):
    """The warn-once dedupe key includes the logical axis *name*: two
    sites that agree on position, shape and dropped mesh axes but drop
    a different logical axis must both warn (the name is not derivable
    from the other key parts when a caller resolves aliases)."""
    import logging

    from repro.dist.sharding import _warn_dropped

    axes = ["batch", None, "kv_heads", None]
    shape = (257, 3, 11, 129)            # distinctive: module-global set
    with caplog.at_level(logging.WARNING, logger="repro.dist.sharding"):
        _warn_dropped(axes, shape, 2, "kv_heads", ("model",), 16)
        _warn_dropped(axes, shape, 2, "kv_heads", ("model",), 16)  # dup
        _warn_dropped(axes, shape, 2, "kv_seq", ("model",), 16)    # new
    warns = [r for r in caplog.records if "NOT sharded" in r.message]
    assert len(warns) == 2
    assert "kv_heads" in warns[0].message
    assert "kv_seq" in warns[1].message


def test_missing_mesh_axis_dropped():
    mesh = FakeMesh({"data": 16, "model": 16})  # no "pod"
    spec = logical_to_spec(["batch"], shape=(256,), mesh=mesh,
                           rules=DEFAULT_RULES)
    assert spec[0] in ("data", ("data",))


@pytest.mark.slow
def test_moe_shard_map_equals_local():
    """Numerical equivalence of the expert-parallel shard_map path vs the
    single-device path, on 8 virtual CPU devices (subprocess: device count
    must be set before jax initializes)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, dataclasses, functools
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.configs import ARCHS
from repro.models.moe import (_moe_body_sharded, moe_ffn_local,
                              padded_experts)

cfg = dataclasses.replace(ARCHS["qwen2-moe-a2.7b"].tiny(),
                          moe_capacity_factor=16.0)
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     devices=jax.devices()[:8])
e_pad = padded_experts(cfg.n_experts, 4)
ks = jax.random.split(jax.random.PRNGKey(0), 5)
d, f = cfg.d_model, cfg.d_ff
x = jax.random.normal(ks[0], (4, 8, d))
router = jax.random.normal(ks[1], (d, e_pad)) * 0.1
wg = jax.random.normal(ks[2], (e_pad, d, f)) * 0.05
wu = jax.random.normal(ks[3], (e_pad, d, f)) * 0.05
wd = jax.random.normal(ks[4], (e_pad, f, d)) * 0.05
y_local, _, _ = moe_ffn_local(x, router, wg, wu, wd, cfg)
body = functools.partial(_moe_body_sharded, cfg=cfg, model_axis="model",
                         fsdp_axes=("data",))
fn = shard_map(body, mesh=mesh,
               in_specs=(P("data", None, None), P(None, None),
                         P("model", "data", None), P("model", "data", None),
                         P("model", None, "data")),
               out_specs=(P("data", None, None), P()), check_rep=False)
y_sh, _ = jax.jit(fn)(x, router, wg, wu, wd)
diff = float(jnp.max(jnp.abs(y_sh - y_local)))
assert diff < 1e-5, diff
print("OK", diff)
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
