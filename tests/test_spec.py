"""Speculative decoding subsystem (DESIGN.md §12): draft sources,
batched verify, accept/resample rule, KV rollback, scheduler surface."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import QuantSpec, quantize_model, run_calibration
from repro.models.registry import build_model
from repro.serve import (Request, Scheduler, ServeEngine, SpecConfig,
                         policy_probs, registry_draft, sample_tokens,
                         self_int8_draft, spec_accept, truncate_slot)


@pytest.fixture(scope="module")
def fp_setup():
    cfg = ARCHS["llama3-8b"].tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


@pytest.fixture(scope="module")
def kv8_setup():
    cfg = dataclasses.replace(ARCHS["llama3-8b"].tiny(), kv_cache_bits=8)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _mixed_requests(cfg, n, seed=0, max_new=(2, 10)):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(3, 28))),
                    max_new_tokens=int(rng.integers(*max_new)))
            for i in range(n)]


def _clone(reqs):
    return [Request(rid=r.rid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens, deadline=r.deadline)
            for r in reqs]


def _assert_identical(plain_eng, spec_eng, reqs):
    res_p = plain_eng.serve(_clone(reqs))
    res_s = spec_eng.serve(_clone(reqs))
    for r in reqs:
        np.testing.assert_array_equal(res_p[r.rid], res_s[r.rid])
    return spec_eng.metrics()


# -- greedy identity: the acceptance-criteria matrix -------------------------

@pytest.mark.parametrize("paged", [False, True])
def test_spec_matches_nonspec_fp16(fp_setup, paged):
    """Greedy serve(spec=...) is token-for-token identical to
    non-speculative serve() on the fp16 cache (dense and paged), and the
    self-int8 draft actually accepts (it tracks its own target)."""
    cfg, m, params = fp_setup
    draft = self_int8_draft(m, params)
    plain = ServeEngine(m, params, n_slots=2, max_len=64, paged=paged,
                        page_size=8)
    spec = ServeEngine(m, params, n_slots=2, max_len=64, paged=paged,
                       page_size=8, spec=SpecConfig(k=3, draft=draft))
    mm = _assert_identical(plain, spec, _mixed_requests(cfg, 6, seed=0))
    assert mm["spec"] and mm["spec_cycles"] > 0
    assert mm["accept_rate"] > 0.5
    assert mm["tokens_per_step"] > 1.0


@pytest.mark.parametrize("paged", [False, True])
def test_spec_matches_nonspec_kv8(kv8_setup, paged):
    """Same identity on the int8-KV cache: the draft's speculative
    writes quantize through the same per-(token, head) scales and the
    verify span overwrites them."""
    cfg, m, params = kv8_setup
    draft = self_int8_draft(m, params)
    plain = ServeEngine(m, params, n_slots=2, max_len=48, paged=paged,
                        page_size=8)
    spec = ServeEngine(m, params, n_slots=2, max_len=48, paged=paged,
                       page_size=8, spec=SpecConfig(k=2, draft=draft))
    _assert_identical(plain, spec, _mixed_requests(cfg, 5, seed=1))


def test_spec_matches_generate_int4_packed_target(fp_setup):
    """The serving configuration that matters: FAQ int4-*packed* target,
    self-int8 draft re-quantized from the packed codes.  Speculative
    output equals generate() exactly and the draft tracks the target
    well (that's the paper's future-activation story paying off)."""
    cfg, m, params = fp_setup
    stats = run_calibration(m.forward, params, [
        {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                      0, cfg.vocab_size)}])
    qp, _ = quantize_model(params, m.quant_site_map(), stats, method="faq",
                           spec=QuantSpec(bits=4, group_size=64),
                           mode="packed")
    draft = self_int8_draft(m, qp, stats)
    eng = ServeEngine(m, qp, n_slots=2, max_len=64,
                      spec=SpecConfig(k=3, draft=draft))
    reqs = _mixed_requests(cfg, 4, seed=2)
    res = eng.serve(_clone(reqs))
    for r in reqs:
        np.testing.assert_array_equal(res[r.rid], eng.generate(r))
    mm = eng.metrics()
    assert mm["accept_rate"] > 0.7          # int8(served) ~ int4 target
    assert mm["draft_kind"] == "self-int8"


def test_spec_identity_survives_hostile_draft(fp_setup):
    """Correctness never depends on the draft: an *independent*
    randomly-initialized registry draft proposes garbage (acceptance
    ~0) yet greedy output stays exactly the target's."""
    cfg, m, params = fp_setup
    draft = registry_draft("stablelm-12b", seed=7)
    plain = ServeEngine(m, params, n_slots=2, max_len=64)
    spec = ServeEngine(m, params, n_slots=2, max_len=64,
                       spec=SpecConfig(k=2, draft=draft))
    mm = _assert_identical(plain, spec, _mixed_requests(cfg, 4, seed=3))
    assert mm["accept_rate"] < 0.5
    assert mm["draft_kind"] == "model"


def test_spec_moe_single_slot():
    """MoE verify routes the burst per position, so single-slot
    speculative decode matches exactly.  (Multi-slot batched MoE decode
    is composition-dependent — expert capacity contention — with or
    without speculation, so identity is only well-defined per-slot.)"""
    cfg = ARCHS["qwen2-moe-a2.7b"].tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    assert m.supports_spec()
    draft = self_int8_draft(m, params)
    plain = ServeEngine(m, params, n_slots=1, max_len=64)
    spec = ServeEngine(m, params, n_slots=1, max_len=64,
                       spec=SpecConfig(k=3, draft=draft))
    _assert_identical(plain, spec, _mixed_requests(cfg, 3, seed=4))


def test_spec_unsupported_model_falls_back():
    """Ring-buffer hymba lacks the span-write decode path: the engine
    declines spec and serves non-speculatively."""
    cfg = ARCHS["hymba-1.5b"].tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    assert not m.supports_spec()
    eng = ServeEngine(m, params, n_slots=2, max_len=48,
                      spec=SpecConfig(k=3, draft=self_int8_draft(m, params)))
    assert eng._spec is None
    res = eng.serve([Request(rid=0, prompt=np.arange(6) % cfg.vocab_size,
                             max_new_tokens=3)])
    assert res[0].shape == (3,)
    assert not eng.metrics()["spec"]


# -- budget / deadline truncation against speculative bursts -----------------

def test_spec_burst_overshoot_truncated_at_budget(fp_setup):
    """max_new_tokens that is not a multiple of k+1: the final burst
    overshoots and the accepted surplus must be dropped — output lengths
    (and tokens) match non-spec exactly, and the engine's capacity
    invariants hold."""
    cfg, m, params = fp_setup
    draft = self_int8_draft(m, params)
    plain = ServeEngine(m, params, n_slots=2, max_len=64)
    spec = ServeEngine(m, params, n_slots=2, max_len=64,
                       spec=SpecConfig(k=3, draft=draft))
    # budgets 5 and 6 with k+1 = 4-token bursts: both overshoot mid-burst
    reqs = [Request(rid=0, prompt=np.arange(9) % cfg.vocab_size,
                    max_new_tokens=5),
            Request(rid=1, prompt=np.arange(17) % cfg.vocab_size,
                    max_new_tokens=6)]
    res_p = plain.serve(_clone(reqs))
    res_s = spec.serve(_clone(reqs))
    for r in reqs:
        assert len(res_s[r.rid]) == r.max_new_tokens
        np.testing.assert_array_equal(res_p[r.rid], res_s[r.rid])


def test_spec_capacity_truncation_matches_nonspec(fp_setup):
    """A request hitting max_len mid-burst truncates at exactly the
    same point as non-speculative serving (the cycle's draft depth
    shrinks near capacity instead of clamp-corrupting the cache)."""
    cfg, m, params = fp_setup
    draft = self_int8_draft(m, params)
    max_len = 24
    plain = ServeEngine(m, params, n_slots=2, max_len=max_len,
                        buckets=(8, 24))
    spec = ServeEngine(m, params, n_slots=2, max_len=max_len,
                       buckets=(8, 24), spec=SpecConfig(k=3, draft=draft))
    prompt = (np.arange(8) % cfg.vocab_size).astype(np.int32)
    reqs = [Request(rid=0, prompt=prompt, max_new_tokens=100)]
    res_p = plain.serve(_clone(reqs))
    res_s = spec.serve(_clone(reqs))
    np.testing.assert_array_equal(res_p[0], res_s[0])
    assert res_s[0].shape == (1 + max_len - len(prompt),)
    assert spec.metrics()["truncated"] == 1


def test_edf_deadline_expires_mid_decode_spec_burst(fp_setup):
    """EDF-scheduled request whose deadline passes *mid-decode* while a
    speculative burst overshoots its budget: accepted tokens past the
    deadline/budget are dropped, the request is truncated (not
    expired), and the emitted prefix matches the deadline-free run.
    The engine clock is injected (``clock=`` seam) so expiry lands
    deterministically inside the decode loop — no monkeypatching."""
    cfg, m, params = fp_setup

    draft = self_int8_draft(m, params)
    prompt = (np.arange(7) % cfg.vocab_size).astype(np.int32)

    # deadline-free reference
    ref_eng = ServeEngine(m, params, n_slots=1, max_len=64,
                          spec=SpecConfig(k=3, draft=draft))
    ref = ref_eng.serve([Request(rid=9, prompt=prompt,
                                 max_new_tokens=40)])[9]

    clock = {"t": 0.0}

    def fake_time():
        clock["t"] += 1.0           # each engine timestamp advances 1s
        return clock["t"]

    eng = ServeEngine(m, params, n_slots=1, max_len=64,
                      spec=SpecConfig(k=3, draft=draft), clock=fake_time)
    sched = Scheduler(eng)
    streamed = []
    # expires a few engine timestamps in: admission survives, a later
    # speculative burst crosses it mid-decode
    sched.submit(Request(rid=0, prompt=prompt, max_new_tokens=40),
                 deadline=6.5,
                 on_token=lambda rid, t: streamed.append(t))
    out = sched.run()
    assert eng.metrics()["truncated"] == 1
    assert eng.metrics()["expired"] == 0
    assert 0 < len(out[0]) < 40
    np.testing.assert_array_equal(out[0], ref[:len(out[0])])
    assert streamed == out[0].tolist()
    assert out.summary["truncated"] == 1


# -- scheduler summary surface ------------------------------------------------

def test_scheduler_run_surfaces_spec_summary(fp_setup):
    cfg, m, params = fp_setup
    draft = self_int8_draft(m, params)
    eng = ServeEngine(m, params, n_slots=2, max_len=64,
                      spec=SpecConfig(k=3, draft=draft))
    sched = Scheduler(eng)
    prompt = np.arange(5) % cfg.vocab_size
    for rid, budget in ((0, 12), (1, 3)):
        sched.submit(Request(rid=rid, prompt=prompt, max_new_tokens=budget))
    out = sched.run()
    s = out.summary
    assert s["spec"] is True
    assert s["requests"] == 2 and s["completed"] == 2
    assert 0.0 <= s["accept_rate"] <= 1.0
    assert s["draft_kind"] == "self-int8" and s["spec_k"] == 3
    assert set(s["tokens_per_step_by_request"]) == {0, 1}
    # the long request rides speculative bursts: > 1 token per step
    assert s["tokens_per_step_by_request"][0] > 1.0
    assert s["tokens_per_step"] > 1.0
    assert s["tokens_generated"] == 15


def test_spec_draft_vocab_mismatch_fails_fast(fp_setup):
    """An independent draft with a different vocab can't feed the
    elementwise accept rule — rejected at engine construction, not as
    an opaque broadcast error inside the jitted cycle."""
    from repro.serve import ModelDraft

    cfg, m, params = fp_setup
    cfg2 = dataclasses.replace(cfg, vocab_size=cfg.vocab_size // 2)
    dm = build_model(cfg2)
    draft = ModelDraft(model=dm, params=dm.init(jax.random.PRNGKey(1)))
    with pytest.raises(ValueError, match="vocab_size"):
        ServeEngine(m, params, n_slots=2, max_len=32,
                    spec=SpecConfig(k=2, draft=draft))


def test_draft_share_counts_only_emitted_tokens(fp_setup):
    """Budget-truncated bursts accept more drafts than they emit:
    draft_share must count the emitted subset (bounded by 1), while
    accept_rate keeps measuring raw draft quality."""
    cfg, m, params = fp_setup
    draft = self_int8_draft(m, params)
    eng = ServeEngine(m, params, n_slots=2, max_len=64,
                      spec=SpecConfig(k=3, draft=draft))
    # budget 2: one token at prefill + a burst that emits exactly one
    reqs = [Request(rid=i, prompt=np.arange(5 + i) % cfg.vocab_size,
                    max_new_tokens=2) for i in range(4)]
    eng.serve(reqs)
    mm = eng.metrics()
    assert 0.0 <= mm["draft_share"] <= 1.0
    assert mm["emitted_draft_tokens"] <= mm["accepted_tokens"]
    assert mm["tokens_generated"] == 8


def test_scheduler_summary_is_per_run(fp_setup):
    """A reused Scheduler reports each run's own digest, not the
    engine-lifetime cumulative counters."""
    cfg, m, params = fp_setup
    eng = ServeEngine(m, params, n_slots=2, max_len=64)
    sched = Scheduler(eng)
    prompt = np.arange(5) % cfg.vocab_size
    sched.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
    sched.submit(Request(rid=1, prompt=prompt, max_new_tokens=4))
    first = sched.run().summary
    sched.submit(Request(rid=2, prompt=prompt, max_new_tokens=3))
    second = sched.run().summary
    assert first["requests"] == 2 and first["completed"] == 2
    assert first["tokens_generated"] == 8
    assert second["requests"] == 1 and second["completed"] == 1
    assert second["tokens_generated"] == 3
    assert set(second["tokens_per_step_by_request"]) == {2}


def test_independent_draft_kv_tracks_through_fill_fallback(fp_setup):
    """Plain-decode fallback iterations (paged prefix-hit slots
    teacher-forcing their prompt tail) must advance the independent
    draft's KV too — otherwise later cycles attend permanent holes and
    acceptance silently collapses.  The draft here *is* the target
    (same arch, same seed), so acceptance stays ~1 iff tracking works."""
    cfg, m, params = fp_setup
    draft = registry_draft("llama3-8b", seed=0)   # identical weights
    rng = np.random.default_rng(8)
    sys_prompt = rng.integers(0, cfg.vocab_size, size=16)
    reqs = [Request(rid=i,
                    prompt=np.concatenate(
                        [sys_prompt,
                         rng.integers(0, cfg.vocab_size, size=4 + 3 * i)]),
                    max_new_tokens=9)
            for i in range(3)]
    plain = ServeEngine(m, params, n_slots=2, max_len=64, paged=True,
                        page_size=8)
    spec = ServeEngine(m, params, n_slots=2, max_len=64, paged=True,
                       page_size=8, spec=SpecConfig(k=3, draft=draft))
    res_p = plain.serve(_clone(reqs))
    res_s = spec.serve(_clone(reqs))
    for r in reqs:
        np.testing.assert_array_equal(res_p[r.rid], res_s[r.rid])
    mm = spec.metrics()
    assert mm["prefix_hits"] >= 1           # the fill path really ran
    assert mm["accept_rate"] > 0.9


# -- sampler units ------------------------------------------------------------

def test_sampler_top_p_restricts_support():
    key = jax.random.PRNGKey(0)
    logits = jnp.log(jnp.asarray([[0.5, 0.3, 0.15, 0.05],
                                  [0.4, 0.3, 0.2, 0.1]], jnp.float32))
    temps = jnp.ones(2)
    tk = jnp.zeros(2, jnp.int32)
    # top_p just over the head mass: only tokens inside the nucleus draw
    tp = jnp.asarray([0.6, 0.65])
    for i in range(30):
        out = sample_tokens(logits, temps, tk,
                            jax.random.fold_in(key, i), tp)
        assert int(out[0]) in (0, 1)        # 0.5 + 0.3 covers 0.6
        assert int(out[1]) in (0, 1)        # 0.4 + 0.3 covers 0.65
    # top_p <= 0 and >= 1 disable the mask; tiny top_p degenerates to
    # greedy (the top-1 token always survives)
    out = sample_tokens(logits, temps, tk, key, jnp.asarray([0.0, 1.0]))
    assert out.shape == (2,)
    for i in range(10):
        out = sample_tokens(logits, temps, tk, jax.random.fold_in(key, i),
                            jnp.full(2, 1e-6))
        np.testing.assert_array_equal(np.asarray(out), [0, 0])
    # greedy rows ignore top_p entirely
    out = sample_tokens(logits, jnp.zeros(2), tk, key, jnp.full(2, 0.3))
    np.testing.assert_array_equal(np.asarray(out), [0, 0])


def test_policy_probs_greedy_is_onehot_and_matches_sampler():
    logits = jnp.asarray([[0.1, 3.0, 1.0, -1e30],
                          [2.0, 0.5, 1.5, -1e30]], jnp.float32)
    p = policy_probs(logits, jnp.zeros(2))
    np.testing.assert_array_equal(np.asarray(p),
                                  [[0, 1, 0, 0], [1, 0, 0, 0]])
    # sampling rows: a proper distribution over the unmasked support
    p = policy_probs(logits, jnp.ones(2), jnp.full(2, 2, jnp.int32),
                     jnp.zeros(2))
    np.testing.assert_allclose(np.asarray(p.sum(-1)), [1.0, 1.0],
                               rtol=1e-5)
    assert float(p[0, 0]) == 0.0 and float(p[0, 3]) == 0.0  # top-k=2


def test_spec_accept_greedy_semantics():
    """Greedy accept: leading draft tokens equal to the target argmax
    are kept, the first mismatch emits the target argmax, full
    acceptance emits the bonus argmax."""
    v = 8
    temps = jnp.zeros(1)
    key = jax.random.PRNGKey(0)

    def target(*ids):                       # (1, K+1, V) argmax at ids
        return jnp.stack([jax.nn.one_hot(i, v) * 5.0 for i in ids])[None]

    onehot = lambda i: jax.nn.one_hot(jnp.asarray([i]), v)
    # draft proposes [3, 4]; target argmaxes [3, 4, 6] -> all accepted
    out, n = spec_accept(jnp.asarray([[3, 4]]),
                         jnp.stack([onehot(3), onehot(4)], 1),
                         target(3, 4, 6), temps, None, None, key)
    assert int(n[0]) == 2
    np.testing.assert_array_equal(np.asarray(out[0]), [3, 4, 6])
    # draft proposes [3, 4]; target argmaxes [5, ...] -> reject first,
    # emit target argmax 5
    out, n = spec_accept(jnp.asarray([[3, 4]]),
                         jnp.stack([onehot(3), onehot(4)], 1),
                         target(5, 1, 2), temps, None, None, key)
    assert int(n[0]) == 0
    assert int(out[0, 0]) == 5


def test_spec_accept_leftover_distribution_statistics():
    """Sampled rows follow the leftover rule: q puts {0.5, 0.5} on
    tokens {0, 1}, p puts {0.25, 0.75} on tokens {1, 2}.  A draw of 0
    always rejects (p(0)=0) and must resample from
    norm(max(p-q, 0)) = one-hot(2); a draw of 1 accepts with
    probability p(1)/q(1) = 0.5, else also resamples to 2."""
    q = jnp.asarray([[0.5, 0.5, 0.0, 0.0]])
    p_logits = jnp.log(jnp.asarray([[1e-9, 0.25, 0.75, 1e-9]]))[None]
    temps = jnp.ones(1)
    seen = set()
    for i in range(60):
        key = jax.random.PRNGKey(i)
        for d in (0, 1):
            out, n = spec_accept(
                jnp.asarray([[d]]), q[:, None],
                jnp.concatenate([p_logits, p_logits], 1),
                temps, None, None, key)
            tok = int(out[0, 0])
            if d == 0:
                # residual = norm(max(p - q, 0)): token 1's mass is
                # fully covered by q, so the resample is always 2
                assert int(n[0]) == 0 and tok == 2
            else:
                seen.add((int(n[0]), tok))
    # d=1: accepted about half the time (keeps 1), else resampled to 2
    assert (1, 1) in seen and (0, 2) in seen
    assert all(s in ((1, 1), (0, 2)) for s in seen)


def test_truncate_slot_rolls_back_len_only(fp_setup):
    cfg, m, _ = fp_setup
    cache = m.init_cache(2, 16)
    cache = dict(cache, len=jnp.asarray([9, 12], jnp.int32),
                 k=jnp.ones_like(cache["k"]))
    out = jax.jit(truncate_slot)(cache, jnp.asarray([7, 12], jnp.int32))
    np.testing.assert_array_equal(np.asarray(out["len"]), [7, 12])
    assert bool(jnp.all(out["k"] == 1))     # data untouched, only len


# -- paged specifics ----------------------------------------------------------

def test_spec_paged_prefix_sharing_and_rollback(fp_setup):
    """Shared-prefix paged workload under speculation: prefix-hit slots
    teacher-force their tail through plain decode (spec pauses while a
    slot fills), bursts trim rejected-suffix pages refcount-safely, and
    outputs match the non-speculative paged engine token-for-token."""
    cfg, m, params = fp_setup
    draft = self_int8_draft(m, params)
    rng = np.random.default_rng(5)
    sys_prompt = rng.integers(0, cfg.vocab_size, size=16)
    reqs = [Request(rid=i,
                    prompt=np.concatenate(
                        [sys_prompt,
                         rng.integers(0, cfg.vocab_size, size=3 + 5 * i)]),
                    max_new_tokens=6)
            for i in range(4)]
    plain = ServeEngine(m, params, n_slots=2, max_len=64, paged=True,
                        page_size=8)
    spec = ServeEngine(m, params, n_slots=2, max_len=64, paged=True,
                       page_size=8, spec=SpecConfig(k=3, draft=draft))
    res_p = plain.serve(_clone(reqs))
    res_s = spec.serve(_clone(reqs))
    for r in reqs:
        np.testing.assert_array_equal(res_p[r.rid], res_s[r.rid])
    mm = spec.metrics()
    assert mm["prefix_hits"] >= 1           # sharing still engages
    # every page ref released on retirement (only index-held refs stay)
    pool = spec.pool
    for p in range(1, pool.n_pages):
        assert pool.ref[p] in (0, 1)


def test_spec_serve_interpret_smoke(monkeypatch):
    """Spec serving forced onto the Pallas kernel path (interpret):
    the verify span unrolls per-position flash-decode kernel calls and
    must still match non-speculative serving exactly."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    cfg = ARCHS["llama3-8b"].tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    draft = self_int8_draft(m, params)
    plain = ServeEngine(m, params, n_slots=2, max_len=32)
    spec = ServeEngine(m, params, n_slots=2, max_len=32,
                       spec=SpecConfig(k=2, draft=draft))
    reqs = _mixed_requests(cfg, 3, seed=6, max_new=(2, 5))
    res_p = plain.serve(_clone(reqs))
    res_s = spec.serve(_clone(reqs))
    for r in reqs:
        np.testing.assert_array_equal(res_p[r.rid], res_s[r.rid])
