"""End-to-end system test: train a tiny LM on the synthetic pipeline,
calibrate, FAQ-quantize to the packed serving format, and serve — the
full lifecycle the framework is built for."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core import QuantSpec, quantize_model, run_calibration
from repro.data.synthetic import DataConfig, SyntheticLM, calibration_batches
from repro.models.registry import build_model
from repro.serve.engine import Request, ServeEngine
from repro.train.trainer import TrainConfig, make_train_step


def test_train_quantize_serve_lifecycle():
    cfg = ARCHS["llama3-8b"].tiny()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size))

    # 1) train briefly
    train_step, opt = make_train_step(m, TrainConfig(lr=3e-3, warmup=5,
                                                     total_steps=30))
    train_step = jax.jit(train_step)
    opt_state = opt.init(params)
    first = last = None
    for step in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step, 8, 64).items()}
        params, opt_state, metrics = train_step(params, opt_state, batch)
        last = float(metrics["loss"])
        first = first if first is not None else last
    assert last < first

    # 2) calibrate + quantize (packed FAQ int4)
    calib = calibration_batches(data, 8, 64)
    stats = run_calibration(m.forward, params,
                            [{k: jnp.asarray(v) for k, v in b.items()}
                             for b in calib])
    qp, report = quantize_model(params, m.quant_site_map(), stats,
                                method="faq",
                                spec=QuantSpec(bits=4, group_size=64),
                                mode="packed")
    assert report

    # 3) serve: greedy generation must match the quantized model's own
    # teacher-forced argmax (internal consistency of the serving path)
    eng = ServeEngine(m, qp, max_len=64)
    prompt = data.sequence(999, 12)
    out = eng.generate(Request(rid=0, prompt=prompt, max_new_tokens=4))
    full = np.concatenate([prompt, out[:3]])
    logits, _ = jax.jit(lambda p, b: m.forward(p, b))(
        qp, {"tokens": jnp.asarray(full)[None]})
    expect = int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))
    assert int(out[3]) == expect
