"""Theorem 1 numeric verification (paper §2.3)."""
from repro.core.theory import theorem1_check, theorem1_win_rate

import jax


def test_theorem1_win_rate():
    """Under the theorem's scenario (persistent channel importance, noisy
    small-calibration statistics), FAQ's fused scale beats AWQ's
    current-layer scale in a large majority of draws."""
    rate = theorem1_win_rate(n_seeds=16)
    assert rate >= 0.75, f"win rate {rate}"


def test_theorem1_single_instance():
    r = theorem1_check(jax.random.PRNGKey(0))
    assert float(r.delta_awq) > 0 and float(r.delta_faq) > 0
