"""Open-loop traffic harness: seeded trace generation, the arrival
feed, the injectable clock seam, and run_traffic percentile records."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.registry import build_model
from repro.serve import (ArrivalFeed, Request, Scheduler, ServeEngine,
                         TrafficConfig, make_trace, summarize)


@pytest.fixture(scope="module")
def fp_setup():
    cfg = ARCHS["llama3-8b"].tiny()
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


# -- trace generation ---------------------------------------------------------

def test_make_trace_seeded_and_shaped():
    cfg = TrafficConfig(n_requests=50, rate=20.0, seed=3)
    t1, t2 = make_trace(cfg), make_trace(cfg)
    assert len(t1) == 50
    assert t1[0][0] == 0.0                      # first arrival at t=0
    offs = [t for t, _ in t1]
    assert offs == sorted(offs)
    for (a, ra), (b, rb) in zip(t1, t2):        # same seed -> same trace
        assert a == b
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
    lens = [len(r.prompt) for _, r in t1]
    assert max(lens) <= cfg.prompt_len_max and min(lens) >= 1
    assert len(set(lens)) > 3                   # long-tail, not constant


def test_make_trace_bursty_and_shared_prefix():
    cfg = TrafficConfig(n_requests=24, process="bursty", burst_size=6,
                        rate=30.0, shared_prefix_frac=1.0, seed=1)
    trace = make_trace(cfg)
    offs = [t for t, _ in trace]
    assert len(set(offs)) == 4                  # 24/6 bursts
    # every prompt starts with one of the n_prefixes shared prefixes
    firsts = {tuple(r.prompt[:cfg.prefix_len]) for _, r in trace}
    assert 1 <= len(firsts) <= cfg.n_prefixes
    with pytest.raises(ValueError):
        make_trace(TrafficConfig(process="weibull"))


# -- arrival feed -------------------------------------------------------------

def test_arrival_feed_releases_by_clock():
    reqs = [Request(rid=i, prompt=np.ones(4, np.int32)) for i in range(3)]
    arrivals = {}
    feed = ArrivalFeed([(0.0, reqs[0]), (1.0, reqs[1]), (2.5, reqs[2])],
                       record=lambda rid, t: arrivals.__setitem__(rid, t))
    assert feed.pending() and feed.next_time() is None  # not anchored yet
    assert [r.rid for r in feed.poll(10.0)] == [0]      # anchors t0=10
    assert feed.next_time() == 11.0
    assert feed.poll(10.5) == []
    assert [r.rid for r in feed.poll(12.9)] == [1, 2]
    assert not feed.pending() and feed.next_time() is None
    assert arrivals == {0: 10.0, 1: 11.0, 2: 12.5}


def test_arrival_feed_edf_orders_same_poll():
    reqs = [Request(rid=i, prompt=np.ones(4, np.int32)) for i in range(3)]
    reqs[0].deadline = 9.0
    reqs[2].deadline = 5.0          # tightest deadline, latest offset
    feed = ArrivalFeed([(0.5, reqs[0]), (0.6, reqs[1]), (0.7, reqs[2])])
    assert feed.poll(10.0) == []                        # anchors t0=10
    assert [r.rid for r in feed.poll(20.0)] == [2, 0, 1]


# -- percentile report --------------------------------------------------------

def test_summarize_percentiles():
    records = {i: dict(arrival=0.0, admit=0.01 * i, first=0.02 + 0.01 * i,
                       end=0.10 + 0.01 * i, tokens=5)
               for i in range(20)}
    rep = summarize(records)
    assert rep["submitted"] == rep["completed"] == 20
    assert rep["tokens"] == 100
    for key in ("ttft_ms", "queue_delay_ms", "per_token_ms"):
        dist = rep[key]
        assert np.isfinite([dist["p50"], dist["p95"], dist["p99"]]).all()
        assert dist["p50"] <= dist["p95"] <= dist["p99"]
    assert rep["ttft_ms"]["p50"] == pytest.approx(115.0)


# -- clock seam ---------------------------------------------------------------

def test_injected_clock_drives_deadlines(fp_setup):
    """One ``clock=`` seam end-to-end: a fake clock makes a mid-decode
    deadline expire deterministically, no monkeypatching."""
    cfg, m, params = fp_setup
    tick = {"t": 0.0}

    def fake_clock():
        tick["t"] += 1.0
        return tick["t"]

    eng = ServeEngine(m, params, n_slots=1, max_len=64, clock=fake_clock)
    assert Scheduler(eng).clock is fake_clock
    prompt = (np.arange(6) % cfg.vocab_size).astype(np.int32)
    ref = ServeEngine(m, params, n_slots=1, max_len=64).serve(
        [Request(rid=1, prompt=prompt, max_new_tokens=30)])[1]
    out = eng.serve([Request(rid=0, prompt=prompt, max_new_tokens=30,
                             deadline=8.5)])[0]
    mm = eng.metrics()
    assert mm["truncated"] == 1 and mm["expired"] == 0
    assert 0 < len(out) < 30
    np.testing.assert_array_equal(out, ref[:len(out)])


# -- open-loop serving --------------------------------------------------------

def test_run_traffic_records_and_percentiles(fp_setup):
    cfg, m, params = fp_setup
    tick = {"t": 0.0}

    def fake_clock():
        tick["t"] += 0.002
        return tick["t"]

    eng = ServeEngine(m, params, n_slots=2, max_len=64, clock=fake_clock)
    tcfg = TrafficConfig(n_requests=10, rate=100.0, max_new_tokens=4,
                         prompt_len_median=6, prompt_len_max=20,
                         vocab_size=cfg.vocab_size, seed=7)
    res = Scheduler(eng).run_traffic(make_trace(tcfg))
    assert len(res) == 10
    rep = res.traffic
    assert rep["submitted"] == rep["completed"] == 10
    assert rep["tokens"] == 40
    for rec in res.records.values():
        assert rec["arrival"] is not None
        assert rec["admit"] >= rec["arrival"]
        assert rec["first"] >= rec["admit"]
        assert rec["end"] >= rec["first"]
        assert rec["tokens"] == 4
    for key in ("ttft_ms", "queue_delay_ms", "per_token_ms"):
        dist = rep[key]
        assert np.isfinite([dist["p50"], dist["p95"], dist["p99"]]).all()
        assert dist["p50"] <= dist["p95"] <= dist["p99"]


def test_run_traffic_greedy_matches_closed_loop(fp_setup):
    """Open-loop admission changes *when* requests run, never what they
    generate: greedy outputs match the closed-loop serve."""
    cfg, m, params = fp_setup
    tcfg = TrafficConfig(n_requests=8, rate=50.0, max_new_tokens=4,
                        vocab_size=cfg.vocab_size, seed=11)
    eng = ServeEngine(m, params, n_slots=2, max_len=64)
    res = Scheduler(eng).run_traffic(make_trace(tcfg))
    closed = ServeEngine(m, params, n_slots=2, max_len=64).serve(
        [req for _, req in
         [(t, Request(rid=100 + r.rid, prompt=r.prompt,
                      max_new_tokens=r.max_new_tokens))
          for t, r in make_trace(tcfg)]])
    for t, r in make_trace(tcfg):
        np.testing.assert_array_equal(res[r.rid], closed[100 + r.rid])
